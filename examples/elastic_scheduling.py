"""Elastic scheduling walkthrough (paper §III.B, Figs 8-9).

Models the paper's exact Tencent-Cloud setup: Shanghai (Cascade Lake CPUs)
and Chongqing (Skylake CPUs) with uneven data, plans resources with
Algorithm 1, and simulates the waiting-time/cost effect over a 100 Mbps WAN.

Run:  PYTHONPATH=src python examples/elastic_scheduling.py
"""
from repro.core.scheduler import (CloudResources, optimal_matching,
                                  predict_times, waiting_fraction)
from repro.core.sync import SyncConfig
from repro.core.wan import SimCloud, WANConfig, simulate

# paper Table IV case 3: data ratio 2:1, Cascade vs Skylake, 12 cores each
clouds = [CloudResources("shanghai", (("cascade", 6),), data_size=2.0),
          CloudResources("chongqing", (("skylake", 6),), data_size=1.0)]

print("=== Algorithm 1: optimal matching ===")
plans = optimal_matching(clouds)
for p in plans:
    cores = {d: 2 * n for d, n in p.allocation}
    print(f"  {p.region:10s} -> {cores} (LP={p.load_power:.2f})")

print("\n=== predicted waiting fraction (greedy vs elastic) ===")
print("  greedy :", {k: round(v, 3) for k, v in
                     waiting_fraction(predict_times(clouds)).items()})
print("  elastic:", {k: round(v, 3) for k, v in
                     waiting_fraction(predict_times(clouds, plans)).items()})

print("\n=== simulated 300-iteration run (ResNet/4, 0.6 MB grads) ===")
for label, units in (("greedy", [6, 6]),
                     ("elastic", [dict(p.allocation).get(d, 0)
                                  for p, d in zip(plans,
                                                  ("cascade", "skylake"))])):
    sims = [SimCloud(c.region, iter_time_s=0.7 * c.data_size / (u / 6),
                     units=2 * u) for c, u in zip(clouds, units)]
    r = simulate(sims, SyncConfig("asgd", 1), n_iters=300, model_mb=0.6,
                 wan=WANConfig(seed=0))
    wait = sum(c.wait_s for c in r.clouds)
    print(f"  {label:8s} makespan={r.makespan_s:8.1f}s wait={wait:8.1f}s "
          f"cost={r.total_cost:.3f}")
