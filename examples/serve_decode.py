"""Batched serving example: prefill + KV-cache decode on a smoke-scale
assigned architecture, including a sliding-window (gemma-style) model whose
ring cache keeps decode memory bounded.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.registry import get_model_fns
from repro.serving.engine import BatchScheduler, ServingEngine

for arch_name in ("granite-8b", "gemma2-27b", "mamba2-1.3b"):
    arch = get_arch(arch_name)
    cfg = arch.smoke
    fns = get_model_fns(arch.module)
    params = fns.init_params(jax.random.key(0), cfg)
    engine = ServingEngine(arch, params, cache_len=48, use_smoke=True)
    sched = BatchScheduler(engine, batch_size=4)

    rng = np.random.default_rng(0)
    for _ in range(6):
        plen = int(rng.integers(4, 17))
        sched.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                     max_new=8)
    t0 = time.time()
    results = sched.run()
    toks = sum(len(v) for v in results.values())
    print(f"{arch_name:14s} served {len(results)} requests, {toks} tokens "
          f"in {time.time() - t0:.1f}s")
