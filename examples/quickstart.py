"""Quickstart: train a small LM on 2 emulated cloud partitions with the
paper's ASGD-GA synchronization, then generate from it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import dense
from repro.core.sync import SyncConfig
from repro.data.pipeline import TokenStream
from repro.models import transformer as T
from repro.training.trainer import Trainer, TrainerConfig

# 1. a small decoder-only config (same machinery as the 10 assigned archs)
cfg = dense("quickstart-lm", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            d_ff=512, vocab=256, tie_embeddings=True, vocab_multiple=64,
            param_dtype="float32", compute_dtype="float32", remat="none")

# 2. two geo-distributed "clouds" = two pod partitions, synced every 4 steps
#    by shipping accumulated gradients to one ring peer (paper ASGD-GA)
trainer = Trainer(
    loss_fn=lambda p, b: T.loss_fn(p, cfg, b),
    init_fn=lambda k: T.init_params(k, cfg),
    cfg=TrainerConfig(n_pods=2, optimizer="sgd", lr=0.1,
                      sync=SyncConfig("asgd_ga", interval=4)),
)
state = trainer.init_state(jax.random.key(0))

streams = [TokenStream(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                       seed=1, shard=i) for i in range(2)]


def batches(step):
    parts = [s.batch(step) for s in streams]
    return {k: jnp.asarray(np.stack([p[k] for p in parts])) for k in parts[0]}


state, hist = trainer.fit(state, batches, n_steps=60, log_every=20,
                          model_mb=1.0)
print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}   "
      f"inter-pod traffic: {trainer.traffic_mb:.1f} MB")
assert hist["loss"][-1] < hist["loss"][0]

# 3. greedy decode with the pod-0 replica through the KV cache
params = jax.tree.map(lambda x: x[0], state.params)
cache = T.init_cache(cfg, 1, 32)
tok = jnp.asarray([[1]], jnp.int32)
out = []
for t in range(16):
    logits, cache = T.decode_step(params, cfg, tok, cache, jnp.int32(t))
    tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
    out.append(int(tok[0, 0]))
print("generated:", out)
