"""Compare the paper's synchronization strategies on REAL training.

Trains the paper's LeNet on two emulated cloud partitions with uneven data
(2:1) under all four strategies and prints the accuracy/loss outcomes plus
the WAN traffic each strategy would ship (paper Figs 10-11).

Run:  PYTHONPATH=src python examples/geo_sync_strategies.py
"""
import jax
import numpy as np

from repro.core.sync import SyncConfig
from repro.data.pipeline import GeoDataset, synthetic_classification
from repro.models.reference import PAPER_MODELS, param_mb
from repro.training.trainer import (Trainer, TrainerConfig, accuracy_eval,
                                    stack_pod_batches)

m = PAPER_MODELS["lenet"]
data = synthetic_classification(2000, m["input_shape"], m["n_classes"], seed=0)
test = synthetic_classification(500, m["input_shape"], m["n_classes"], seed=1)
geo = GeoDataset.partition(data, ["shanghai", "chongqing"], [2, 1])
print(f"geo shards: {geo.sizes()}")

for strat, k in (("asgd", 1), ("asgd_ga", 8), ("ama", 8), ("sma", 8)):
    loaders = [geo.loader("shanghai", 32, seed=0),
               geo.loader("chongqing", 32, seed=1)]
    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=SyncConfig(strat, k)))
    st = tr.init_state(jax.random.key(0))
    st, hist = tr.fit(st,
                      lambda s: stack_pod_batches([next(l) for l in loaders]),
                      150, eval_fn=accuracy_eval(m["apply"], test),
                      eval_every=150,
                      model_mb=param_mb(jax.tree.map(lambda x: x[0],
                                                     st.params)))
    print(f"{strat:8s}@{k}: acc={hist['eval'][-1][1]:.3f} "
          f"loss={np.mean(hist['loss'][-10:]):.4f} "
          f"wan={tr.traffic_mb:7.1f} MB")
