"""Performance hillclimbing driver (EXPERIMENTS.md §Perf).

Hypothesis -> change -> re-lower -> re-analyse cycles on the three chosen
(arch x shape) pairs.  Each variant is a tagged dry-run record
(``experiments/dryrun/<arch>__<shape>__<mesh>__<tag>.json``); this script
runs the variants and prints the roofline-term deltas vs the baseline.

Variants (the "change" column of the §Perf log):
  chunked   attention_impl=xla_chunked — flash-style blockwise attention in
            XLA; kills the O(S^2) fp32 score buffers  (memory/bytes term)
  onehot    embed_impl=onehot — vocab-sharded one-hot matmul embedding;
            avoids SPMD's involuntary full rematerialization of the gathered
            embedding table  (collective term)
  dots      remat=dots — keep matmul outputs, recompute elementwise only
            (compute term, at activation-memory cost)
  both      chunked + onehot
  cap10     MoE capacity_factor 1.25 -> 1.0 (drops overflow tokens;
            all-to-all and expert-compute term)
  syncN     multi-pod only: sync strategy sweep on the pod axis —
            asgd@1 (baseline per-step all-reduce) vs ama@8 vs asgd_ga@8 vs
            asgd_ga@8 + top-k 1% compression (the paper's technique + the
            beyond-paper compressor; measured on the sync_step record)

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --pair gemma3-12b:train_4k \
      --variants chunked,onehot,both
  PYTHONPATH=src python -m benchmarks.hillclimb --sync-sweep kimi-k2-1t-a32b
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from typing import Dict, Optional

from repro.models.config import MoEConfig

VARIANTS: Dict[str, Dict] = {
    "chunked": {"attention_impl": "xla_chunked"},
    "onehot": {"embed_impl": "onehot"},
    "both": {"attention_impl": "xla_chunked", "embed_impl": "onehot"},
    "dots": {"remat": "dots"},
    "chunked_dots": {"attention_impl": "xla_chunked", "remat": "dots"},
    "best": {"attention_impl": "xla_chunked", "embed_impl": "onehot",
             "remat": "dots"},
    "grouped": {"moe_dispatch": "grouped"},
    "grouped_onehot": {"moe_dispatch": "grouped", "embed_impl": "onehot"},
    "grouped_ff": {"moe_dispatch": "grouped", "moe_param_shard": "ff"},
    "moeff": {"moe_param_shard": "ff"},
    "moeff_onehot": {"moe_param_shard": "ff", "embed_impl": "onehot"},
    "all3": {"moe_param_shard": "ff", "embed_impl": "onehot",
             "attention_impl": "xla_chunked"},
}


def _term_summary(rec: Dict) -> Dict:
    from benchmarks.roofline import analyze_record
    row = analyze_record(rec)
    if row is None:
        return {"status": rec.get("status"), "error": rec.get("error", "")[:300]}
    return {"compute_s": row.compute_s, "memory_s": row.memory_s,
            "collective_s": row.collective_s, "dominant": row.dominant,
            "useful_ratio": row.useful_ratio}


def run_pair(arch: str, shape: str, variants, mesh: str = "single_pod"):
    from repro.launch.dryrun import run_one

    base_path = f"experiments/dryrun/{arch}__{shape}__{mesh}.json"
    if os.path.exists(base_path):
        base = json.load(open(base_path))
    else:
        base = run_one(arch, shape, mesh)
    print(f"baseline: {json.dumps(_term_summary(base))}")

    results = {"baseline": _term_summary(base)}
    for name in variants:
        ov = dict(VARIANTS[name])
        if name == "cap10":
            cfg_moe = None  # handled below with a real MoEConfig
        rec = run_one(arch, shape, mesh, tag=name, config_overrides=ov)
        results[name] = _term_summary(rec)
        print(f"{name}: {json.dumps(results[name])}")
    return results


def run_moe_capacity(arch: str, shape: str, mesh: str = "single_pod"):
    from repro.configs import get_arch
    from repro.launch.dryrun import run_one
    cfg = get_arch(arch).config
    ov = {"moe": MoEConfig(num_experts=cfg.moe.num_experts,
                           top_k=cfg.moe.top_k, capacity_factor=1.0)}
    rec = run_one(arch, shape, mesh, tag="cap10", config_overrides=ov)
    print(f"cap10: {json.dumps(_term_summary(rec))}")
    return rec


def run_sync_sweep(arch: str, shape: str = "train_4k"):
    """The paper's own experiment at dry-run level: inter-pod bytes per
    training step under each strategy (multi-pod mesh)."""
    from repro.launch.dryrun import run_one

    out = {}
    settings = [("asgd", 1, 0.0), ("ama", 8, 0.0), ("asgd_ga", 8, 0.0),
                ("asgd_ga", 8, 0.01)]
    for strat, k, topk in settings:
        tag = f"sync_{strat}{k}" + (f"_top{topk}" if topk else "")
        rec = run_one(arch, shape, "multi_pod", sync_strategy=strat,
                      sync_interval=k, sync_compress=topk, tag=tag,
                      extrapolate=False, config_overrides=None)
        if rec["status"] != "ok":
            out[tag] = {"status": rec["status"],
                        "error": rec.get("error", "")[:200]}
            print(tag, json.dumps(out[tag]))
            continue
        # the sync_step program touches ONLY the pod axis (roll/mean over the
        # stacked dim), so its collective total per device IS the inter-pod
        # traffic per sync round; the asgd baseline instead syncs inside
        # every train step (grads pmean over pod)
        step_total = rec["collectives"]["total_bytes"]
        sync_rec = rec.get("sync_step", {})
        sync_total = sync_rec.get("collectives", {}).get("total_bytes", 0)
        out[tag] = {"train_step_collective_B_per_dev": step_total,
                    "sync_round_B_per_dev": sync_total,
                    "amortized_sync_B_per_dev_step": sync_total / k,
                    "status": "ok"}
        print(tag, json.dumps(out[tag]))
    with open(f"experiments/bench/sync_sweep_{arch}.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", help="arch:shape")
    ap.add_argument("--variants", default="chunked,onehot,both")
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--moe-capacity", help="arch:shape")
    ap.add_argument("--sync-sweep", help="arch")
    args = ap.parse_args()
    if args.pair:
        arch, shape = args.pair.split(":")
        run_pair(arch, shape, args.variants.split(","), args.mesh)
    if args.moe_capacity:
        arch, shape = args.moe_capacity.split(":")
        run_moe_capacity(arch, shape)
    if args.sync_sweep:
        run_sync_sweep(args.sync_sweep)


if __name__ == "__main__":
    main()
