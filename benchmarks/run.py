"""Benchmark harness — one function per paper table/figure plus the roofline.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark) and writes
full JSON results to ``experiments/bench/``.
"""
from __future__ import annotations

import json
import os
import time

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_DIR = os.path.join(HERE, "..", "experiments", "bench")


def _run(name: str, fn, derived_key) -> None:
    t0 = time.time()
    result = fn()
    dt_us = (time.time() - t0) * 1e6
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(result, f, indent=1)
    derived = derived_key(result) if callable(derived_key) else derived_key
    print(f"{name},{dt_us:.0f},{derived}", flush=True)


def main() -> None:
    from benchmarks import paper_repro as P

    _run("table1_device_quantification", P.bench_table1,
         lambda r: f"max_tn_err={r['max_tn_rel_err_vs_paper']}")

    _run("fig7_usability_lenet", lambda: P.bench_usability(model="lenet"),
         lambda r: f"acc_gap={r['acc_gap']}")
    _run("fig7_usability_deepfm",
         lambda: P.bench_usability(model="deepfm", steps=100),
         lambda r: f"acc_gap={r['acc_gap']}")

    _run("fig8_elastic_scheduling", P.bench_scheduling,
         lambda r: "cost_red=" + "/".join(
             str(r[c]["cost_reduction"]) for c in ("case1", "case2", "case3")))

    _run("fig10_sync_strategies", P.bench_sync,
         lambda r: f"deepfm_max_speedup="
                   f"{max(v['speedup'] for v in r['deepfm'].values())}")

    _run("fig11_sma_accuracy", P.bench_sma,
         lambda r: f"sma_acc={r['accuracy']['sma@8']}")

    from benchmarks import elasticity as E
    _run("elasticity", E.bench_elasticity,
         lambda r: f"speedup={r['speedup']} "
                   f"cost_red={r['cost_reduction']}")

    from benchmarks import wan_codec as W
    _run("wan_codec", W.run_bench,          # also writes BENCH_wan_codec.json
         lambda r: f"enc_speedup={r['encode_kernel']['encode_speedup']}x "
                   f"wire_red={r['bytes_on_wire']['reduction_vs_dense']}x "
                   f"ef_frac="
                   f"{r['ef_convergence']['ef_loss_reduction_frac_of_dense']}")

    from benchmarks import autotune as A
    _run("autotune", A.bench_autotune,      # also writes BENCH_autotune.json
         lambda r: f"adaptive_speedup={r['speedup_vs_best_static']}x "
                   f"guard_ok="
                   f"{r['acceptance']['ef_guard_never_violated']}")

    from benchmarks import serving as S
    _run("serving", S.bench_serving,        # also writes BENCH_serving.json
         lambda r: f"throughput_speedup={r['throughput_speedup']}x "
                   f"p99_improvement={r['p99_improvement']}x "
                   f"reroute_ok="
                   f"{r['acceptance']['router_reroutes_on_link_collapse']}")

    # roofline from the dry-run artifacts (skips silently if none exist yet)
    def _roofline():
        from benchmarks import roofline as R
        rows = R.load_rows()
        with open(R.OUT_PATH, "w") as f:
            json.dump([R.asdict(r) for r in rows], f, indent=1)
        doms = {}
        for r in rows:
            if r.mesh == "single_pod":
                doms[r.dominant] = doms.get(r.dominant, 0) + 1
        return {"rows": len(rows), "dominant_histogram": doms}

    _run("roofline", _roofline,
         lambda r: f"rows={r['rows']} dominants={r['dominant_histogram']}")


if __name__ == "__main__":
    main()
