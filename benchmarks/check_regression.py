"""Benchmark-regression gate: re-run the fast paths of the committed
benches and fail the build when the trajectory regresses.

Two BENCH_*.json baselines are committed (``experiments/bench/``); this
checker makes them a *gate*, not a log.  Checks, cheapest first:

- **Exact** (tolerance 1e-6): payload math — bytes-on-wire per tier,
  reductions vs dense.  Pure arithmetic over ``SyncConfig.payload_mb``;
  any drift is a real semantics change.
- **Replay** (exact): the adaptive controllers' decision sequences.
  ``BENCH_autotune.json`` records the per-step (sim_t, bandwidth,
  EF-norm) signal stream — per bucket for the multi-controller run;
  replaying it through a fresh ``AdaptiveSyncController`` (and the
  per-bucket stream through a fresh ``BucketedSyncController``) must
  reproduce the recorded decisions rung-for-rung — a deterministic
  regression check of both control laws without re-training — and must
  never escalate past the EF guard on any bucket.  The topology scenario
  records the planner's interleaved (per-link observation, decide) event
  stream the same way; a fresh ``LinkBeliefs`` + ``TopologyPlanner`` must
  reproduce its shape decisions exactly, reason strings (with embedded
  cost estimates) included.  The streaming scenario records the per-chunk
  observation stream and the chunk-level controller's per-chunk decision
  dicts; a fresh ``StreamingShipController`` — sharing one fresh probe
  estimator with the replayed round-level controller, as the live run
  did — must reproduce both decision streams, and every chunk's billed
  seconds must re-derive from its round's transfer draws through
  ``wan.stream_chunk_time`` float-for-float.  ``BENCH_faults.json`` records every faulted
  sync round's (step, expected transfer time) inputs and resolved
  outcome; re-running the committed FaultPlan + RetryPolicy through
  ``resolve_round`` must reproduce the retry/degrade/crash decision
  stream float-for-float.  ``BENCH_serving.json`` records the serving
  plane the same way: the continuous variant's router event stream must
  replay placement-for-placement through a fresh ``GeoRouter`` and the
  windowed load stream decision-for-decision through a fresh
  ``ServingElasticityController``.  ``BENCH_elasticity.json`` records the
  live-migration decision stream (plan diff, keep set, barrier-reconcile
  stall, staged snapshot bytes, replaced full pause); replaying the
  scenario's events through a fresh ``ElasticityController`` and
  ``ReconfigPlan.migration_bill`` must reproduce it field-for-field.
- **Banded** (deterministic sims, 5%): the elasticity benchmark's
  speedup / cost-reduction / traffic-reduction and the serving
  benchmark's throughput-speedup / p99-improvement (discrete-event
  simulators, seeded RNG).
- **Banded** (timing, floor at 40% of baseline): the fused-codec encode
  speedup over the iterative-argmax kernel, re-timed at a reduced buffer
  size so the whole gate stays CI-fast.  Timing on shared runners is
  noisy, hence the generous floor — it still catches the
  "someone serialized the kernel again" class of regression.
- **Acceptance flags**: every ``acceptance`` boolean in every committed
  baseline must still be true (a baseline refreshed into a failing state
  is itself a regression).

Exit code 1 on any failure.  ``--report PATH`` writes the full check
table as JSON (uploaded as a CI artifact next to freshly regenerated
baselines).

Run:  PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Sequence

HERE = os.path.dirname(os.path.abspath(__file__))
BENCH_DIR = os.path.join(HERE, "..", "experiments", "bench")

REDUCED_N = 1 << 20        # encode re-time buffer — must match the
#   baseline's size: the iterative-argmax/fused gap only opens at real
#   buffer sizes (interpret-mode dispatch overhead dominates below ~1M),
#   so a smaller proxy would under-measure; one rep keeps it CI-fast
TIMING_FLOOR = 0.4         # re-timed speedup must be >= 40% of baseline
SIM_TOL = 0.05             # deterministic-sim band


class Gate:
    def __init__(self):
        self.rows: List[Dict] = []

    def check(self, name: str, ok: bool, detail: str) -> None:
        self.rows.append({"check": name, "ok": bool(ok), "detail": detail})
        mark = "PASS" if ok else "FAIL"
        print(f"[{mark}] {name}: {detail}")

    @property
    def failed(self) -> bool:
        return any(not r["ok"] for r in self.rows)


def _load(name: str) -> Dict:
    with open(os.path.join(BENCH_DIR, name)) as f:
        return json.load(f)


# ------------------------------------------------------------ exact checks


def check_payload_math(gate: Gate, base: Dict) -> None:
    from repro.core.sync import SyncConfig

    wire = base["bytes_on_wire"]
    model_mb, frac, interval = wire["model_mb"], 0.01, wire["interval"]
    expect = {
        "dense_fp32_mb": SyncConfig("asgd_ga", interval),
        "sparse_fp32_mb": SyncConfig("asgd_ga", interval,
                                     compress_topk=frac),
        "codec_int8_mb": SyncConfig("asgd_ga", interval, compress_topk=frac,
                                    quantize_int8=True),
        "codec_fp8_mb": SyncConfig("asgd_ga", interval, compress_topk=frac,
                                   quantize_int8=True, value_dtype="fp8"),
        "codec_int4_mb": SyncConfig("asgd_ga", interval, compress_topk=frac,
                                    quantize_int8=True, value_dtype="int4"),
    }
    for key, cfg in expect.items():
        want = round(cfg.payload_mb(model_mb), 4)
        got = wire[key]
        gate.check(f"wan_codec.bytes_on_wire.{key}",
                   abs(want - got) < 1e-6,
                   f"baseline {got} vs recomputed {want}")


# ----------------------------------------------------------- replay checks


def _tuner_parts(tuner_rec: Dict, base_sync: Dict, **sync_extra):
    """Rebuild the exact (controller knobs, guard, base SyncConfig) a
    baseline recorded — shared by all three replay gates so a change to
    how the bench records its controller cannot drift between them.  The
    baseline records the exact controller the bench ran; knobs are NOT
    duplicated here, so retuning the bench without refreshing the
    baseline fails loudly instead of replaying a different controller."""
    from repro.core.sync import SyncConfig

    knobs = dict(tuner_rec)
    knobs.pop("base_sync", None)
    knobs["topk_ladder"] = tuple(knobs["topk_ladder"])
    sync = SyncConfig(base_sync["strategy"], base_sync["interval"],
                      compress_topk=base_sync["compress_topk"],
                      quantize_int8=True, error_feedback=True, **sync_extra)
    return knobs, knobs["ef_guard"], sync


def _check_decisions(gate: Gate, name: str, replayed, recorded) -> None:
    gate.check(name, replayed == recorded,
               f"{len(replayed)} replayed vs {len(recorded)} recorded"
               + ("" if replayed == recorded
                  else f"; first diff at "
                       f"{next((i for i, (a, b) in enumerate(zip(replayed, recorded)) if a != b), min(len(replayed), len(recorded)))}"))


def check_controller_replay(gate: Gate, base: Dict) -> None:
    from repro.core.autotune import AdaptiveSyncController, BucketStats

    adaptive = base["variants"]["adaptive"]
    scen = base["scenario"]
    knobs, guard, sync = _tuner_parts(scen["tuner"],
                                      scen["tuner"]["base_sync"])
    tuner = AdaptiveSyncController(
        sync, scen["model_mb"], scen["compute_step_s"], **knobs)
    tuner.observe_wan(scen["trace"][0][1])
    replayed = []
    for step, (sim_t, bw, msg_norm, resid_norm) in \
            enumerate(adaptive["signals"]):
        tuner.observe_wan(bw)
        # full-precision norms off the baseline: preserves both the
        # no-reading state (msg_norm 0) and the consume-once staleness
        # comparison exactly as the live run saw them
        upd = tuner.update(step, BucketStats(msg_norm=msg_norm,
                                             resid_norm=resid_norm))
        if upd is not None:
            replayed.append((step, upd.rung, upd.sync.interval, upd.reason))
    recorded = [(d["step"], d["rung"], d["interval"], d["reason"])
                for d in adaptive["decisions"]]
    _check_decisions(gate, "autotune.replay.decisions", replayed, recorded)
    gate.check("autotune.replay.max_ef_ratio_under_guard",
               tuner.max_ef_ratio <= guard,
               f"replayed max {round(tuner.max_ef_ratio, 6)} vs guard {guard}")


def check_measured_replay(gate: Gate, base: Dict) -> None:
    """Replay the measured-feedback (transport-seam) scenario: the
    recorded per-step (billed transfer, EF stats) stream through a fresh
    MeasuredWanProbe + probe_est-injected AdaptiveSyncController must
    reproduce the recorded decisions exactly — the controller's ONLY
    bandwidth input is the transfer observations, so this pins the whole
    measured data path (transfer time -> achieved mbps -> estimator ->
    control law) deterministically."""
    from repro.core.autotune import AdaptiveSyncController, BucketStats
    from repro.core.transport import MeasuredWanProbe

    scen = base["scenario"]
    meas = base["measured"]
    run = meas["variant"]
    knobs, guard, sync = _tuner_parts(scen["tuner"],
                                      scen["tuner"]["base_sync"])
    probe = MeasuredWanProbe(**meas["probe"])
    tuner = AdaptiveSyncController(
        sync, scen["model_mb"], scen["compute_step_s"],
        probe_est=probe.estimator, **knobs)
    replayed = []
    for step, (sim_t, transfer, msg_norm, resid_norm) in \
            enumerate(run["signals"]):
        if transfer is not None:
            probe.observe_transfer(transfer[0], transfer[1])
        upd = tuner.update(step, BucketStats(msg_norm=msg_norm,
                                             resid_norm=resid_norm))
        if upd is not None:
            replayed.append((step, upd.rung, upd.sync.interval, upd.reason))
    recorded = [(d["step"], d["rung"], d["interval"], d["reason"])
                for d in run["decisions"]]
    _check_decisions(gate, "autotune.measured_replay.decisions",
                     replayed, recorded)
    gate.check("autotune.measured_replay.guard",
               tuner.max_ef_ratio <= guard,
               f"replayed max {round(tuner.max_ef_ratio, 6)} vs guard "
               f"{guard}")
    gate.check("autotune.measured_replay.probe_fed_from_transfers_only",
               probe.n_observations == sum(
                   1 for s in run["signals"] if s[1] is not None)
               and probe.n_observations > 0,
               f"{probe.n_observations} transfer observations")


def check_bucketed_replay(gate: Gate, base: Dict) -> None:
    """Replay the multi-controller (per-bucket) trace: the recorded
    per-bucket signal stream through a fresh BucketedSyncController must
    reproduce every decision — rungs, interval and reasons — exactly."""
    from repro.core.autotune import BucketStats, BucketedSyncController

    scen = base["scenario"]
    bucketed = base["bucketed"]
    run = bucketed["variants"]["bucketed"]
    # the bucketed scenario records its own knob set (wider escalation
    # margin for the undiluted per-bucket ratios) — replay exactly those
    knobs, guard, sync = _tuner_parts(bucketed["tuner"],
                                      scen["tuner"]["base_sync"],
                                      bucket_policy="layer-class")
    tuner = BucketedSyncController(
        sync, bucketed["bucket_mb"], scen["compute_step_s"], **knobs)
    tuner.observe_wan(scen["trace"][0][1])
    replayed = []
    for step, (sim_t, bw, per_bucket) in enumerate(run["signals"]):
        tuner.observe_wan(bw)
        stats = {n: BucketStats(msg_norm=m, resid_norm=r)
                 for n, (m, r) in per_bucket.items()}
        upd = tuner.update(step, stats)
        if upd is not None:
            replayed.append((step, {n: r for n, r, _ in upd.rungs},
                             upd.sync.interval, list(upd.reasons)))
    recorded = [(d["step"], d["rungs"], d["interval"], d["reasons"])
                for d in run["decisions"]]
    _check_decisions(gate, "autotune.bucketed_replay.decisions",
                     replayed, recorded)
    gate.check("autotune.bucketed_replay.guard_on_every_bucket",
               all(r <= guard
                   for r in tuner.max_ef_ratio_by_bucket.values()),
               f"replayed per-bucket max "
               f"{ {n: round(r, 4) for n, r in tuner.max_ef_ratio_by_bucket.items()} } "
               f"vs guard {guard}")


def check_topology_replay(gate: Gate, base: Dict) -> None:
    """Replay the topology planner's decisions: the baseline records the
    auto variant's exact interleaved event stream — per-link bandwidth
    observations (as billed by the HierarchicalTransport) and planner
    decide calls (step, payload) in occurrence order.  Feeding it through
    a fresh LinkBeliefs + TopologyPlanner must reproduce the recorded
    decision tuples exactly, reason strings included — the reasons embed
    both candidates' cost estimates to 4 decimals, so this pins the whole
    topology cost model (belief EMA + cliff-snap -> schedule compilation
    -> round-cost estimate -> hysteresis/margin switch law)
    deterministically, without re-training."""
    from repro.core.topology import LinkBeliefs, TopologyPlanner, TopologySpec

    topo = base["topology"]
    auto = topo["variants"]["auto"]
    spec = TopologySpec.from_regions(topo["regions"],
                                     kind=topo["initial_kind"])
    beliefs = LinkBeliefs(default_mbps=topo["default_mbps"],
                          **topo["beliefs"])
    planner = TopologyPlanner(spec, beliefs, **topo["planner"])
    n_obs = 0
    for ev in auto["events"]:
        if ev[0] == "obs":
            beliefs.observe(ev[1], ev[2], float(ev[3]))
            n_obs += 1
        elif ev[0] == "decide":
            planner.decide(int(ev[1]), float(ev[2]))
    replayed = [list(d) for d in planner.decisions]
    recorded = [list(d) for d in auto["planner_decisions"]]
    _check_decisions(gate, "topology.replay.planner_decisions",
                     replayed, recorded)
    gate.check("topology.replay.final_kind",
               planner.kind == auto["final_kind"] and n_obs > 0,
               f"replayed {planner.kind} vs recorded {auto['final_kind']} "
               f"({n_obs} link observations)")
    # the schedule-shape arithmetic the traffic accounting bills: a fresh
    # compile at default beliefs must make the recorded number of
    # payload-sized WAN transfers per round (ring over R singleton
    # regions: R; tree: 2(R-1))
    fresh = LinkBeliefs(default_mbps=topo["default_mbps"])
    for kind, want in topo["wan_transfers"].items():
        got = spec.with_kind(kind).compile(fresh).wan_transfers
        gate.check(f"topology.wan_transfers.{kind}", got == want,
                   f"baseline {want} vs recomputed {got}")


def check_streaming_replay(gate: Gate, base: Dict) -> None:
    """Replay the chunk-granular streaming scenario: the baseline records,
    per variant, the per-step (billed transfer, EF stats) signal stream
    and — for the streaming variant — every round's chunk observation
    list plus the ``StreamingShipController``'s per-chunk decision dicts.
    Re-running BOTH coupled laws from those records — the round-level
    controller at every step top, the chunk-level controller inside every
    streaming round, sharing ONE fresh probe estimator exactly as the
    live run shared one — must reproduce the round decisions AND the
    chunk decision stream field-for-field (achieved/believed floats
    included).  The transport's billing law is re-derived too: every
    pre-retune chunk must bill its exact pro-rata slice of the round's
    clean draw (``stream_chunk_time``), every post-retune chunk its slice
    of the tail draw, and the round total must be the untouched clean
    draw (zero retune) or prefix-sum + tail — float-for-float after the
    JSON round trip.  Together these pin the whole chunk-level data path
    (per-chunk bill -> achieved mbps -> cliff law -> rung choice ->
    round-level handoff) deterministically, without re-training."""
    from repro.core.autotune import (AdaptiveSyncController, BucketStats,
                                     StreamingShipController)
    from repro.core.transport import MeasuredWanProbe
    from repro.core.wan import stream_chunk_time

    scen = base["scenario"]
    sb = base["streaming"]
    stream_knobs = dict(sb["stream"])
    for vname in ("round_adaptive", "streaming"):
        run = sb["variants"][vname]
        knobs, guard, sync = _tuner_parts(scen["tuner"],
                                          scen["tuner"]["base_sync"],
                                          overlap_chunks=sb["chunks"])
        probe = MeasuredWanProbe(**sb["probe"])
        tuner = AdaptiveSyncController(
            sync, scen["model_mb"], scen["compute_step_s"],
            probe_est=probe.estimator, **knobs)
        stream = (StreamingShipController(
                      sync, scen["model_mb"],
                      probe_est=probe.estimator, **stream_knobs)
                  if vname == "streaming" else None)
        rounds = {r["step"]: r for r in run.get("stream_rounds", [])}
        cur_sync = sync
        replayed = []
        for step, (sim_t, transfer, msg_norm, resid_norm) in \
                enumerate(run["signals"]):
            if transfer is not None:
                # the previous round's fold, in the exact order the live
                # run's estimator saw it (chunk observations never touch
                # the estimator — the round barrier folds once)
                probe.observe_transfer(transfer[0], transfer[1])
            stats = BucketStats(msg_norm=msg_norm, resid_norm=resid_norm)
            upd = tuner.update(step, stats)
            if upd is not None:
                cur_sync = upd.sync
                replayed.append((step, upd.rung, upd.sync.interval,
                                 upd.reason))
            rr = rounds.get(step)
            if rr is not None:
                stream.note_stats(stats)
                stream.begin_round(step, cur_sync)
                for name, mb, secs in rr["chunks"]:
                    stream.observe_chunk(name, float(mb), float(secs))
                stream.end_round()
        recorded = [(d["step"], d["rung"], d["interval"], d["reason"])
                    for d in run["decisions"]]
        _check_decisions(gate, f"streaming.replay.{vname}.round_decisions",
                         replayed, recorded)
        gate.check(f"streaming.replay.{vname}.guard",
                   tuner.max_ef_ratio <= guard,
                   f"replayed max {round(tuner.max_ef_ratio, 6)} vs guard "
                   f"{guard}")
        if stream is None:
            continue
        replayed_chunks = json.loads(json.dumps(stream.decisions))
        _check_decisions(gate, "streaming.replay.chunk_decisions",
                         replayed_chunks, run["stream_decisions"])
        gate.check("streaming.replay.mid_round_retunes",
                   stream.n_retunes == run["n_stream_retunes"]
                   and stream.n_rounds == run["n_stream_rounds"],
                   f"replayed {stream.n_retunes} retunes over "
                   f"{stream.n_rounds} rounds vs recorded "
                   f"{run['n_stream_retunes']}/{run['n_stream_rounds']}")

    # the billing law: each recorded chunk's seconds must re-derive from
    # its round's draws exactly (the cut point — which chunks are the
    # re-encoded tail — comes from the decision stream's retune entry)
    run = sb["variants"]["streaming"]
    cut_by_step = {d["step"]: d["chunk"] + 1
                   for d in run["stream_decisions"]
                   if d["action"] == "retune"}
    bad: List[str] = []
    for rr in run["stream_rounds"]:
        cut = (cut_by_step[rr["step"]] if rr["retuned"]
               else len(rr["chunks"]))
        prefix_s = 0.0
        for i, (name, mb, secs) in enumerate(rr["chunks"]):
            if i < cut:
                want = stream_chunk_time(rr["t_round"], mb, rr["total_mb"])
                prefix_s += want
            else:
                want = stream_chunk_time(rr["t_tail"], mb, rr["tail_mb"])
            if want != secs:
                bad.append(f"step {rr['step']} chunk {i}: "
                           f"{secs} != {want}")
        want_t = (rr["t_round"] if not rr["retuned"]
                  else prefix_s + rr["t_tail"])
        if want_t != rr["t_s"]:
            bad.append(f"step {rr['step']} round total: "
                       f"{rr['t_s']} != {want_t}")
    gate.check("streaming.replay.chunk_billing_law", not bad,
               f"{sum(len(r['chunks']) for r in run['stream_rounds'])} "
               f"chunks re-billed over {len(run['stream_rounds'])} rounds"
               + ("" if not bad else f"; first: {bad[0]}"))


def check_faults_replay(gate: Gate, base: Dict) -> None:
    """Replay the chaos transport's fault decisions: the baseline records
    every faulted round's inputs (step, expected transfer time at the
    then-current belief) and its resolved outcome.  Re-running the same
    committed FaultPlan + RetryPolicy through ``resolve_round`` — the one
    pure law the live ChaosTransport, the fault bench and this gate share
    — must reproduce every recorded (kinds, attempts, retry bill,
    slowdown, crashed set) exactly, floats included, after the JSON
    round-trip.  This pins the whole fault decision path (event schedule
    -> retry/backoff law -> degraded-membership call) deterministically,
    without re-training."""
    from repro.core.faults import FaultEvent, FaultPlan, resolve_round
    from repro.core.wan import RetryPolicy

    scen = base["scenario"]
    plan = FaultPlan(events=tuple(FaultEvent(**e)
                                  for e in scen["fault_events"]),
                     seed=scen["seed"])
    policy = RetryPolicy(**scen["retry_policy"])
    for name, run in base["variants"].items():
        replayed, recorded = [], []
        for o in run["outcomes"]:
            out = resolve_round(plan, policy, o["step"], o["expected_s"])
            replayed.append([o["step"], list(out.kinds), out.attempts,
                             out.extra_s, out.slowdown, list(out.crashed)])
            recorded.append([o["step"], o["kinds"], o["attempts"],
                             o["extra_s"], o["slowdown"], o["crashed"]])
        _check_decisions(gate, f"faults.replay.{name}", replayed, recorded)
    tol, ntl = base["variants"]["tolerant"], base["variants"]["no_tolerance"]
    gate.check("faults.tolerant_reaches_no_tolerance_fails",
               bool(tol["reached_target"]
                    and (ntl["diverged"] or not ntl["reached_target"])),
               f"tolerant t_target {tol['time_to_target_s']}s vs "
               f"no-tolerance reached={ntl['reached_target']} "
               f"diverged={ntl['diverged']}")


def check_serving_replay(gate: Gate, base: Dict) -> None:
    """Replay the serving plane's recorded decision streams: the baseline
    commits the continuous variant's full router event stream (route /
    observe / complete in invocation order) and the autoscaler's windowed
    load observations.  Feeding the events through a fresh ``GeoRouter``
    must reproduce every placement — scores and reason strings included —
    and the load stream through a fresh ``ServingElasticityController``
    must reproduce every scale decision: together they pin the whole
    serving control path (link belief EMA + cliff-snap -> three-term
    score -> placement; windowed rps -> hysteresis scale law)
    deterministically, without re-simulating."""
    from repro.core.control_plane import (CloudEvent,
                                          ServingElasticityController)
    from repro.serving.router import ReplicaSpec, replay_decisions

    scen = base["scenario"]
    specs = [ReplicaSpec(**r) for r in scen["replicas"]]
    replayed = replay_decisions(specs, base["router"]["mode"],
                                base["router"]["events"],
                                **scen["router_knobs"])
    _check_decisions(gate, "serving.replay.router_decisions",
                     replayed, base["router"]["decisions"])
    regions = {s.region for s in specs}
    gate.check("serving.replay.placements_on_known_replicas",
               len(replayed) > 0 and
               all(d["chosen"] in regions for d in replayed),
               f"{len(replayed)} placements over {sorted(regions)}")

    ctrl = ServingElasticityController(**base["autoscaler"]["knobs"])
    scale_replayed = []
    for t, rps in base["autoscaler"]["observations"]:
        d = ctrl.handle(CloudEvent("load_changed", time_s=t, rps=rps))
        scale_replayed.append([t, d.old_replicas, d.new_replicas, d.reason])
    _check_decisions(gate, "serving.replay.autoscaler_decisions",
                     scale_replayed, base["autoscaler"]["decisions"])


def check_migration_replay(gate: Gate, base: Dict) -> None:
    """Replay the live-migration decision stream: rebuild the committed
    scenario's plan, feed the same two events through a fresh
    ``ElasticityController``, and re-derive each migration's bill
    (``ReconfigPlan.migration_bill`` — keep set, barrier-reconcile stall,
    staged snapshot bytes, replaced full pause) — the recomputed stream
    must match the committed one field-for-field.  This pins the whole
    migration cost law (plan diff -> pod transition -> barrier-overlap
    billing) deterministically, without re-running the DES."""
    from benchmarks.elasticity import (MODEL_MB, N_ITERS, NEW_BANDWIDTH,
                                       T_BANDWIDTH, T_LEAVE,
                                       migration_decision, paper_clouds)
    from repro.core.control_plane import (CloudEvent, ElasticityController,
                                          TrainingRequest,
                                          build_training_plan)
    from repro.core.sync import SyncConfig

    scen = base["scenario"]
    plan = build_training_plan(TrainingRequest(
        model="resnet18", clouds=paper_clouds(),
        sync=SyncConfig("asgd_ga", 8), n_iters=N_ITERS,
        global_batch=scen["global_batch"]))
    controller = ElasticityController(plan, ref_bandwidth_mbps=100.0)
    rc_leave = controller.handle(
        CloudEvent("cloud_left", region="chongqing", time_s=T_LEAVE))
    rc_bw = controller.handle(
        CloudEvent("bandwidth_changed", bandwidth_mbps=NEW_BANDWIDTH,
                   time_s=T_BANDWIDTH))
    replayed = [migration_decision(rc_leave, MODEL_MB, 100.0),
                migration_decision(rc_bw, MODEL_MB, NEW_BANDWIDTH)]
    recorded = base["migration"]["decisions"]
    _check_decisions(gate, "elasticity.migration_replay.decisions",
                     replayed, recorded)
    gate.check("elasticity.migration_replay.barrier_overlap_billing",
               all(d["barrier_s"] < d["pause_replaced_s"]
                   for d in replayed),
               "every migration's stall below the full pause it replaced")


# ----------------------------------------------------------- banded checks


def check_elasticity_sim(gate: Gate, base: Dict) -> None:
    from benchmarks.elasticity import bench_elasticity

    fresh = bench_elasticity()
    for key in ("speedup", "cost_reduction", "traffic_reduction"):
        b, f = base[key], fresh[key]
        ok = abs(f - b) <= SIM_TOL * max(abs(b), 1e-9)
        gate.check(f"elasticity.{key}", ok,
                   f"baseline {b} vs fresh {f} (band {SIM_TOL:.0%})")
    gate.check("elasticity.elastic_beats_static", fresh["speedup"] > 1.0,
               f"speedup {fresh['speedup']}")
    gate.check("elasticity.no_pause_in_elastic_run",
               fresh["elastic"]["reconfig_s"]
               <= base["migration"]["pause_replaced_s_total"],
               f"fresh reconfig stall {fresh['elastic']['reconfig_s']}s vs "
               f"replaced pauses {base['migration']['pause_replaced_s_total']}s")


def check_serving_sim(gate: Gate, base: Dict) -> None:
    from benchmarks.serving import bench_serving

    fresh = bench_serving(seed=base["scenario"]["seed"])
    for key in ("throughput_speedup", "p99_improvement"):
        b, f = base[key], fresh[key]
        ok = abs(f - b) <= SIM_TOL * max(abs(b), 1e-9)
        gate.check(f"serving.{key}", ok,
                   f"baseline {b} vs fresh {f} (band {SIM_TOL:.0%})")
    gate.check("serving.continuous_beats_batch",
               fresh["throughput_speedup"] > 1.0
               and fresh["p99_improvement"] > 1.0,
               f"fresh {fresh['throughput_speedup']}x delivered tokens/sec,"
               f" {fresh['p99_improvement']}x p99")


def check_encode_speedup(gate: Gate, base: Dict) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.topk_compress import topk_compress_pallas
    from repro.kernels.wan_codec import k_per_block, wan_encode_pallas

    x = jnp.asarray(np.random.default_rng(0).normal(size=(REDUCED_N,)),
                    jnp.float32)
    k = int(REDUCED_N * 0.01)

    def timeit(fn, reps=1):
        fn()
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / reps

    t_old = timeit(lambda: topk_compress_pallas(x, k, block=1024,
                                                interpret=True))
    kb = k_per_block(4096, 0.01)
    t_new = timeit(lambda: wan_encode_pallas(x, kb, block=4096,
                                             interpret=True))
    speedup = t_old / t_new
    floor = base["encode_kernel"]["encode_speedup"] * TIMING_FLOOR
    gate.check("wan_codec.encode_speedup", speedup >= floor,
               f"re-timed {speedup:.1f}x at n={REDUCED_N} vs floor "
               f"{floor:.1f}x (baseline "
               f"{base['encode_kernel']['encode_speedup']}x at n=2^20)")


# -------------------------------------------------------- acceptance flags


def check_acceptance_flags(gate: Gate, baselines: Dict[str, Dict]) -> None:
    for name, base in baselines.items():
        for flag, ok in base.get("acceptance", {}).items():
            gate.check(f"{name}.acceptance.{flag}", bool(ok),
                       "committed baseline flag")


def main(argv: Sequence[str] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default=None,
                    help="write the check table as JSON here")
    args = ap.parse_args(argv)

    baselines = {
        "wan_codec": _load("BENCH_wan_codec.json"),
        "elasticity": _load("BENCH_elasticity.json"),
        "autotune": _load("BENCH_autotune.json"),
        "faults": _load("BENCH_faults.json"),
        "serving": _load("BENCH_serving.json"),
    }
    gate = Gate()
    check_acceptance_flags(gate, baselines)
    check_payload_math(gate, baselines["wan_codec"])
    check_controller_replay(gate, baselines["autotune"])
    check_measured_replay(gate, baselines["autotune"])
    check_bucketed_replay(gate, baselines["autotune"])
    check_topology_replay(gate, baselines["autotune"])
    check_streaming_replay(gate, baselines["autotune"])
    check_faults_replay(gate, baselines["faults"])
    check_serving_replay(gate, baselines["serving"])
    check_migration_replay(gate, baselines["elasticity"])
    check_elasticity_sim(gate, baselines["elasticity"])
    check_serving_sim(gate, baselines["serving"])
    check_encode_speedup(gate, baselines["wan_codec"])

    n_fail = sum(1 for r in gate.rows if not r["ok"])
    print(f"\n{len(gate.rows)} checks, {n_fail} failed")
    if args.report:
        with open(args.report, "w") as f:
            json.dump({"checks": gate.rows, "failed": n_fail}, f, indent=1)
    return 1 if gate.failed else 0


if __name__ == "__main__":
    sys.exit(main())
