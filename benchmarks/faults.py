"""Fault-tolerance benchmark: chaos-injected WAN sync, tolerant vs
no-tolerance, on the emulated convergence/wall-clock timeline.

The scenario commits a seeded crash-and-flap fault trace against the
2-pod LeNet run the other benches use (same numerics as multi-pod TPU):
failed transfer attempts, a hard timeout, wire corruption, a transient
link flap and finally a pod crash — every fault keyed to a sync step.
Three variants ride the SAME trace:

- ``tolerant`` — ``ChaosTransport(tolerate=True)``: per-chunk checksums
  catch the corruption, failed/timed-out attempts retry under the bounded
  ``RetryPolicy`` (billed at full cost, fed to the measured probe), and
  the crash degrades rounds over the surviving membership.
- ``tolerant_adaptive`` — same, with the ``AdaptiveSyncController``
  closed over the measured probe, locking the guard interplay: degraded
  rounds zero the EF telemetry, so the controller must NOT read a dead
  pod's round as an ef-guard violation (acceptance-flagged).
- ``no_tolerance`` — the baseline the tolerant path is measured against:
  no checksums (the corruption decodes straight into the parameters and
  the run diverges), no degraded rounds (the crashed peer hangs every
  remaining round ``NO_TOLERANCE_HANG`` expected-transfer-times).

Headline acceptance: the tolerant run reaches the target loss; the
no-tolerance baseline does not (diverged or stalled).  Every faulted
round's decision (``resolve_round``) lands in ``BENCH_faults.json`` as a
replayable stream — ``benchmarks/check_regression.py`` re-runs the same
pure law over the recorded inputs and demands exact float equality, the
same discipline as the controller decision replays.

Run:  PYTHONPATH=src python -m benchmarks.faults
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_DIR = os.path.join(HERE, "..", "experiments", "bench")
OUT_PATH = os.path.join(OUT_DIR, "BENCH_faults.json")

MODEL_MB = 44.6           # ResNet18 gradients, paper Table III ballpark
COMPUTE_STEP_S = 0.3      # emulated local compute per step
OVERLAP = 0.55            # async blocking share = 1 - overlap (paper-calib)
STEPS = 220
TARGET_LOSS = 0.01        # 5-step running mean target (from init ~2.38)
EF_GUARD = 0.98
SEED = 0

# calm flat link + zero sim noise: every second on the timeline is either
# honest compute or a fault's bill, so the tolerant-vs-no-tolerance gap is
# exactly what the tolerance machinery buys (and the replay is trivial to
# audit by hand)
LINK_MBPS = 100.0
WAN_KW = dict(fluctuation=0.0, latency_s=0.0, seed=SEED)

SYNC_KW = dict(strategy="asgd_ga", interval=4, compress_topk=0.05,
               quantize_int8=True, error_feedback=True)

# recorded into BENCH_faults.json so check_regression replays EXACTLY this
# retry law (same discipline as the controller knobs in BENCH_autotune)
RETRY_KW = dict(max_retries=3, timeout_factor=4.0, backoff_s=0.5,
                backoff_base=2.0, assume_mbps=LINK_MBPS)

# the committed fault trace — every event lands on a sync step of the
# fixed interval-4 cadence (steps 3, 7, 11, ...):
#   wire corruption EARLY, while the loss is still far from target
#   (checksums catch it — without them it decodes into the parameters
#   long before the baseline could converge), two failed attempts, a
#   hard timeout (6x >= the 4x budget => declared failed + retried), a
#   6-round link flap, a second corruption, and a pod-1 crash that stays
#   down for the rest of the run
FAULT_EVENTS = (
    dict(kind="corrupt", step=23, pod=1),
    dict(kind="fail", step=39, pod=1, attempts=2),
    dict(kind="timeout", step=67, pod=1, factor=6.0),
    dict(kind="flap", step=119, pod=1, factor=8.0, duration=6),
    dict(kind="corrupt", step=151, pod=0),
    dict(kind="crash", step=183, pod=1, mode="degrade"),
)
CRASH_STEP = 183

# adaptive variant: interval pinned at the base cadence so the committed
# fault steps keep landing on sync rounds; the controller still owns the
# codec rung (and must hold it through the degraded tail)
TUNER_KW = dict(ef_guard=EF_GUARD, topk_ladder=(0.05, 0.02, 0.01),
                hysteresis=2, interval_budget=4, max_interval=4)

# empty-plan passthrough check: a short run, bare transport vs the same
# transport chaos-wrapped with NO events — bit-identical or the wrapper
# is not a wrapper
PASSTHROUGH_STEPS = 40


def _plan():
    from repro.core.faults import FaultEvent, FaultPlan

    return FaultPlan(events=tuple(FaultEvent(**e) for e in FAULT_EVENTS),
                     seed=SEED)


def _transport(plan=None, tolerate: bool = True):
    from repro.core.faults import ChaosTransport
    from repro.core.transport import MeasuredWanProbe, SimTransport
    from repro.core.wan import BandwidthTrace, RetryPolicy, WANConfig

    inner = SimTransport(BandwidthTrace((0.0,), (LINK_MBPS,)),
                         WANConfig(bandwidth_mbps=LINK_MBPS, **WAN_KW),
                         probe=MeasuredWanProbe())
    if plan is None:
        return inner
    return ChaosTransport(inner, plan, policy=RetryPolicy(**RETRY_KW),
                          tolerate=tolerate)


def _make_trainer(sync, transport):
    from repro.data.pipeline import GeoDataset, synthetic_classification
    from repro.models.reference import PAPER_MODELS
    from repro.training.trainer import Trainer, TrainerConfig

    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(1500, m["input_shape"], m["n_classes"],
                                    seed=SEED)
    geo = GeoDataset.partition(data, ["sh", "cq"], [2, 1])
    loaders = [geo.loader("sh", 32, seed=0), geo.loader("cq", 32, seed=1)]
    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=sync),
                 transport=transport)
    return tr, loaders


def run_variant(*, tolerate: bool, adaptive: bool = False) -> Dict:
    """One chaos run on the emulated timeline; returns the measured
    trajectory plus the transport's replayable ``resolve_round`` stream."""
    from repro.core.autotune import AdaptiveSyncController, BucketStats
    from repro.core.sync import SyncConfig, is_sync_step
    from repro.training.trainer import stack_pod_batches

    sync = SyncConfig(SYNC_KW["strategy"], SYNC_KW["interval"],
                      compress_topk=SYNC_KW["compress_topk"],
                      quantize_int8=SYNC_KW["quantize_int8"],
                      error_feedback=SYNC_KW["error_feedback"])
    transport = _transport(_plan(), tolerate=tolerate)
    trainer, loaders = _make_trainer(sync, transport)
    state = trainer.init_state(jax.random.key(SEED))
    tuner = (AdaptiveSyncController(
                 sync, MODEL_MB, COMPUTE_STEP_S,
                 probe_est=transport.probe.estimator, **TUNER_KW)
             if adaptive else None)

    sim_t = 0.0
    losses: List[float] = []
    decisions: List[Dict] = []
    traffic_mb = 0.0
    max_ratio = 0.0
    time_to_target: Optional[float] = None
    stats = BucketStats(0.0, 0.0)
    for step in range(STEPS):
        if tuner is not None:
            upd = tuner.update(step, stats)
            if upd is not None:
                trainer, state = trainer.retune(state, upd.sync)
                decisions.append({
                    "step": step, "sim_t": round(sim_t, 2),
                    "rung": upd.rung, "tier": upd.tier,
                    "compress_topk": upd.sync.compress_topk,
                    "interval": upd.sync.interval, "reason": upd.reason})
        state, metrics = trainer.train_step(
            state, stack_pod_batches([next(ld) for ld in loaders]))
        losses.append(float(metrics["loss"]))
        sim_t += COMPUTE_STEP_S
        if is_sync_step(trainer.cfg.sync, step):
            payload = trainer.cfg.sync.payload_mb(MODEL_MB)
            transport.clock_s = sim_t
            transport.begin_round(step)
            prev_retries = transport.retries
            # the real codec ship through the chaos wrapper: injected
            # failures retry (or degrade the round) exactly as in
            # launch.train — then the round is billed at emulated scale
            state = trainer._host_sync(state)
            t = transport.on_sync({"all": payload}, step=step)
            sim_t += t * (1.0 - OVERLAP)
            # retried attempts re-ship the full round payload: bill them
            # at full cost, like the DES link_failed branch does
            traffic_mb += payload * (trainer.cfg.n_pods
                                     + (transport.retries - prev_retries))
            stats = BucketStats.from_sync_state(state.sync_state)
            max_ratio = max(max_ratio, stats.ef_ratio)
        if (time_to_target is None and len(losses) >= 5
                and float(np.mean(losses[-5:])) <= TARGET_LOSS):
            time_to_target = round(sim_t, 2)

    final_loss = float(np.mean(losses[-5:]))
    out = {
        "tolerate": tolerate,
        "time_to_target_s": time_to_target,
        "reached_target": time_to_target is not None,
        "diverged": not bool(np.isfinite(final_loss)),
        "final_loss": (round(final_loss, 6) if np.isfinite(final_loss)
                       else None),
        "total_sim_s": round(sim_t, 2),
        "traffic_mb": round(traffic_mb, 2),
        "max_ef_ratio": round(max_ratio, 6),
        "retries": transport.retries,
        "retried_wire_mb": round(transport.retried_mb, 6),
        "degraded_rounds": transport.degraded_rounds,
        # full precision: check_regression re-runs resolve_round over
        # these recorded inputs and demands exact equality
        "outcomes": transport.outcomes,
    }
    if tuner is not None:
        out.update({
            "n_retunes": len(decisions),
            "decisions": decisions,
            "final_config": {
                "value_dtype": trainer.cfg.sync.value_dtype,
                "compress_topk": trainer.cfg.sync.compress_topk,
                "interval": trainer.cfg.sync.interval},
        })
    return out


def check_passthrough() -> Dict:
    """Empty plan => the wrapper IS the wrapped transport: run the same
    short training twice (bare SimTransport vs chaos-wrapped with no
    events) and demand bit-identical parameters, telemetry, billed
    transfer times and probe belief."""
    from repro.core.faults import FaultPlan
    from repro.core.sync import SyncConfig
    from repro.training.trainer import stack_pod_batches

    def _run(transport):
        sync = SyncConfig(SYNC_KW["strategy"], SYNC_KW["interval"],
                          compress_topk=SYNC_KW["compress_topk"],
                          quantize_int8=SYNC_KW["quantize_int8"],
                          error_feedback=SYNC_KW["error_feedback"])
        trainer, loaders = _make_trainer(sync, transport)
        state = trainer.init_state(jax.random.key(SEED))
        for step in range(PASSTHROUGH_STEPS):
            state, _ = trainer.train_step(
                state, stack_pod_batches([next(ld) for ld in loaders]))
            state = trainer.maybe_sync(state, step, MODEL_MB)
            transport.tick(COMPUTE_STEP_S)
        return state, transport

    sa, ta = _run(_transport())
    sb, tb = _run(_transport(FaultPlan()))   # chaos-wrapped, zero events
    params_equal = all(
        bool(jnp.array_equal(a, b).all())
        for a, b in zip(jax.tree.leaves(sa.params),
                        jax.tree.leaves(sb.params)))
    telemetry_equal = bool(
        jnp.array_equal(sa.sync_state.msg_norm,
                        sb.sync_state.msg_norm).all()
        and jnp.array_equal(sa.sync_state.resid_norm,
                            sb.sync_state.resid_norm).all())
    times_a = [r.seconds for r in ta.records]
    times_b = [r.seconds for r in tb.records]
    belief_a = ta.probe.estimator.bandwidth_mbps
    belief_b = tb.probe.estimator.bandwidth_mbps
    return {
        "steps": PASSTHROUGH_STEPS,
        "params_bit_equal": params_equal,
        "telemetry_bit_equal": telemetry_equal,
        "billed_times_equal": times_a == times_b,
        "probe_belief_equal": belief_a == belief_b,
        "bit_exact": bool(params_equal and telemetry_equal
                          and times_a == times_b and belief_a == belief_b),
    }


def bench_faults() -> Dict:
    report: Dict = {
        "scenario": {
            "model_mb": MODEL_MB, "compute_step_s": COMPUTE_STEP_S,
            "overlap": OVERLAP, "steps": STEPS,
            "target_loss": TARGET_LOSS, "link_mbps": LINK_MBPS,
            "wan": dict(WAN_KW), "sync": dict(SYNC_KW),
            "retry_policy": dict(RETRY_KW),
            "fault_events": [dict(e) for e in FAULT_EVENTS],
            "seed": SEED, "crash_step": CRASH_STEP,
            "tuner": {k: list(v) if isinstance(v, tuple) else v
                      for k, v in TUNER_KW.items()},
        },
        "variants": {
            "tolerant": run_variant(tolerate=True),
            "tolerant_adaptive": run_variant(tolerate=True, adaptive=True),
            "no_tolerance": run_variant(tolerate=False),
        },
        "passthrough": check_passthrough(),
    }
    tol = report["variants"]["tolerant"]
    ada = report["variants"]["tolerant_adaptive"]
    ntl = report["variants"]["no_tolerance"]
    report["tolerant_s"] = tol["time_to_target_s"]
    report["no_tolerance_s"] = ntl["time_to_target_s"]
    report["acceptance"] = {
        # the headline: under the same committed fault trace, tolerance
        # reaches the target; its absence diverges or stalls
        "tolerant_reaches_target": tol["reached_target"],
        "no_tolerance_fails":
            bool(ntl["diverged"] or not ntl["reached_target"]),
        # the machinery was actually exercised, not dodged
        "tolerant_retried_and_degraded":
            bool(tol["retries"] > 0 and tol["degraded_rounds"] > 0),
        "tolerant_never_diverged": not tol["diverged"],
        # guard interplay: degraded rounds zero the EF telemetry, so the
        # controller never reads a dead pod's round as an ef violation
        "no_spurious_ef_deescalation_after_crash":
            not any(d["step"] > CRASH_STEP
                    and d["reason"] in ("ef-guard", "ef-trend")
                    for d in ada.get("decisions", ())),
        "adaptive_ef_guard_never_violated":
            ada["max_ef_ratio"] <= EF_GUARD,
        "adaptive_reaches_target": ada["reached_target"],
        # empty plan == wrapped transport, to the bit
        "empty_plan_bit_exact": report["passthrough"]["bit_exact"],
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    return report


def _print_report(r: Dict) -> None:
    print(f"{'variant':20s} {'t_target_s':>10s} {'final_loss':>10s} "
          f"{'retries':>7s} {'degraded':>8s} {'traffic':>8s}")
    for name, v in r["variants"].items():
        t = v["time_to_target_s"]
        fl = v["final_loss"] if v["final_loss"] is not None else "NaN/inf"
        print(f"{name:20s} {t if t is not None else '--':>10} "
              f"{fl!s:>10} {v['retries']:>7} {v['degraded_rounds']:>8} "
              f"{v['traffic_mb']:>8}")
    ada = r["variants"]["tolerant_adaptive"]
    print(f"adaptive: {ada['n_retunes']} retunes, max_ef "
          f"{ada['max_ef_ratio']}, final {ada['final_config']}")
    print(f"passthrough ({r['passthrough']['steps']} steps): "
          f"bit_exact={r['passthrough']['bit_exact']}")
    print(f"acceptance: {r['acceptance']}")


def main() -> Dict:
    report = bench_faults()                 # writes BENCH_faults.json
    _print_report(report)
    print(f"wrote {os.path.relpath(OUT_PATH, os.path.join(HERE, '..'))}")
    return report


if __name__ == "__main__":
    main()
