"""Render §Dry-run and §Roofline tables into EXPERIMENTS.md from the raw
artifacts (idempotent: replaces the <!-- DRYRUN_TABLE --> and
<!-- ROOFLINE_TABLE --> markers / previously generated blocks)."""
import glob
import json
import os
import re

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.join(HERE, "..")


def dryrun_table() -> str:
    rows = []
    for p in sorted(glob.glob(os.path.join(ROOT, "experiments/dryrun/*.json"))):
        r = json.load(open(p))
        if r.get("tag"):
            continue
        mem = r.get("memory", {})
        arg = mem.get("argument_size_in_bytes", 0) / 2**30
        tmp = mem.get("temp_size_in_bytes", 0) / 2**30
        coll = r.get("collectives", {}).get("total_bytes", 0) / 2**30
        status = r["status"]
        if status == "skipped":
            cell = f"skip: {r['skip_reason'][:48]}"
            rows.append((r["arch"], r["shape"], r["mesh"], status, cell))
        else:
            cell = (f"args {arg:.2f} GiB, temps {tmp:.2f} GiB, "
                    f"coll {coll:.2f} GiB, compile {r.get('compile_s', 0):.0f}s")
            rows.append((r["arch"], r["shape"], r["mesh"], status, cell))
    lines = ["| arch | shape | mesh | status | per-device memory & collectives |",
             "|---|---|---|---|---|"]
    for a, s, m, st, cell in rows:
        lines.append(f"| {a} | {s} | {m} | {st} | {cell} |")
    ok = sum(1 for r in rows if r[3] == "ok")
    sk = sum(1 for r in rows if r[3] == "skipped")
    lines.append(f"\n**{ok} compiled ok, {sk} declared skips, "
                 f"{len(rows) - ok - sk} errors.**")
    return "\n".join(lines)


def roofline_table() -> str:
    import sys
    sys.path.insert(0, ROOT)
    from benchmarks.roofline import load_rows, markdown_table
    rows = load_rows()
    single = markdown_table(rows, "single_pod")
    multi = markdown_table(rows, "multi_pod")
    return ("### Single-pod (16x16 = 256 chips)\n\n" + single +
            "\n\n### Multi-pod (2x16x16 = 512 chips) — proves the pod axis "
            "shards\n\n" + multi)


def inject(md: str, marker: str, content: str) -> str:
    block = f"<!-- {marker} -->\n{content}\n<!-- /{marker} -->"
    if f"<!-- /{marker} -->" in md:
        return re.sub(
            rf"<!-- {marker} -->.*?<!-- /{marker} -->", block, md,
            flags=re.S)
    return md.replace(f"<!-- {marker} -->", block)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    md = open(path).read()
    md = inject(md, "DRYRUN_TABLE", dryrun_table())
    md = inject(md, "ROOFLINE_TABLE", roofline_table())
    open(path, "w").write(md)
    main_perf()
    print("EXPERIMENTS.md tables regenerated")


def _terms(path):
    import sys
    sys.path.insert(0, ROOT)
    from benchmarks.roofline import analyze_record
    r = json.load(open(path))
    row = analyze_record(r)
    if row is None:
        return None
    return row


def perf_table(arch, shape, tags, mesh="single_pod"):
    lines = ["| variant | compute s | memory s | collective s | dominant | 6ND/HLO |",
             "|---|---|---|---|---|---|"]
    for tag in tags:
        suffix = f"__{tag}" if tag else ""
        p = os.path.join(ROOT, f"experiments/dryrun/{arch}__{shape}__{mesh}{suffix}.json")
        if not os.path.exists(p):
            continue
        row = _terms(p)
        if row is None:
            lines.append(f"| {tag or 'baseline'} | - | - | - | error | - |")
            continue
        lines.append(
            f"| {tag or 'baseline'} | {row.compute_s:.2f} | {row.memory_s:.2f} "
            f"| {row.collective_s:.2f} | {row.dominant} | {row.useful_ratio:.2f} |")
    return "\n".join(lines)


def sync_table():
    p = os.path.join(ROOT, "experiments/bench/sync_sweep_qwen3-moe-30b-a3b.json")
    if not os.path.exists(p):
        return "(pending)"
    d = json.load(open(p))
    lines = ["| strategy | train-step collectives/dev | sync round/dev | amortized sync B/dev/step |",
             "|---|---|---|---|"]
    for tag, v in d.items():
        if v.get("status") != "ok":
            lines.append(f"| {tag} | error: {v.get('error','')[:60]} | | |")
            continue
        lines.append(
            f"| {tag} | {v['train_step_collective_B_per_dev']/2**30:.2f} GiB "
            f"| {v['sync_round_B_per_dev']/2**20:.1f} MiB "
            f"| {v['amortized_sync_B_per_dev_step']/2**20:.2f} MiB |")
    return "\n".join(lines)


def main_perf():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    md = open(path).read()
    md = inject(md, "PERF_KIMI_TABLE", perf_table(
        "kimi-k2-1t-a32b", "train_4k",
        ["", "grouped", "grouped_ff"]))
    md = inject(md, "PERF_GEMMA3_TABLE", perf_table(
        "gemma3-12b", "train_4k",
        ["", "chunked", "onehot", "both", "dots", "chunked_dots", "best"]))
    md = inject(md, "PERF_SYNC_TABLE", sync_table())
    open(path, "w").write(md)
    print("perf tables injected")


if __name__ == "__main__":
    main()
