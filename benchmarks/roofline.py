"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

For each (arch x shape x mesh) record produced by ``repro.launch.dryrun``:

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)      [per chip == global/global]
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 50 GB/s)

FLOPs/bytes come from the *extrapolated* costs (XLA-CPU cost_analysis counts
scan bodies once; the dry-run compiles unrolled 1-/2-group variants and
extrapolates — see dryrun._extrapolate_costs).  All extrapolated quantities
are per-chip (cost_analysis runs on the partitioned module).

MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (prefill) /
2·N_active·batch (decode); the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat/redundancy waste (attention FLOPs are excluded from MODEL_FLOPS by
convention, so ratios < 1 are expected; << 1 flags waste).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
INTER_POD_BW = 12.5e9

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN_DIR = os.path.join(HERE, "..", "experiments", "dryrun")
OUT_PATH = os.path.join(HERE, "..", "experiments", "roofline.json")


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_chip: float
    hlo_flops_per_chip: float
    useful_ratio: float
    bound_fraction: float          # dominant term / sum of terms
    cross_pod_s: Optional[float]
    advice: str

    @property
    def step_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def _model_flops(rec: Dict) -> float:
    n = rec["active_params"]
    tokens = rec.get("tokens", 0)
    kind = {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[rec["shape"]]
    per = {"train": 6, "prefill": 2, "decode": 2}[kind]
    return per * n * tokens


def _advice(dom: str, kinds: Dict[str, float], rec: Dict) -> str:
    if dom == "collective":
        top = max(kinds, key=kinds.get) if any(kinds.values()) else "?"
        hints = {
            "all-gather": "FSDP param all-gathers dominate — raise per-chip "
                          "batch (amortize) or move params to model-axis "
                          "sharding / cache gathered params across microbatch",
            "all-reduce": "gradient/logit all-reduces dominate — "
                          "reduce-scatter + ZeRO grads, or sync less often "
                          "(the paper's ASGD-GA/MA on the pod axis)",
            "all-to-all": "MoE dispatch all-to-all dominates — lower "
                          "capacity_factor, widen expert-parallel groups",
            "collective-permute": "ring sends dominate — batch the ring "
                                  "payload or compress (topk_compress)",
        }
        return hints.get(top, "rebalance sharding")
    if dom == "memory":
        return ("HBM-bound — bf16 logits, flash-attention tiling instead of "
                "S^2 buffers, fewer remat passes")
    return "MXU-bound — good; raise arithmetic intensity only via dtype/fusion"


def analyze_record(rec: Dict) -> Optional[RooflineRow]:
    if rec.get("status") != "ok" or "extrapolated" not in rec:
        return None
    ex = rec["extrapolated"]
    chips = rec["mesh_info"]["n_devices"]
    compute_s = ex["flops"] / PEAK_FLOPS
    memory_s = ex["bytes"] / HBM_BW
    collective_s = ex["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dom = max(terms, key=terms.get)
    model_fl = _model_flops(rec) / chips
    hlo_fl = ex["flops"]
    cross = (ex.get("cross_pod_bytes", 0.0) / INTER_POD_BW
             if rec["mesh_info"].get("n_pods", 1) > 1 else None)
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dom,
        model_flops_per_chip=model_fl,
        hlo_flops_per_chip=hlo_fl,
        useful_ratio=(model_fl / hlo_fl if hlo_fl else 0.0),
        bound_fraction=terms[dom] / max(sum(terms.values()), 1e-30),
        cross_pod_s=cross,
        advice=_advice(dom, ex.get("bytes_by_kind", {}), rec),
    )


def load_rows(dryrun_dir: str = DRYRUN_DIR) -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag"):
            continue   # hillclimb variants analyzed separately
        row = analyze_record(rec)
        if row is not None:
            rows.append(row)
    return rows


def markdown_table(rows: List[RooflineRow], mesh: str = "single_pod") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "6ND/HLO | advice |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.mesh != mesh:
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.3e} | {r.memory_s:.3e} "
            f"| {r.collective_s:.3e} | **{r.dominant}** "
            f"| {r.useful_ratio:.2f} | {r.advice} |")
    return "\n".join(lines)


def main():
    rows = load_rows()
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump([asdict(r) for r in rows], f, indent=1)
    print(markdown_table(rows))
    print(f"\n{len(rows)} rows -> {os.path.relpath(OUT_PATH)}")
    # the three hillclimb picks
    single = [r for r in rows if r.mesh == "single_pod"]
    if single:
        worst = min(single, key=lambda r: r.useful_ratio)
        coll = max(single, key=lambda r: r.collective_s)
        print(f"\nworst useful-ratio: {worst.arch} {worst.shape} "
              f"({worst.useful_ratio:.2f})")
        print(f"most collective-bound: {coll.arch} {coll.shape} "
              f"({coll.collective_s:.3e}s)")


if __name__ == "__main__":
    main()
