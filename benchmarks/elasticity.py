"""Elasticity-engine benchmark: elastic re-plan vs static plan under churn.

Scenario (paper §III.B made mid-training): three cloud regions train with the
Algorithm-1 plan when (1) a region departs and (2) WAN bandwidth collapses.

- **static** — no runtime control plane.  The departed region's batch shard
  is absorbed wholesale by its ring predecessor (no re-split is possible
  without a scheduler), allocations stay as planned at launch, and the sync
  interval never adapts to the bandwidth drop.
- **elastic** — the ``ElasticityController`` consumes both events, re-runs
  Algorithm 1 incrementally, re-splits the global batch across the survivors
  and scales the sync interval with the bandwidth; each reconfiguration is
  applied as a *live migration* (the async snapshot engine's path): the
  departing/joining pod state stages from the last durable snapshot while
  surviving pods keep stepping, so the only stall charged is the
  barrier-aligned reconcile (``ReconfigPlan.migration_bill`` — at most one
  sync round) and the staged snapshot bytes bill as overlapped background
  traffic.  The legacy full-pause cost (``reconfig_pause_s``) is recorded
  alongside each migration decision as ``pause_replaced_s`` for the
  before/after accounting.

Both timelines run on the same discrete-event WAN simulator with the same
seed; the report prints the comparison and writes
``experiments/bench/BENCH_elasticity.json``.

Run:  PYTHONPATH=src python -m benchmarks.elasticity
      PYTHONPATH=src python -m benchmarks.elasticity --compare A.json B.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Sequence

from repro.core.control_plane import (CloudEvent, ElasticityController,
                                      TrainingPlan, TrainingRequest,
                                      build_training_plan)
from repro.core.scheduler import CloudResources, load_power
from repro.core.sync import SyncConfig
from repro.core.wan import SimCloud, SimEvent, WANConfig, simulate

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_DIR = os.path.join(HERE, "..", "experiments", "bench")
OUT_PATH = os.path.join(OUT_DIR, "BENCH_elasticity.json")

# per-unit-of-batch-per-unit-of-power iteration time (calibrated so the
# straggler region lands near the paper's ~0.5 s ResNet iteration)
KAPPA = 0.05
MODEL_MB = 44.6          # ResNet18 gradient size, paper Table III ballpark
N_ITERS = 600
T_LEAVE = 100.0          # chongqing departs
T_BANDWIDTH = 200.0      # WAN drops 100 -> 25 Mbps
NEW_BANDWIDTH = 25.0


def paper_clouds() -> tuple:
    return (CloudResources("shanghai", (("cascade", 6),), data_size=2.0),
            CloudResources("chongqing", (("sky", 6),), data_size=1.0),
            CloudResources("beijing", (("sky", 3),), data_size=1.0))


def sim_clouds(plan: TrainingPlan) -> List[SimCloud]:
    """Map a control-plane plan onto simulator clouds: iteration time grows
    with the batch shard and shrinks with the allocated computing power."""
    out = []
    for p, b in zip(plan.resource_plans, plan.batch_split):
        power = load_power(p.allocation, 1.0)
        out.append(SimCloud(region=p.region, iter_time_s=KAPPA * b / power,
                            units=p.units))
    return out


def reconfig_pause_s(model_mb: float, bandwidth_mbps: float,
                     replan_s: float = 5.0) -> float:
    """Checkpointed pod re-stack (save + restore over the WAN) + re-plan —
    the legacy full-pause billing a live migration replaces.  Kept as the
    recorded ``pause_replaced_s`` comparison term."""
    return 2.0 * model_mb * 8.0 / bandwidth_mbps + replan_s


def migration_decision(rc, model_mb: float, bandwidth_mbps: float) -> Dict:
    """One entry of the recorded migration decision stream: the plan diff,
    the live-migration bill (barrier-overlap cost), and the full pause it
    replaced.  ``check_regression`` replays this stream exactly."""
    keep, n_new = rc.pod_transition()
    bill = rc.migration_bill(model_mb, bandwidth_mbps)
    return {
        "event": rc.event.kind,
        "diff": rc.diff.summary(),
        "keep": list(keep),
        "n_new": n_new,
        "bandwidth_mbps": bandwidth_mbps,
        "barrier_s": round(bill["barrier_s"], 4),
        "migrate_mb": round(bill["migrate_mb"], 4),
        "pause_replaced_s": round(
            reconfig_pause_s(model_mb, bandwidth_mbps), 4),
    }


def _accounting(result) -> Dict:
    return {
        "makespan_s": round(result.makespan_s, 1),
        "total_cost": round(result.total_cost, 4),
        "total_traffic_mb": round(result.total_traffic_mb, 1),
        "wait_s": round(sum(c.wait_s for c in result.clouds), 1),
        "reconfig_s": round(sum(c.reconfig_s for c in result.clouds), 1),
        "n_reconfigs": result.n_reconfigs,
        "final_interval": result.sync_cfg.interval,
        "per_region": {c.region: {"total_s": round(c.total_s, 1),
                                  "wait_s": round(c.wait_s, 1),
                                  "cost": round(c.cost, 4)}
                       for c in result.clouds},
    }


def bench_elasticity(seed: int = 0) -> Dict:
    clouds = paper_clouds()
    request = TrainingRequest(model="resnet18", clouds=clouds,
                              sync=SyncConfig("asgd_ga", 8),
                              n_iters=N_ITERS, global_batch=96)
    plan = build_training_plan(request)
    sims = sim_clouds(plan)
    wan = WANConfig(bandwidth_mbps=100.0, seed=seed)
    by_region = {s.region: s for s in sims}
    split = dict(zip((p.region for p in plan.resource_plans),
                     plan.batch_split))

    # ---- static timeline: predecessor absorbs the dead region's shard,
    # interval stays fixed
    ring = dict((plan.resource_plans[b].region, plan.resource_plans[a].region)
                for a, b in plan.topology)          # receiver -> sender
    absorber = ring["chongqing"]
    absorb_factor = (split[absorber] + split["chongqing"]) / split[absorber]
    static_events = [
        SimEvent(T_LEAVE, "cloud_left", region="chongqing"),
        SimEvent(T_LEAVE, "slowdown", region=absorber, factor=absorb_factor),
        SimEvent(T_BANDWIDTH, "bandwidth_changed",
                 bandwidth_mbps=NEW_BANDWIDTH),
    ]
    static = simulate(sims, request.sync, n_iters=N_ITERS, model_mb=MODEL_MB,
                      wan=wan, events=static_events)

    # ---- elastic timeline: the controller replans after each event
    controller = ElasticityController(plan, ref_bandwidth_mbps=100.0)
    rc_leave = controller.handle(
        CloudEvent("cloud_left", region="chongqing", time_s=T_LEAVE))
    rc_bw = controller.handle(
        CloudEvent("bandwidth_changed", bandwidth_mbps=NEW_BANDWIDTH,
                   time_s=T_BANDWIDTH))
    migrations = [migration_decision(rc_leave, MODEL_MB, 100.0),
                  migration_decision(rc_bw, MODEL_MB, NEW_BANDWIDTH)]
    elastic_events = [
        SimEvent(T_LEAVE, "reconfig", clouds=sim_clouds(rc_leave.new),
                 sync=rc_leave.new.request.sync, migration=True,
                 barrier_s=migrations[0]["barrier_s"],
                 migrate_mb=migrations[0]["migrate_mb"],
                 pause_s=migrations[0]["pause_replaced_s"]),
        SimEvent(T_BANDWIDTH, "bandwidth_changed",
                 bandwidth_mbps=NEW_BANDWIDTH),
        SimEvent(T_BANDWIDTH, "reconfig", clouds=sim_clouds(rc_bw.new),
                 sync=rc_bw.new.request.sync, migration=True,
                 barrier_s=migrations[1]["barrier_s"],
                 migrate_mb=migrations[1]["migrate_mb"],
                 pause_s=migrations[1]["pause_replaced_s"]),
    ]
    elastic = simulate(sims, request.sync, n_iters=N_ITERS,
                       model_mb=MODEL_MB, wan=wan, events=elastic_events)

    result = {
        "scenario": {
            "clouds": {c.region: dict(c.devices) for c in clouds},
            "global_batch": request.global_batch,
            "sync": "asgd_ga@8",
            "n_iters": N_ITERS,
            "model_mb": MODEL_MB,
            "events": [f"cloud_left:chongqing@{T_LEAVE:.0f}s",
                       f"bandwidth:100->{NEW_BANDWIDTH:.0f}Mbps"
                       f"@{T_BANDWIDTH:.0f}s"],
            "static_absorber": absorber,
            "elastic_diffs": [rc_leave.diff.summary(), rc_bw.diff.summary()],
            "elastic_batch_split": list(rc_bw.new.batch_split),
        },
        "static": _accounting(static),
        "elastic": _accounting(elastic),
        "migration": {
            "enabled": True,
            "decisions": migrations,
            "pause_replaced_s_total": round(
                sum(m["pause_replaced_s"] for m in migrations), 2),
        },
        "speedup": round(static.makespan_s / elastic.makespan_s, 3),
        "cost_reduction": round(1.0 - elastic.total_cost / static.total_cost,
                                3),
        "traffic_reduction": round(
            1.0 - elastic.total_traffic_mb / static.total_traffic_mb, 3),
        "acceptance": {
            "elastic_beats_static":
                bool(static.makespan_s > elastic.makespan_s),
            # every migration's stall is at most one sync-payload transfer
            # at the bandwidth in effect — "one sync barrier, not a pause"
            "reconfig_within_one_barrier": bool(all(
                m["barrier_s"] <= MODEL_MB * 8.0 / m["bandwidth_mbps"] + 1e-9
                for m in migrations)),
            # the elastic run's total reconfig stall (summed over every
            # region) sits below even a single region's worth of the
            # full pauses it replaced
            "pause_eliminated": bool(
                sum(c.reconfig_s for c in elastic.clouds)
                < sum(m["pause_replaced_s"] for m in migrations)),
        },
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(result, f, indent=1)
    return result


def print_report(r: Dict) -> None:
    print("=== elasticity: elastic re-plan vs static plan under churn ===")
    for ev in r["scenario"]["events"]:
        print(f"  event: {ev}")
    print(f"  elastic re-plans: {r['scenario']['elastic_diffs']}")
    print(f"  {'':10s} {'makespan':>10s} {'cost':>10s} {'traffic':>10s} "
          f"{'wait':>8s} {'interval':>8s}")
    for label in ("static", "elastic"):
        v = r[label]
        print(f"  {label:10s} {v['makespan_s']:>9.1f}s {v['total_cost']:>10.3f} "
              f"{v['total_traffic_mb']:>8.1f}MB {v['wait_s']:>7.1f}s "
              f"{v['final_interval']:>8d}")
    mig = r.get("migration", {})
    if mig.get("enabled"):
        print(f"  live migration: reconfig stall "
              f"{r['elastic']['reconfig_s']}s total vs "
              f"{mig['pause_replaced_s_total']}s of replaced full pauses")
    print(f"  -> speedup {r['speedup']}x, cost reduction "
          f"{100 * r['cost_reduction']:.1f}%, traffic reduction "
          f"{100 * r['traffic_reduction']:.1f}%")
    print(f"  written: {os.path.relpath(OUT_PATH)}")


def compare(path_a: str, path_b: str) -> None:
    a, b = json.load(open(path_a)), json.load(open(path_b))
    print(f"{'metric':24s} {os.path.basename(path_a):>16s} "
          f"{os.path.basename(path_b):>16s}")
    for key in ("speedup", "cost_reduction", "traffic_reduction"):
        print(f"{key:24s} {a[key]:>16} {b[key]:>16}")
    for label in ("static", "elastic"):
        for key in ("makespan_s", "total_cost", "total_traffic_mb"):
            print(f"{label}.{key:18s} {a[label][key]:>16} {b[label][key]:>16}")


def main(argv: Sequence[str] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"),
                    help="diff two BENCH_elasticity.json files instead")
    args = ap.parse_args(argv)
    if args.compare:
        compare(*args.compare)
        return {}
    r = bench_elasticity(seed=args.seed)
    print_report(r)
    return r


if __name__ == "__main__":
    main()
