"""Adaptive WAN sync autotuner benchmark: adaptive vs best-static codec
config on a fluctuating-bandwidth WAN trace.

The measurement couples two timelines:

- **Convergence** is real: the emulated 2-pod LeNet run from the codec
  benches (same numerics as multi-pod TPU), so compression aggressiveness
  has its true effect on the loss trajectory — an over-compressed run
  needs more steps to a target loss, exactly the failure mode a controller
  must not buy bandwidth with.
- **Wall-clock** is emulated: each step costs ``COMPUTE_STEP_S``; each sync
  round blocks for ``payload * 8 / bw(t) * (1 - overlap)`` at the trace's
  bandwidth (paper-calibrated overlap 0.55; deterministic — the trace IS
  the fluctuation, so regression CI can band-check the numbers).  Payload
  uses the paper's Table III ResNet18 gradient size, scaled by each
  config's ``payload_mb`` math.

Headline metric: **time-to-target-loss** — emulated seconds until the
5-step running-mean loss first reaches the target.  The adaptive controller
must beat the best *static* configuration, with its EF-residual guard never
violated (``max_ef_ratio <= ef_guard`` over the whole run).

A second scenario measures **per-bucket vs single-bucket control** on the
same fluctuating trace: DeepFM (the paper's CTR workload — its embedding
table is ~27% of the payload and norm-class vectors ~2%, so the layer-class
partition has real byte mass to trade) trained once under the single-bucket
``AdaptiveSyncController`` and once under the ``BucketedSyncController``.
Acceptance: the bucketed run reaches the target **no later** at **no more
wire bytes**, with neither run's EF guard violated on any bucket.

The per-sync signal stream (sim time, bandwidth, EF norms — per bucket for
the multi-controller run) and the decision lists land in
``BENCH_autotune.json`` so ``benchmarks/check_regression.py`` can replay
both control laws deterministically without re-training.

Run:  PYTHONPATH=src python -m benchmarks.autotune
      PYTHONPATH=src python -m benchmarks.autotune --compare A.json B.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_DIR = os.path.join(HERE, "..", "experiments", "bench")
OUT_PATH = os.path.join(OUT_DIR, "BENCH_autotune.json")

MODEL_MB = 44.6           # ResNet18 gradients, paper Table III ballpark
COMPUTE_STEP_S = 0.3      # emulated local compute per step
OVERLAP = 0.55            # async blocking share = 1 - overlap (paper-calib)
STEPS = 220
TARGET_LOSS = 0.01        # 5-step running mean target (from init ~2.38)
EF_GUARD = 0.98           # above the bottom rung's intrinsic steady-state
#   ratio (~0.95 at int4@0.01 on this task): a guard below that would pin
#   the controller off its own ladder floor

# the controller's constructor knobs, recorded into BENCH_autotune.json so
# check_regression.py replays EXACTLY this controller (a bench retune that
# forgets to refresh baselines fails the gate loudly, not confusingly)
TUNER_KW = dict(ef_guard=EF_GUARD, topk_ladder=(0.05, 0.02, 0.01),
                hysteresis=2, interval_budget=8, max_interval=12)
BASE_SYNC = dict(strategy="asgd_ga", interval=4, compress_topk=0.05)
SEED = 0

# per-bucket scenario: DeepFM, same trace, same emulated payload scale.
# Both deepfm runs (single AND bucketed) use the same knobs; the wider
# escalate_margin reflects that per-bucket EF ratios are structurally
# higher than the pooled single-bucket ratio (a bucket's own ratio is not
# diluted by easier buckets' energy — on deepfm the dense tower reads
# ~0.96 where the pooled ratio reads ~0.88), so the escalation threshold
# scales accordingly; the hard ef_guard is identical for both.
BUCKETED_MODEL = "deepfm"
BUCKETED_TARGET_LOSS = 0.04      # bce from ~0.69; reached ~step 140
BUCKETED_TUNER_KW = {**TUNER_KW, "escalate_margin": 0.99}
FEATURE_VOCAB = 5400             # Frappe-scale feature space (reference.py)

# the fluctuating link: calm 100 Mbps, a deep 0.5 Mbps trough, partial
# recovery, a second trough — the regime the paper measures ("low bandwidth
# and high fluctuations") where no static config is right twice: fidelity
# tiers die in the troughs, aggressive tiers waste the calm stretches, and
# only spending staleness *when the link demands it* threads both
TRACE_SEGMENTS = ((0.0, 100.0), (12.0, 0.5), (60.0, 60.0),
                  (90.0, 2.0), (130.0, 80.0))

# measured-feedback scenario (the PR-5 transport seam): a SimTransport
# bills each sync round with the simulator's transfer law on the SAME
# trace, and the controller's ONLY bandwidth input is those billed times
# (MeasuredWanProbe -> injected probe_est).  latency 0 keeps achieved ==
# trace bandwidth at calm (a 50 ms latency would dominate the small
# compressed payloads and bias the belief toward single-digit Mbps);
# sigma 0.15 exercises the estimator's smoothing while inflating the
# timeline by ~1% mean — the decision band absorbs it.
MEASURED_WAN = dict(fluctuation=0.15, latency_s=0.0, seed=SEED)
MEASURED_PROBE = dict(alpha=0.5, cliff_snap=4.0)   # MeasuredWanProbe knobs,
#   recorded into the baseline so check_regression replays EXACTLY this
#   probe (same discipline as the controller knobs)
MEASURED_BAND = 0.15   # time-to-target band vs the trace-driven run

# mesh overlap measurement: 4 virtual devices, 8 chunks, a 1 Mbps emulated
# WAN hop sized so per-chunk transfer and per-chunk encode are comparable
# (that is the regime where pipelining pays; see
# MeshTransport.measure_overlap)
MESH_OVERLAP = dict(n_pods=4, n_elems=1 << 21, emulate_mbps=1.0, chunks=8)

# hierarchical-topology scenario (the third actuator): 3 pods, one per
# region, all links calm at 100 Mbps except gz<->sh, which collapses to
# 2 Mbps at t=10s and stays down.  This is the asymmetric regime where the
# shape matters: a 3-region ring crosses EVERY link every round (no
# reordering can dodge the bad one), while a tree re-roots at cq and
# aggregates over the two healthy links — one slow round to discover the
# cliff, then fast forever.  Shipping is bit-exact either way
# (HierarchicalTransport delegates to the inline ring), so the fixed
# ``ring`` and ``tree`` variants — static codec config — share ONE loss
# trajectory step for step (acceptance-flagged), and their time-to-target
# difference is purely what each shape pays the collapsed link.  ``auto``
# is the full composition: the measured-feedback adaptive codec
# controller with a TopologyPlanner wired in as the third actuator,
# starting on the ring and switching shapes from measured link beliefs.
TOPOLOGY_REGIONS = ("sh", "cq", "gz")
TOPOLOGY_CALM_MBPS = 100.0
TOPOLOGY_BAD_LINK = ("gz", "sh")
TOPOLOGY_BAD_SEGMENTS = ((0.0, 100.0), (10.0, 2.0))
TOPOLOGY_PLANNER = dict(hysteresis=2, switch_margin=0.85)   # recorded into
#   the baseline so check_regression replays EXACTLY this planner (same
#   discipline as the controller/probe knobs)

# streaming (chunk-granular) scenario: repeated MID-ROUND cliffs — the
# link collapses between one sync's fold and the next round's transfer,
# i.e. inside the exact window the round-level controllers cannot see
# (they decide at the top of the step from the previous round's
# measurements).  The once-per-round autotuner pays each surprise as one
# full stale transfer at the old tier; the streaming controller reads the
# cliff off the FIRST chunk and re-encodes the round's unsent tail at a
# cheaper rung, so it pays ~one chunk plus a cheap tail.  Calm stretches
# between cliffs let the belief recover (and the round controller
# re-escalate), so every collapse is a fresh surprise for both variants —
# the measured difference is purely the in-flight round's reaction.
STREAM_TRACE_SEGMENTS = ((0.0, 100.0), (6.0, 0.5), (26.0, 100.0),
                         (46.0, 0.5), (66.0, 100.0), (86.0, 0.5),
                         (106.0, 100.0))
STREAM_CHUNKS = 8          # overlap_chunks: first-chunk feedback at 1/8 of
#   the round's payload
STREAM_KNOBS = dict(cliff_ratio=4.0, hysteresis=1)   # recorded into the
#   baseline so check_regression replays EXACTLY this chunk-level law
#   (the --stream-cliff / --stream-hysteresis production defaults)
STREAM_SPEEDUP_MIN = 1.2   # acceptance: streaming >= 1.2x faster to the
#   target loss than the once-per-round autotuner on the same cliffs


def _trace():
    from repro.core.wan import BandwidthTrace

    return BandwidthTrace(times_s=tuple(t for t, _ in TRACE_SEGMENTS),
                          mbps=tuple(b for _, b in TRACE_SEGMENTS))


def _make_trainer(sync, model: str = "lenet", transport=None, stream=None):
    from repro.data.pipeline import GeoDataset, synthetic_classification
    from repro.models.reference import PAPER_MODELS
    from repro.training.trainer import Trainer, TrainerConfig

    m = PAPER_MODELS[model]
    data = synthetic_classification(
        1500, m["input_shape"], m["n_classes"], seed=SEED,
        feature_vocab=FEATURE_VOCAB if model == "deepfm" else None)
    geo = GeoDataset.partition(data, ["sh", "cq"], [2, 1])
    loaders = [geo.loader("sh", 32, seed=0), geo.loader("cq", 32, seed=1)]
    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05, sync=sync),
                 transport=transport, stream=stream)
    return tr, loaders


def run_variant(sync, *, adaptive: bool = False, bucketed: bool = False,
                measured: bool = False,
                model: str = "lenet", target_loss: float = TARGET_LOSS,
                tuner_kw: Optional[Dict] = None) -> Dict:
    """One emulated-timeline training run; returns the measured trajectory.

    ``adaptive=True`` attaches an AdaptiveSyncController that observes the
    trace bandwidth + each sync's EF stats and retunes through
    ``Trainer.retune`` — the exact production path of ``launch.train
    --adaptive-sync``.  ``bucketed=True`` attaches the per-bucket
    BucketedSyncController instead (``--bucket-policy layer-class``) and
    records per-bucket signals/decisions for the replay gate.

    ``measured=True`` (the transport-seam scenario, implies the adaptive
    controller): the SAME fluctuating trace drives a ``SimTransport`` —
    and **nothing else**.  The controller never sees the trace: its only
    bandwidth input is the transport-billed transfer time of each sync
    round, folded through ``MeasuredWanProbe`` into the injected
    ``probe_est`` (the exact production path of ``launch.train
    --transport sim --adaptive-sync``).  Observability is therefore
    sync-cadence-bound — a link crash is discovered by *paying one
    transfer on it* — which is the honest cost of measured feedback that
    the trace-driven variant's every-step probing hides."""
    from repro.core.autotune import (AdaptiveSyncController, BucketStats,
                                     BucketedSyncController,
                                     bucket_stats_from_sync_state)
    from repro.core.sync import bucket_weights_of, is_sync_step
    from repro.core.transport import MeasuredWanProbe, SimTransport
    from repro.core.wan import WANConfig
    from repro.training.trainer import stack_pod_batches

    trace = _trace()
    trainer, loaders = _make_trainer(sync, model=model)
    state = trainer.init_state(jax.random.key(SEED))
    weights = (bucket_weights_of(sync, state.params)
               if sync.bucket_policy != "single" else None)
    tuner = None
    transport = None
    kw = tuner_kw if tuner_kw is not None else TUNER_KW
    if measured:
        transport = SimTransport(
            trace, WANConfig(bandwidth_mbps=trace.mbps[0], **MEASURED_WAN),
            probe=MeasuredWanProbe(**MEASURED_PROBE))
        tuner = AdaptiveSyncController(
            sync, MODEL_MB, COMPUTE_STEP_S,
            probe_est=transport.probe.estimator, **kw)
        # NO observe_wan: the belief starts empty and fills from billed
        # transfers only
    elif bucketed:
        bucket_mb = {n: w * MODEL_MB for n, w in weights.items()}
        tuner = BucketedSyncController(sync, bucket_mb, COMPUTE_STEP_S, **kw)
        tuner.observe_wan(trace.at(0.0))
    elif adaptive:
        tuner = AdaptiveSyncController(sync, MODEL_MB, COMPUTE_STEP_S, **kw)
        tuner.observe_wan(trace.at(0.0))

    sim_t = 0.0
    losses: List[float] = []
    signals: List[list] = []   # [sim_t, bw, <stats...>] per step
    decisions: List[Dict] = []
    traffic_mb = 0.0
    max_ratio = 0.0
    time_to_target: Optional[float] = None
    stats = BucketStats(0.0, 0.0)       # no reading before the first sync
    bstats: Dict[str, BucketStats] = {}
    pending_transfer: Optional[List[float]] = None   # [mb, s] since last step

    for step in range(STEPS):
        # the WAN monitor probes every step (out-of-band, like the bus's
        # bandwidth_changed events) and the controller decides at the TOP
        # of the step — reaction latency must NOT be coupled to the sync
        # cadence, or a crashed link is discovered only by paying one full
        # transfer at the stale config.  (In measured mode there IS no
        # out-of-band monitor: the probe advanced when the last sync's
        # transfer was billed, below.)
        bw = trace.at(sim_t)
        if tuner is not None:
            # full-precision norms, NOT a rounded ratio: the replay gate
            # reconstructs BucketStats from these, and both the
            # "no reading yet" state (msg_norm 0) and the controllers'
            # consume-once staleness check (value equality of consecutive
            # readings) must survive the JSON round trip exactly
            if measured:
                signals.append([round(sim_t, 3), pending_transfer,
                                stats.msg_norm, stats.resid_norm])
                pending_transfer = None
                upd = tuner.update(step, stats)
            elif bucketed:
                tuner.observe_wan(bw)
                signals.append([round(sim_t, 3), bw,
                                {n: [s.msg_norm, s.resid_norm]
                                 for n, s in bstats.items()}])
                upd = tuner.update(step, bstats)
            else:
                tuner.observe_wan(bw)
                signals.append([round(sim_t, 3), bw,
                                stats.msg_norm, stats.resid_norm])
                upd = tuner.update(step, stats)
            if upd is not None:
                trainer, state = trainer.retune(state, upd.sync)
                if bucketed:
                    decisions.append({
                        "step": step, "sim_t": round(sim_t, 2),
                        "rungs": {n: r for n, r, _ in upd.rungs},
                        "tiers": {n: t for n, _, t in upd.rungs},
                        "interval": upd.sync.interval,
                        "reasons": list(upd.reasons)})
                else:
                    decisions.append({
                        "step": step, "sim_t": round(sim_t, 2),
                        "rung": upd.rung, "tier": upd.tier,
                        "value_dtype": upd.sync.value_dtype,
                        "compress_topk": upd.sync.compress_topk,
                        "interval": upd.sync.interval,
                        "reason": upd.reason})

        state, metrics = trainer.train_step(
            state, stack_pod_batches([next(ld) for ld in loaders]))
        losses.append(float(metrics["loss"]))
        sim_t += COMPUTE_STEP_S

        if is_sync_step(trainer.cfg.sync, step):
            payload = trainer.cfg.sync.payload_mb(MODEL_MB,
                                                  bucket_weights=weights)
            if measured:
                # the transport bills this round at its sim clock (the
                # same trace), records the transfer, and feeds the probe —
                # the ONLY bandwidth signal the controller ever gets
                transport.clock_s = sim_t
                t = transport.on_sync({"all": payload}, step=step)
                pending_transfer = [payload, t]
                sim_t += t * (1.0 - OVERLAP)
            else:
                bw = trace.at(sim_t)        # achieved bandwidth this round
                sim_t += payload * 8.0 / bw * (1.0 - OVERLAP)
            traffic_mb += payload * trainer.cfg.n_pods
            state = trainer._sync_step(state)
            stats = BucketStats.from_sync_state(state.sync_state)
            max_ratio = max(max_ratio, stats.ef_ratio)
            if bucketed:
                bstats = bucket_stats_from_sync_state(
                    state.sync_state, trainer.cfg.sync.bucket_names)

        if (time_to_target is None and len(losses) >= 5
                and float(np.mean(losses[-5:])) <= target_loss):
            time_to_target = round(sim_t, 2)

    out = {
        "time_to_target_s": time_to_target,
        "final_loss": round(float(np.mean(losses[-5:])), 6),
        "total_sim_s": round(sim_t, 2),
        "traffic_mb": round(traffic_mb, 2),
        "max_ef_ratio": round(max_ratio, 6),
    }
    if tuner is not None:
        out.update({
            "n_retunes": len(decisions),
            "ef_guard": EF_GUARD,
            "decisions": decisions,
            "signals": signals,
        })
        if bucketed:
            out.update({
                "final_rungs": {n: b.rung for n, b in tuner.buckets.items()},
                "final_config": {
                    n: {"value_dtype": b.cfg.value_dtype,
                        "compress_topk": b.cfg.compress_topk}
                    for n, b in tuner.buckets.items()},
                "final_interval": trainer.cfg.sync.interval,
                "max_ef_ratio_by_bucket": {
                    n: round(r, 6)
                    for n, r in tuner.max_ef_ratio_by_bucket.items()},
            })
        else:
            out.update({
                "final_rung": tuner.rung,
                "final_config": {
                    "value_dtype": trainer.cfg.sync.value_dtype,
                    "compress_topk": trainer.cfg.sync.compress_topk,
                    "interval": trainer.cfg.sync.interval},
            })
    return out


def static_variants() -> Dict[str, "object"]:
    from repro.core.sync import SyncConfig

    base = dict(quantize_int8=True, error_feedback=True)
    return {
        "dense@4": SyncConfig("asgd_ga", 4),
        "int8_topk0.05@4": SyncConfig("asgd_ga", 4, compress_topk=0.05,
                                      **base),
        "fp8_topk0.02@4": SyncConfig("asgd_ga", 4, compress_topk=0.02,
                                     value_dtype="fp8", **base),
        "int4_topk0.01@4": SyncConfig("asgd_ga", 4, compress_topk=0.01,
                                      value_dtype="int4", **base),
    }


def bench_bucketed() -> Dict:
    """Per-bucket vs single-bucket adaptive control, same trace, DeepFM."""
    import jax as _jax
    from repro.core.sync import SyncConfig, bucket_weights_of
    from repro.models.reference import PAPER_MODELS

    base_kw = dict(compress_topk=BASE_SYNC["compress_topk"],
                   quantize_int8=True, error_feedback=True)
    single = SyncConfig(BASE_SYNC["strategy"], BASE_SYNC["interval"],
                        **base_kw)
    multi = SyncConfig(BASE_SYNC["strategy"], BASE_SYNC["interval"],
                       bucket_policy="layer-class", **base_kw)
    p0 = PAPER_MODELS[BUCKETED_MODEL]["init"](_jax.random.key(SEED))
    stacked = _jax.tree.map(lambda x: x[None], p0)
    weights = bucket_weights_of(multi, stacked)
    out = {
        "model": BUCKETED_MODEL,
        "target_loss": BUCKETED_TARGET_LOSS,
        "tuner": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in BUCKETED_TUNER_KW.items()},
        # full precision, NOT rounded: check_regression rebuilds the
        # controller from these, and _fit_interval's ceil / the interval
        # deadband are discontinuous — a rounded weight could replay a
        # different decision stream than the live run produced
        "bucket_mb": {n: w * MODEL_MB for n, w in weights.items()},
        "variants": {
            "single": run_variant(single, adaptive=True,
                                  model=BUCKETED_MODEL,
                                  target_loss=BUCKETED_TARGET_LOSS,
                                  tuner_kw=BUCKETED_TUNER_KW),
            "bucketed": run_variant(multi, bucketed=True,
                                    model=BUCKETED_MODEL,
                                    target_loss=BUCKETED_TARGET_LOSS,
                                    tuner_kw=BUCKETED_TUNER_KW),
        },
    }
    t_single = out["variants"]["single"]["time_to_target_s"]
    t_bucket = out["variants"]["bucketed"]["time_to_target_s"]
    out["single_s"], out["bucketed_s"] = t_single, t_bucket
    out["speedup_vs_single"] = (round(t_single / t_bucket, 3)
                                if t_single and t_bucket else None)
    return out


def run_streaming_variant(streaming: bool) -> Dict:
    """One measured-feedback run on the mid-round-cliff trace.

    Both variants are the SAME measured-feedback adaptive setup as the
    transport-seam scenario — a SimTransport bills every round on the
    cliff trace, and the round-level controller's only bandwidth input is
    the probe belief those billed transfers feed — and the same sync
    config (``overlap_chunks`` set either way, so the chunked codec's
    numerics are shared).  ``streaming=True`` additionally hands the
    trainer the transport and a ``StreamingShipController`` sharing the
    SAME belief, so every sync round runs the chunk-granular protocol
    (``Trainer._stream_sync``): zero-retune rounds are bit-identical to
    the classic path (property-tested), and on a mid-round cliff the
    unsent tail re-encodes at a cheaper rung.  The recorded streams — the
    per-step (billed transfer, EF stats) signals, the per-round chunk
    observation lists and the controller's per-chunk decision dicts — are
    exactly what ``check_regression.check_streaming_replay`` re-runs."""
    from repro.core.autotune import (AdaptiveSyncController, BucketStats,
                                     StreamingShipController)
    from repro.core.sync import SyncConfig, is_sync_step
    from repro.core.transport import MeasuredWanProbe, SimTransport
    from repro.core.wan import BandwidthTrace, WANConfig
    from repro.training.trainer import stack_pod_batches

    trace = BandwidthTrace(times_s=tuple(t for t, _ in STREAM_TRACE_SEGMENTS),
                           mbps=tuple(b for _, b in STREAM_TRACE_SEGMENTS))
    transport = SimTransport(
        trace, WANConfig(bandwidth_mbps=trace.mbps[0], **MEASURED_WAN),
        probe=MeasuredWanProbe(**MEASURED_PROBE))
    sync = SyncConfig(BASE_SYNC["strategy"], BASE_SYNC["interval"],
                      compress_topk=BASE_SYNC["compress_topk"],
                      quantize_int8=True, error_feedback=True,
                      overlap_chunks=STREAM_CHUNKS)
    stream = (StreamingShipController(
                  sync, MODEL_MB, ef_guard=EF_GUARD,
                  probe_est=transport.probe.estimator, **STREAM_KNOBS)
              if streaming else None)
    trainer, loaders = _make_trainer(sync, transport=transport,
                                     stream=stream)
    tuner = AdaptiveSyncController(
        sync, MODEL_MB, COMPUTE_STEP_S,
        probe_est=transport.probe.estimator, **TUNER_KW)
    state = trainer.init_state(jax.random.key(SEED))
    # the trainer ships the REAL (small) model, so the transport bills and
    # the probe observes real-scale transfers; the emulated timeline
    # re-scales those seconds to the paper's ResNet18 payload.  With
    # latency 0 the transfer law is linear in MB, so one dense-size ratio
    # scales every chunk and every round uniformly — and achieved/believed
    # bandwidth (every decision input) is scale-free, so the decision
    # stream is exactly what a 44.6 MB model would have produced
    n_elems = sum(int(np.prod(x.shape[1:]))
                  for x in jax.tree.leaves(state.params))
    em_scale = MODEL_MB / (n_elems * 4 / 1e6)

    sim_t = 0.0
    losses: List[float] = []
    signals: List[list] = []
    decisions: List[Dict] = []
    traffic_mb = 0.0
    max_ratio = 0.0
    time_to_target: Optional[float] = None
    stats = BucketStats(0.0, 0.0)
    pending_transfer: Optional[List[float]] = None
    for step in range(STEPS):
        signals.append([round(sim_t, 3), pending_transfer,
                        stats.msg_norm, stats.resid_norm])
        pending_transfer = None
        upd = tuner.update(step, stats)
        if upd is not None:
            trainer, state = trainer.retune(state, upd.sync)
            decisions.append({
                "step": step, "sim_t": round(sim_t, 2),
                "rung": upd.rung, "tier": upd.tier,
                "value_dtype": upd.sync.value_dtype,
                "compress_topk": upd.sync.compress_topk,
                "interval": upd.sync.interval,
                "reason": upd.reason})
        state, metrics = trainer.train_step(
            state, stack_pod_batches([next(ld) for ld in loaders]))
        losses.append(float(metrics["loss"]))
        sim_t += COMPUTE_STEP_S
        if is_sync_step(trainer.cfg.sync, step):
            transport.clock_s = sim_t
            wire = trainer.wire_mb(state)
            streamed = (trainer._stream_sync(state, step)
                        if streaming else None)
            if streamed is not None:
                state = streamed
                rr = transport.stream_rounds[-1]
                t = rr["t_s"]
                # what the probe observed at the fold: the clean round
                # total, or — after a retune — what actually shipped
                mb_obs = (rr["total_mb"] if not rr["retuned"]
                          else rr["shipped_mb"])
                traffic_mb += rr["shipped_mb"] * em_scale \
                    * trainer.cfg.n_pods
            else:
                state = trainer._sync_step(state)
                t = transport.on_sync(wire, step=step)
                mb_obs = sum(wire.values())
                traffic_mb += mb_obs * em_scale * trainer.cfg.n_pods
            # real-scale observation (exactly what the probe folded —
            # the replay gate re-feeds it verbatim); emulated-scale bill
            pending_transfer = [mb_obs, t]
            sim_t += t * em_scale * (1.0 - OVERLAP)
            stats = BucketStats.from_sync_state(state.sync_state)
            max_ratio = max(max_ratio, stats.ef_ratio)
        if (time_to_target is None and len(losses) >= 5
                and float(np.mean(losses[-5:])) <= TARGET_LOSS):
            time_to_target = round(sim_t, 2)

    out = {
        "time_to_target_s": time_to_target,
        "final_loss": round(float(np.mean(losses[-5:])), 6),
        "total_sim_s": round(sim_t, 2),
        "traffic_mb": round(traffic_mb, 2),
        "max_ef_ratio": round(max_ratio, 6),
        "n_retunes": len(decisions),
        "ef_guard": EF_GUARD,
        "emulation_scale": em_scale,
        "decisions": decisions,
        "signals": signals,
        "final_config": {
            "value_dtype": trainer.cfg.sync.value_dtype,
            "compress_topk": trainer.cfg.sync.compress_topk,
            "interval": trainer.cfg.sync.interval},
    }
    if streaming:
        out.update({
            # full precision everywhere: check_streaming_replay re-bills
            # every chunk (stream_chunk_time over t_round/t_tail) and
            # re-runs the decision law (achieved = mb*8/s vs the
            # estimator belief) float-for-float off these records
            "n_stream_retunes": trainer.stream_retunes,
            "n_stream_rounds": stream.n_rounds,
            "stream_rounds": [
                {**r, "chunks": [list(c) for c in r["chunks"]]}
                for r in transport.stream_rounds],
            "stream_decisions": stream.decisions,
        })
    return out


def bench_streaming() -> Dict:
    """Once-per-round autotuner vs chunk-granular streaming retune on the
    mid-round-cliff trace — the first-chunk-feedback scenario."""
    out: Dict = {
        "trace": [list(seg) for seg in STREAM_TRACE_SEGMENTS],
        "wan": dict(MEASURED_WAN),
        "probe": dict(MEASURED_PROBE),
        "chunks": STREAM_CHUNKS,
        "stream": {**STREAM_KNOBS, "ef_guard": EF_GUARD},
        "speedup_min": STREAM_SPEEDUP_MIN,
        "variants": {
            "round_adaptive": run_streaming_variant(False),
            "streaming": run_streaming_variant(True),
        },
    }
    t_round = out["variants"]["round_adaptive"]["time_to_target_s"]
    t_stream = out["variants"]["streaming"]["time_to_target_s"]
    out["round_adaptive_s"], out["streaming_s"] = t_round, t_stream
    out["speedup_vs_round_adaptive"] = (round(t_round / t_stream, 3)
                                        if t_round and t_stream else None)
    return out


def run_topology_variant(kind: str) -> Dict:
    """One topology-scenario run: 3 pods / 3 regions aggregating through a
    ``HierarchicalTransport`` whose gz<->sh link collapses mid-run.

    ``ring`` / ``tree`` fix the shape AND the codec config for the whole
    run: shipping is bit-exact across shapes, so these two share one loss
    trajectory step for step (an acceptance flag pins it) and their
    time-to-target difference is *purely* what each shape pays the
    collapsed link — the clean ablation.  ``auto`` is the full
    three-actuator composition: the measured-feedback adaptive controller
    (probe fed by billed round times, as in the transport-seam scenario)
    with a ``TopologyPlanner`` wired in (``topology=``), switching shapes
    from the measured link beliefs — the production path of
    ``launch.train --topology auto --adaptive-sync``.  The ``auto`` run
    additionally records the exact interleaved (link observation, planner
    decide) event stream so ``check_regression`` can replay the topology
    control law deterministically."""
    from repro.core.autotune import AdaptiveSyncController, BucketStats
    from repro.core.sync import SyncConfig, is_sync_step
    from repro.core.topology import (HierarchicalTransport, LinkBeliefs,
                                     TopologyPlanner, TopologySpec, link_key)
    from repro.core.transport import MeasuredWanProbe
    from repro.core.wan import BandwidthTrace, WANConfig
    from repro.data.pipeline import GeoDataset, synthetic_classification
    from repro.models.reference import PAPER_MODELS
    from repro.training.trainer import (Trainer, TrainerConfig,
                                        stack_pod_batches)

    events: List[list] = []   # interleaved, in exact occurrence order

    class RecordingBeliefs(LinkBeliefs):
        def observe(self, a, b, mbps):
            events.append(["obs", a, b, float(mbps)])
            super().observe(a, b, mbps)

    class RecordingPlanner(TopologyPlanner):
        def decide(self, step, payload_mb):
            events.append(["decide", step, float(payload_mb)])
            return super().decide(step, payload_mb)

    spec = TopologySpec.from_regions(
        list(TOPOLOGY_REGIONS), kind=("ring" if kind == "auto" else kind))
    # the link beliefs reuse the measured probe's estimator knobs: same
    # cliff-snap scale, per link instead of pooled
    beliefs = RecordingBeliefs(default_mbps=TOPOLOGY_CALM_MBPS,
                               **MEASURED_PROBE)
    transport = HierarchicalTransport(
        spec, BandwidthTrace((0.0,), (TOPOLOGY_CALM_MBPS,)),
        wan=WANConfig(bandwidth_mbps=TOPOLOGY_CALM_MBPS, **MEASURED_WAN),
        link_traces={link_key(*TOPOLOGY_BAD_LINK): BandwidthTrace(
            times_s=tuple(t for t, _ in TOPOLOGY_BAD_SEGMENTS),
            mbps=tuple(b for _, b in TOPOLOGY_BAD_SEGMENTS))},
        probe=MeasuredWanProbe(**MEASURED_PROBE), beliefs=beliefs)
    planner = (RecordingPlanner(transport.spec, beliefs,
                                apply=transport.set_kind, **TOPOLOGY_PLANNER)
               if kind == "auto" else None)

    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(1500, m["input_shape"], m["n_classes"],
                                    seed=SEED)
    geo = GeoDataset.partition(data, list(TOPOLOGY_REGIONS), [1, 1, 1])
    loaders = [geo.loader(r, 32, seed=i)
               for i, r in enumerate(TOPOLOGY_REGIONS)]
    sync = SyncConfig(BASE_SYNC["strategy"], BASE_SYNC["interval"],
                      compress_topk=BASE_SYNC["compress_topk"],
                      quantize_int8=True, error_feedback=True)
    trainer = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                      TrainerConfig(n_pods=len(TOPOLOGY_REGIONS),
                                    optimizer="sgd", lr=0.05, sync=sync),
                      transport=transport)
    tuner = (AdaptiveSyncController(
                 sync, MODEL_MB, COMPUTE_STEP_S,
                 probe_est=transport.probe.estimator, topology=planner,
                 **TUNER_KW)
             if kind == "auto" else None)
    state = trainer.init_state(jax.random.key(SEED))

    sim_t = 0.0
    losses: List[float] = []
    decisions: List[Dict] = []
    traffic_mb = 0.0
    max_ratio = 0.0
    time_to_target: Optional[float] = None
    stats = BucketStats(0.0, 0.0)
    for step in range(STEPS):
        if tuner is not None:
            upd = tuner.update(step, stats)
            if upd is not None:
                trainer, state = trainer.retune(state, upd.sync)
                decisions.append({
                    "step": step, "sim_t": round(sim_t, 2),
                    "rung": upd.rung, "tier": upd.tier,
                    "interval": upd.sync.interval, "reason": upd.reason,
                    "topology": upd.topology})
        state, metrics = trainer.train_step(
            state, stack_pod_batches([next(ld) for ld in loaders]))
        losses.append(float(metrics["loss"]))
        sim_t += COMPUTE_STEP_S
        if is_sync_step(trainer.cfg.sync, step):
            payload = trainer.cfg.sync.payload_mb(MODEL_MB)
            # this round ships under the schedule compiled BEFORE billing
            # (on_sync recompiles at the end) — bill traffic at its count
            legs = transport.wan_transfers_per_round
            transport.clock_s = sim_t
            t = transport.on_sync({"all": payload}, step=step)
            sim_t += t * (1.0 - OVERLAP)
            traffic_mb += payload * legs
            state = trainer._sync_step(state)
            stats = BucketStats.from_sync_state(state.sync_state)
            max_ratio = max(max_ratio, stats.ef_ratio)
        if (time_to_target is None and len(losses) >= 5
                and float(np.mean(losses[-5:])) <= TARGET_LOSS):
            time_to_target = round(sim_t, 2)

    out = {
        "time_to_target_s": time_to_target,
        "final_loss": round(float(np.mean(losses[-5:])), 6),
        "total_sim_s": round(sim_t, 2),
        "traffic_mb": round(traffic_mb, 2),
        "max_ef_ratio": round(max_ratio, 6),
        "n_retunes": len(decisions),
        "decisions": decisions,
        "final_kind": transport.spec.kind,
        "wan_transfers_per_round": transport.wan_transfers_per_round,
        "switches": [list(s) for s in transport.switches],
        "reroutes": [list(r) for r in transport.reroutes],
        "final_beliefs": transport.beliefs.snapshot(),
        "final_config": {
            "value_dtype": trainer.cfg.sync.value_dtype,
            "compress_topk": trainer.cfg.sync.compress_topk,
            "interval": trainer.cfg.sync.interval},
    }
    if planner is not None:
        # full precision (observations AND decide payloads): the replay
        # gate feeds these verbatim into fresh LinkBeliefs/TopologyPlanner
        # and the estimator EMA + estimate comparison are both
        # discontinuous in them
        out["events"] = events
        out["planner_decisions"] = [list(d) for d in planner.decisions]
    return out


def bench_topology() -> Dict:
    """Fixed-ring vs fixed-tree vs planner-driven shape on the collapsing
    asymmetric link — the third-actuator scenario."""
    from repro.core.topology import LinkBeliefs, TopologySpec

    out: Dict = {
        "regions": list(TOPOLOGY_REGIONS),
        "initial_kind": "ring",
        "default_mbps": TOPOLOGY_CALM_MBPS,
        "bad_link": list(TOPOLOGY_BAD_LINK),
        "bad_link_trace": [list(seg) for seg in TOPOLOGY_BAD_SEGMENTS],
        "beliefs": dict(MEASURED_PROBE),
        "planner": dict(TOPOLOGY_PLANNER),
        "wan": dict(MEASURED_WAN),
        "variants": {k: run_topology_variant(k)
                     for k in ("ring", "tree", "auto")},
    }
    # the schedule-shape arithmetic the traffic accounting bills
    # (check_regression recomputes these against a fresh compile)
    spec = TopologySpec.from_regions(list(TOPOLOGY_REGIONS), kind="ring")
    fresh = LinkBeliefs(default_mbps=TOPOLOGY_CALM_MBPS)
    out["wan_transfers"] = {
        k: spec.with_kind(k).compile(fresh).wan_transfers
        for k in ("ring", "tree")}
    for k in ("ring", "tree", "auto"):
        out[f"{k}_s"] = out["variants"][k]["time_to_target_s"]
    out["tree_speedup_vs_ring"] = (
        round(out["ring_s"] / out["tree_s"], 3)
        if out["ring_s"] and out["tree_s"] else None)
    return out


def _mesh_overlap_here() -> Dict:
    """The measurement itself — requires >= 4 devices in THIS process."""
    from repro.core.sync import SyncConfig
    from repro.core.transport import MeshTransport

    cfg = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                     error_feedback=True,
                     overlap_chunks=MESH_OVERLAP["chunks"])
    mesh = MeshTransport(emulate_mbps=MESH_OVERLAP["emulate_mbps"])
    return mesh.measure_overlap(cfg, n_pods=MESH_OVERLAP["n_pods"],
                                n_elems=MESH_OVERLAP["n_elems"], reps=2)


def bench_mesh_overlap() -> Dict:
    """Measured overlap_chunks pipelining on a >= 4-virtual-device mesh.

    Multi-device CPU needs ``XLA_FLAGS=--xla_force_host_platform_device_
    count=4`` *before jax initializes* — and forcing it on the whole bench
    would perturb the training numerics every other scenario's baseline
    was recorded under (multi-device XLA compiles the same program
    slightly differently).  So when this process has one device, the
    measurement runs in a subprocess with the flag set; the rest of the
    bench stays on the single-device numerics CI replays."""
    import jax

    if jax.device_count() >= 4:
        return _mesh_overlap_here()
    import subprocess
    import sys
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(HERE, "..", "src"),
                    os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
    try:
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.autotune", "--mesh-overlap"],
            env=env, cwd=os.path.join(HERE, ".."), capture_output=True,
            text=True, timeout=600, check=True)
        return json.loads(out.stdout.strip().splitlines()[-1])
    except (subprocess.SubprocessError, json.JSONDecodeError, OSError,
            IndexError) as e:   # IndexError: empty stdout on a 0 exit
        return {"skipped": f"4-device subprocess failed: {e}"}


def bench_autotune() -> Dict:
    from repro.core.sync import SyncConfig

    report: Dict = {
        "scenario": {
            "model_mb": MODEL_MB, "compute_step_s": COMPUTE_STEP_S,
            "overlap": OVERLAP, "steps": STEPS,
            "target_loss": TARGET_LOSS, "ef_guard": EF_GUARD,
            "trace": [list(seg) for seg in TRACE_SEGMENTS],
            "tuner": {**{k: list(v) if isinstance(v, tuple) else v
                         for k, v in TUNER_KW.items()},
                      "base_sync": dict(BASE_SYNC)},
        },
        "variants": {},
    }
    for name, sync in static_variants().items():
        report["variants"][name] = run_variant(sync)
    base = SyncConfig(BASE_SYNC["strategy"], BASE_SYNC["interval"],
                      compress_topk=BASE_SYNC["compress_topk"],
                      quantize_int8=True, error_feedback=True)
    report["variants"]["adaptive"] = run_variant(base, adaptive=True)

    statics = {k: v["time_to_target_s"] for k, v in
               report["variants"].items() if k != "adaptive"}
    reached = {k: v for k, v in statics.items() if v is not None}
    best_static = min(reached, key=reached.get) if reached else None
    t_adapt = report["variants"]["adaptive"]["time_to_target_s"]
    report["best_static"] = best_static
    report["best_static_s"] = reached.get(best_static)
    report["adaptive_s"] = t_adapt
    report["speedup_vs_best_static"] = (
        round(reached[best_static] / t_adapt, 3)
        if best_static and t_adapt else None)

    # measured-feedback scenario: same controller knobs, same link, but
    # the ONLY bandwidth input is transport-billed transfer times
    report["measured"] = {
        "wan": dict(MEASURED_WAN),
        "probe": dict(MEASURED_PROBE),
        "band": MEASURED_BAND,
        "variant": run_variant(base, measured=True),
    }
    m = report["measured"]["variant"]
    report["measured"]["trace_adaptive_s"] = t_adapt
    report["measured"]["measured_s"] = m["time_to_target_s"]
    # measured feedback is sync-cadence-bound: a bandwidth cliff is
    # discovered only by PAYING one transfer on it (the trace-driven
    # baseline probes every step and reacts before paying).  The decision
    # band therefore grants exactly that structural cost — one worst-case
    # stale transfer: the base config's payload at the trace's trough —
    # on top of the ordinary percentage band.  Anything beyond it would
    # mean the control law (not the observability) degraded.
    trough = min(bw for _, bw in TRACE_SEGMENTS)
    allowance = base.payload_mb(MODEL_MB) * 8.0 / trough * (1.0 - OVERLAP)
    report["measured"]["stale_transfer_allowance_s"] = round(allowance, 2)
    report["measured"]["bound_s"] = (
        round((1.0 + MEASURED_BAND) * t_adapt + allowance, 2)
        if t_adapt is not None else None)
    report["mesh_overlap"] = bench_mesh_overlap()
    report["streaming"] = bench_streaming()
    report["topology"] = bench_topology()

    report["bucketed"] = bench_bucketed()
    b = report["bucketed"]
    sv, bv = b["variants"]["single"], b["variants"]["bucketed"]
    report["acceptance"] = {
        "adaptive_beats_best_static":
            bool(t_adapt is not None and best_static is not None
                 and t_adapt < reached[best_static]),
        "ef_guard_never_violated":
            report["variants"]["adaptive"]["max_ef_ratio"] <= EF_GUARD,
        "bucketed_time_not_worse":
            bool(b["single_s"] is not None and b["bucketed_s"] is not None
                 and b["bucketed_s"] <= b["single_s"]),
        "bucketed_wire_bytes_not_worse":
            bv["traffic_mb"] <= sv["traffic_mb"],
        "bucketed_ef_guard_never_violated":
            bv["max_ef_ratio"] <= EF_GUARD
            and sv["max_ef_ratio"] <= EF_GUARD,
        # the transport-seam acceptance: measured transfer times alone
        # land the autotuner within the decision band of the trace-driven
        # run on the same fluctuating link, guard clean
        "measured_converges_within_band":
            bool(m["time_to_target_s"] is not None
                 and report["measured"]["bound_s"] is not None
                 and m["time_to_target_s"]
                 <= report["measured"]["bound_s"]),
        "measured_ef_guard_never_violated":
            m["max_ef_ratio"] <= EF_GUARD,
    }
    st = report["streaming"]
    sv = st["variants"]
    report["acceptance"].update({
        # the chunk-granular headline: on cliffs that land mid-round, the
        # streaming retune (first-chunk feedback + tail re-encode) reaches
        # the target loss >= STREAM_SPEEDUP_MIN x sooner than the
        # once-per-round autotuner paying each cliff as one stale transfer
        "streaming_beats_round_adaptive":
            bool(st["speedup_vs_round_adaptive"] is not None
                 and st["speedup_vs_round_adaptive"] >= STREAM_SPEEDUP_MIN),
        # the mechanism actually fired — at least one mid-round retune
        # (and every round ran the streaming protocol, none declined)
        "streaming_retuned_mid_round":
            sv["streaming"]["n_stream_retunes"] >= 1
            and sv["streaming"]["n_stream_rounds"]
            == len(sv["streaming"]["stream_rounds"]),
        # the convergence contract: the EF residual absorbed every
        # mid-round fidelity drop without the guard ever tripping
        "streaming_ef_guard_never_violated":
            sv["streaming"]["max_ef_ratio"] <= EF_GUARD
            and sv["round_adaptive"]["max_ef_ratio"] <= EF_GUARD,
    })
    topo = report["topology"]
    tv = topo["variants"]
    report["acceptance"].update({
        # the third-actuator headline: on the asymmetric collapsing link,
        # the tree's shape (re-rooted around the dead link) reaches the
        # target loss sooner than the flat 3-region ring, which crosses
        # every link every round
        "topology_tree_beats_ring":
            bool(topo["tree_s"] is not None and topo["ring_s"] is not None
                 and topo["tree_s"] < topo["ring_s"]),
        # the planner discovers the same answer from measured beliefs:
        # starts on the ring, ends on the tree, and pays no more than
        # staying on the ring would have
        "topology_auto_switches_to_tree":
            tv["auto"]["final_kind"] == "tree"
            and len(tv["auto"]["switches"]) >= 1,
        "topology_auto_not_worse_than_ring":
            bool(topo["auto_s"] is not None and topo["ring_s"] is not None
                 and topo["auto_s"] <= topo["ring_s"]),
        "topology_ef_guard_never_violated":
            all(v["max_ef_ratio"] <= EF_GUARD for v in tv.values()),
        # the parity guarantee, visible in the bench itself: shape changes
        # billing only, never bytes — the fixed-shape variants (identical
        # static codec config) must end at the exact same loss
        "topology_shapes_share_numerics":
            tv["ring"]["final_loss"] == tv["tree"]["final_loss"],
    })
    if "overlap_speedup" in report["mesh_overlap"]:
        report["acceptance"]["mesh_overlap_speedup_measured"] = \
            report["mesh_overlap"]["overlap_speedup"] > 1.0
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    return report


def _print_report(r: Dict) -> None:
    print(f"{'variant':22s} {'t_target_s':>10s} {'final_loss':>10s} "
          f"{'traffic_mb':>10s}")
    for name, v in r["variants"].items():
        t = v["time_to_target_s"]
        print(f"{name:22s} {t if t is not None else '--':>10} "
              f"{v['final_loss']:>10} {v['traffic_mb']:>10}")
    a = r["variants"]["adaptive"]
    print(f"adaptive: {a['n_retunes']} retunes, max_ef_ratio "
          f"{a['max_ef_ratio']} (guard {a['ef_guard']}), final "
          f"{a['final_config']}")
    print(f"speedup vs best static ({r['best_static']}): "
          f"{r['speedup_vs_best_static']}x")
    m = r["measured"]["variant"]
    print(f"\nmeasured-feedback (no trace wired to the controller): "
          f"t_target {m['time_to_target_s']}s vs trace-driven "
          f"{r['measured']['trace_adaptive_s']}s, bound "
          f"{r['measured']['bound_s']}s (band {r['measured']['band']:.0%} "
          f"+ one stale transfer "
          f"{r['measured']['stale_transfer_allowance_s']}s), "
          f"{m['n_retunes']} retunes, max_ef {m['max_ef_ratio']}, "
          f"final {m['final_config']}")
    mo = r["mesh_overlap"]
    if "overlap_speedup" in mo:
        print(f"mesh overlap ({mo['n_devices']} devices, {mo['chunks']} "
              f"chunks @ {mo['emulate_mbps']} Mbps emulated): "
              f"{mo['overlap_speedup']}x (serial {mo['t_serialized_s']}s "
              f"-> pipelined {mo['t_pipelined_s']}s)")
    else:
        print(f"mesh overlap: {mo['skipped']}")
    st = r["streaming"]
    sv = st["variants"]["streaming"]
    rv = st["variants"]["round_adaptive"]
    print(f"\nstreaming scenario ({st['chunks']} chunks, cliffs "
          f"{[seg for seg in st['trace'] if seg[1] < 10]}):")
    print(f"  round-adaptive t_target {rv['time_to_target_s']}s  traffic "
          f"{rv['traffic_mb']} MB  retunes {rv['n_retunes']}  max_ef "
          f"{rv['max_ef_ratio']}")
    print(f"  streaming      t_target {sv['time_to_target_s']}s  traffic "
          f"{sv['traffic_mb']} MB  retunes {sv['n_retunes']}  max_ef "
          f"{sv['max_ef_ratio']}  mid-round retunes "
          f"{sv['n_stream_retunes']}/{sv['n_stream_rounds']} rounds  "
          f"chunk decisions {len(sv['stream_decisions'])}")
    print(f"  speedup vs once-per-round: {st['speedup_vs_round_adaptive']}x"
          f" (min {st['speedup_min']}x)")
    topo = r["topology"]
    print(f"\ntopology scenario ({'/'.join(topo['regions'])}, "
          f"{topo['bad_link'][0]}<->{topo['bad_link'][1]} collapses "
          f"{topo['bad_link_trace'][0][1]} -> "
          f"{topo['bad_link_trace'][-1][1]} Mbps):")
    for name in ("ring", "tree", "auto"):
        v = topo["variants"][name]
        print(f"  {name:5s} t_target {v['time_to_target_s']}s  traffic "
              f"{v['traffic_mb']} MB  final {v['final_kind']} "
              f"(legs {v['wan_transfers_per_round']})  retunes "
              f"{v['n_retunes']}  max_ef {v['max_ef_ratio']}  "
              f"switches {v['switches']}")
    print(f"  tree speedup vs ring: {topo['tree_speedup_vs_ring']}x")
    b = r["bucketed"]
    print(f"\nbucketed scenario ({b['model']}, target "
          f"{b['target_loss']}): bucket_mb "
          f"{ {n: round(v, 4) for n, v in b['bucket_mb'].items()} }")
    for name in ("single", "bucketed"):
        v = b["variants"][name]
        print(f"  {name:9s} t_target {v['time_to_target_s']}s  traffic "
              f"{v['traffic_mb']} MB  retunes {v['n_retunes']}  "
              f"max_ef {v['max_ef_ratio']}")
    bv = b["variants"]["bucketed"]
    print(f"  bucketed final rungs {bv['final_rungs']}, per-bucket max_ef "
          f"{bv['max_ef_ratio_by_bucket']}")
    print(f"  speedup vs single-bucket: {b['speedup_vs_single']}x")
    print(f"acceptance: {r['acceptance']}")


def _compare(a_path: str, b_path: str) -> None:
    with open(a_path) as f:
        a = json.load(f)
    with open(b_path) as f:
        b = json.load(f)
    print(f"{'metric':38s} {'A':>12s} {'B':>12s}")
    for key in ("best_static_s", "adaptive_s", "speedup_vs_best_static"):
        print(f"{key:38s} {a[key]!s:>12s} {b[key]!s:>12s}")
    for name in a["variants"]:
        ta = a["variants"][name]["time_to_target_s"]
        tb = b["variants"].get(name, {}).get("time_to_target_s")
        print(f"{'t_target[' + name + ']':38s} {ta!s:>12s} {tb!s:>12s}")


def main(argv: Sequence[str] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"),
                    help="diff two BENCH_autotune.json files instead")
    ap.add_argument("--mesh-overlap", action="store_true",
                    help="run ONLY the mesh overlap measurement and print "
                         "its JSON (used by the 4-device subprocess hop)")
    args = ap.parse_args(argv)
    if args.mesh_overlap:
        import jax
        rep = (_mesh_overlap_here() if jax.device_count() >= 4
               else {"skipped": f"needs >= 4 devices, have "
                                f"{jax.device_count()}"})
        print(json.dumps(rep))
        return rep
    if args.compare:
        _compare(*args.compare)
        return {}
    report = bench_autotune()               # writes BENCH_autotune.json
    _print_report(report)
    print(f"wrote {os.path.relpath(OUT_PATH, os.path.join(HERE, '..'))}")
    return report


if __name__ == "__main__":
    main()
