"""Adaptive WAN sync autotuner benchmark: adaptive vs best-static codec
config on a fluctuating-bandwidth WAN trace.

The measurement couples two timelines:

- **Convergence** is real: the emulated 2-pod LeNet run from the codec
  benches (same numerics as multi-pod TPU), so compression aggressiveness
  has its true effect on the loss trajectory — an over-compressed run
  needs more steps to a target loss, exactly the failure mode a controller
  must not buy bandwidth with.
- **Wall-clock** is emulated: each step costs ``COMPUTE_STEP_S``; each sync
  round blocks for ``payload * 8 / bw(t) * (1 - overlap)`` at the trace's
  bandwidth (paper-calibrated overlap 0.55; deterministic — the trace IS
  the fluctuation, so regression CI can band-check the numbers).  Payload
  uses the paper's Table III ResNet18 gradient size, scaled by each
  config's ``payload_mb`` math.

Headline metric: **time-to-target-loss** — emulated seconds until the
5-step running-mean loss first reaches the target.  The adaptive controller
must beat the best *static* configuration, with its EF-residual guard never
violated (``max_ef_ratio <= ef_guard`` over the whole run).

The per-sync signal stream (sim time, bandwidth, EF ratio) and the decision
list land in ``BENCH_autotune.json`` so ``benchmarks/check_regression.py``
can replay the control law deterministically without re-training.

Run:  PYTHONPATH=src python -m benchmarks.autotune
      PYTHONPATH=src python -m benchmarks.autotune --compare A.json B.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_DIR = os.path.join(HERE, "..", "experiments", "bench")
OUT_PATH = os.path.join(OUT_DIR, "BENCH_autotune.json")

MODEL_MB = 44.6           # ResNet18 gradients, paper Table III ballpark
COMPUTE_STEP_S = 0.3      # emulated local compute per step
OVERLAP = 0.55            # async blocking share = 1 - overlap (paper-calib)
STEPS = 220
TARGET_LOSS = 0.01        # 5-step running mean target (from init ~2.38)
EF_GUARD = 0.98           # above the bottom rung's intrinsic steady-state
#   ratio (~0.95 at int4@0.01 on this task): a guard below that would pin
#   the controller off its own ladder floor

# the controller's constructor knobs, recorded into BENCH_autotune.json so
# check_regression.py replays EXACTLY this controller (a bench retune that
# forgets to refresh baselines fails the gate loudly, not confusingly)
TUNER_KW = dict(ef_guard=EF_GUARD, topk_ladder=(0.05, 0.02, 0.01),
                hysteresis=2, interval_budget=8, max_interval=12)
BASE_SYNC = dict(strategy="asgd_ga", interval=4, compress_topk=0.05)
SEED = 0

# the fluctuating link: calm 100 Mbps, a deep 0.5 Mbps trough, partial
# recovery, a second trough — the regime the paper measures ("low bandwidth
# and high fluctuations") where no static config is right twice: fidelity
# tiers die in the troughs, aggressive tiers waste the calm stretches, and
# only spending staleness *when the link demands it* threads both
TRACE_SEGMENTS = ((0.0, 100.0), (12.0, 0.5), (60.0, 60.0),
                  (90.0, 2.0), (130.0, 80.0))


def _trace():
    from repro.core.wan import BandwidthTrace

    return BandwidthTrace(times_s=tuple(t for t, _ in TRACE_SEGMENTS),
                          mbps=tuple(b for _, b in TRACE_SEGMENTS))


def _make_trainer(sync):
    from repro.data.pipeline import GeoDataset, synthetic_classification
    from repro.models.reference import PAPER_MODELS
    from repro.training.trainer import Trainer, TrainerConfig

    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(1500, m["input_shape"], m["n_classes"],
                                    seed=SEED)
    geo = GeoDataset.partition(data, ["sh", "cq"], [2, 1])
    loaders = [geo.loader("sh", 32, seed=0), geo.loader("cq", 32, seed=1)]
    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05, sync=sync))
    return tr, loaders


def run_variant(sync, *, adaptive: bool = False) -> Dict:
    """One emulated-timeline training run; returns the measured trajectory.

    ``adaptive=True`` attaches an AdaptiveSyncController that observes the
    trace bandwidth + each sync's EF stats and retunes through
    ``Trainer.retune`` — the exact production path of ``launch.train
    --adaptive-sync``."""
    from repro.core.autotune import AdaptiveSyncController, BucketStats
    from repro.core.sync import is_sync_step
    from repro.training.trainer import stack_pod_batches

    trace = _trace()
    trainer, loaders = _make_trainer(sync)
    state = trainer.init_state(jax.random.key(SEED))
    tuner = None
    if adaptive:
        tuner = AdaptiveSyncController(sync, MODEL_MB, COMPUTE_STEP_S,
                                       **TUNER_KW)
        tuner.observe_wan(trace.at(0.0))

    sim_t = 0.0
    losses: List[float] = []
    signals: List[List[float]] = []     # [sim_t, bw, ef_ratio] per step
    decisions: List[Dict] = []
    traffic_mb = 0.0
    max_ratio = 0.0
    time_to_target: Optional[float] = None
    stats = BucketStats(0.0, 0.0)       # no reading before the first sync

    for step in range(STEPS):
        # the WAN monitor probes every step (out-of-band, like the bus's
        # bandwidth_changed events) and the controller decides at the TOP
        # of the step — reaction latency must NOT be coupled to the sync
        # cadence, or a crashed link is discovered only by paying one full
        # transfer at the stale config
        bw = trace.at(sim_t)
        if tuner is not None:
            tuner.observe_wan(bw)
            # full-precision norms, NOT a rounded ratio: the replay gate
            # reconstructs BucketStats from these, and both the
            # "no reading yet" state (msg_norm 0) and the controller's
            # consume-once staleness check (value equality of consecutive
            # readings) must survive the JSON round trip exactly
            signals.append([round(sim_t, 3), bw,
                            stats.msg_norm, stats.resid_norm])
            upd = tuner.update(step, stats)
            if upd is not None:
                trainer, state = trainer.retune(state, upd.sync)
                decisions.append({
                    "step": step, "sim_t": round(sim_t, 2),
                    "rung": upd.rung, "tier": upd.tier,
                    "value_dtype": upd.sync.value_dtype,
                    "compress_topk": upd.sync.compress_topk,
                    "interval": upd.sync.interval,
                    "reason": upd.reason})

        state, metrics = trainer.train_step(
            state, stack_pod_batches([next(ld) for ld in loaders]))
        losses.append(float(metrics["loss"]))
        sim_t += COMPUTE_STEP_S

        if is_sync_step(trainer.cfg.sync, step):
            bw = trace.at(sim_t)            # achieved bandwidth this round
            payload = trainer.cfg.sync.payload_mb(MODEL_MB)
            sim_t += payload * 8.0 / bw * (1.0 - OVERLAP)
            traffic_mb += payload * trainer.cfg.n_pods
            state = trainer._sync_step(state)
            stats = BucketStats.from_sync_state(state.sync_state)
            max_ratio = max(max_ratio, stats.ef_ratio)

        if (time_to_target is None and len(losses) >= 5
                and float(np.mean(losses[-5:])) <= TARGET_LOSS):
            time_to_target = round(sim_t, 2)

    out = {
        "time_to_target_s": time_to_target,
        "final_loss": round(float(np.mean(losses[-5:])), 6),
        "total_sim_s": round(sim_t, 2),
        "traffic_mb": round(traffic_mb, 2),
        "max_ef_ratio": round(max_ratio, 6),
    }
    if tuner is not None:
        out.update({
            "n_retunes": len(decisions),
            "ef_guard": EF_GUARD,
            "final_rung": tuner.rung,
            "final_config": {
                "value_dtype": trainer.cfg.sync.value_dtype,
                "compress_topk": trainer.cfg.sync.compress_topk,
                "interval": trainer.cfg.sync.interval},
            "decisions": decisions,
            "signals": signals,
        })
    return out


def static_variants() -> Dict[str, "object"]:
    from repro.core.sync import SyncConfig

    base = dict(quantize_int8=True, error_feedback=True)
    return {
        "dense@4": SyncConfig("asgd_ga", 4),
        "int8_topk0.05@4": SyncConfig("asgd_ga", 4, compress_topk=0.05,
                                      **base),
        "fp8_topk0.02@4": SyncConfig("asgd_ga", 4, compress_topk=0.02,
                                     value_dtype="fp8", **base),
        "int4_topk0.01@4": SyncConfig("asgd_ga", 4, compress_topk=0.01,
                                      value_dtype="int4", **base),
    }


def bench_autotune() -> Dict:
    from repro.core.sync import SyncConfig

    report: Dict = {
        "scenario": {
            "model_mb": MODEL_MB, "compute_step_s": COMPUTE_STEP_S,
            "overlap": OVERLAP, "steps": STEPS,
            "target_loss": TARGET_LOSS, "ef_guard": EF_GUARD,
            "trace": [list(seg) for seg in TRACE_SEGMENTS],
            "tuner": {**{k: list(v) if isinstance(v, tuple) else v
                         for k, v in TUNER_KW.items()},
                      "base_sync": dict(BASE_SYNC)},
        },
        "variants": {},
    }
    for name, sync in static_variants().items():
        report["variants"][name] = run_variant(sync)
    base = SyncConfig(BASE_SYNC["strategy"], BASE_SYNC["interval"],
                      compress_topk=BASE_SYNC["compress_topk"],
                      quantize_int8=True, error_feedback=True)
    report["variants"]["adaptive"] = run_variant(base, adaptive=True)

    statics = {k: v["time_to_target_s"] for k, v in
               report["variants"].items() if k != "adaptive"}
    reached = {k: v for k, v in statics.items() if v is not None}
    best_static = min(reached, key=reached.get) if reached else None
    t_adapt = report["variants"]["adaptive"]["time_to_target_s"]
    report["best_static"] = best_static
    report["best_static_s"] = reached.get(best_static)
    report["adaptive_s"] = t_adapt
    report["speedup_vs_best_static"] = (
        round(reached[best_static] / t_adapt, 3)
        if best_static and t_adapt else None)
    report["acceptance"] = {
        "adaptive_beats_best_static":
            bool(t_adapt is not None and best_static is not None
                 and t_adapt < reached[best_static]),
        "ef_guard_never_violated":
            report["variants"]["adaptive"]["max_ef_ratio"] <= EF_GUARD,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    return report


def _print_report(r: Dict) -> None:
    print(f"{'variant':22s} {'t_target_s':>10s} {'final_loss':>10s} "
          f"{'traffic_mb':>10s}")
    for name, v in r["variants"].items():
        t = v["time_to_target_s"]
        print(f"{name:22s} {t if t is not None else '--':>10} "
              f"{v['final_loss']:>10} {v['traffic_mb']:>10}")
    a = r["variants"]["adaptive"]
    print(f"adaptive: {a['n_retunes']} retunes, max_ef_ratio "
          f"{a['max_ef_ratio']} (guard {a['ef_guard']}), final "
          f"{a['final_config']}")
    print(f"speedup vs best static ({r['best_static']}): "
          f"{r['speedup_vs_best_static']}x")
    print(f"acceptance: {r['acceptance']}")


def _compare(a_path: str, b_path: str) -> None:
    with open(a_path) as f:
        a = json.load(f)
    with open(b_path) as f:
        b = json.load(f)
    print(f"{'metric':38s} {'A':>12s} {'B':>12s}")
    for key in ("best_static_s", "adaptive_s", "speedup_vs_best_static"):
        print(f"{key:38s} {a[key]!s:>12s} {b[key]!s:>12s}")
    for name in a["variants"]:
        ta = a["variants"][name]["time_to_target_s"]
        tb = b["variants"].get(name, {}).get("time_to_target_s")
        print(f"{'t_target[' + name + ']':38s} {ta!s:>12s} {tb!s:>12s}")


def main(argv: Sequence[str] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"),
                    help="diff two BENCH_autotune.json files instead")
    args = ap.parse_args(argv)
    if args.compare:
        _compare(*args.compare)
        return {}
    report = bench_autotune()               # writes BENCH_autotune.json
    _print_report(report)
    print(f"wrote {os.path.relpath(OUT_PATH, os.path.join(HERE, '..'))}")
    return report


if __name__ == "__main__":
    main()
