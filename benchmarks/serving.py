"""Serving-plane benchmark: continuous batching vs run-to-completion under
a bursty multi-region request trace, with geo-aware routing.

Scenario: three regional replicas (the same regions the training benches
churn) serve a seeded 2-minute request trace — a steady trickle plus a
hard burst out of one region — while the us-east<->eu-west link collapses
mid-trace.  Every variant runs the same discrete-event simulator (pure
deterministic arithmetic, no wall clock, no RNG after trace generation):

- **batch** — the run-to-completion baseline: a replica admits a group of
  requests up to its slot capacity, decodes until the *whole group*
  finishes, and only then admits the next group; results are returned at
  group completion (exactly the old ``BatchScheduler`` contract).
- **continuous** — the slot-pool engine: finished requests are evicted
  and new ones inserted at every decode-step boundary (at most one
  prefill per boundary — the decoupled-queue rule), so a long generation
  never holds the pool hostage.

Both variants share the same :class:`~repro.serving.router.GeoRouter`
(measured link beliefs -> placement) and the same autoscaled capacity
trajectory from a :class:`~repro.core.control_plane.
ServingElasticityController` consuming windowed request rates off the
trace, so the comparison isolates the scheduling discipline.

The committed ``BENCH_serving.json`` records the continuous variant's
full router event stream (route / observe / complete in invocation
order) and the autoscaler's observation stream; ``check_regression.py``
replays both through fresh instances and requires decision-for-decision
equality, then re-runs this sim inside the 5% band.

Run:  PYTHONPATH=src python -m benchmarks.serving
      PYTHONPATH=src python -m benchmarks.serving --compare A.json B.json
"""
from __future__ import annotations

import argparse
import heapq
import json
import math
import os
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.control_plane import CloudEvent, ServingElasticityController
from repro.serving.router import GeoRouter, ReplicaSpec, ROUTER_MODES

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_DIR = os.path.join(HERE, "..", "experiments", "bench")
OUT_PATH = os.path.join(OUT_DIR, "BENCH_serving.json")

# --------------------------------------------------------------- scenario

REPLICAS = (
    ReplicaSpec("us-east", device="v5e", units=1, n_slots=4,
                cost_per_unit_hour=3.0),
    ReplicaSpec("eu-west", device="v5e", units=2, n_slots=4,
                cost_per_unit_hour=2.0),
    ReplicaSpec("ap-south", device="v5e", units=1, n_slots=4,
                cost_per_unit_hour=1.0),
)
ROUTER_KNOBS = dict(default_mbps=100.0, alpha=0.5, cliff_snap=4.0,
                    mb_per_token=0.004)
AUTOSCALER_KNOBS = dict(replicas=1, min_replicas=1, max_replicas=2,
                        target_rps_per_replica=2.0, hysteresis=2)

T_TRACE = 120.0            # arrivals span [0, T_TRACE)
BURST = (30.0, 50.0, 8.0)  # (start, end, extra rps) burst out of us-east
BASE_RPS = 1.5             # steady trickle, all regions
T_COLLAPSE = 60.0          # us-east<->eu-west drops ...
COLLAPSE_MBPS = 1.0        # ... from 100 to 1 Mbps
GRACE_S = 15.0             # post-collapse window in which the router is
#   allowed to still pick the dead link: the belief is *measured*, so the
#   first transfer after the collapse must pay it once before cliff-snap
#   reprices the link (same one-payment contract as MeasuredWanProbe)
BURST_WINDOW = (BURST[0], BURST[1] + 15.0)   # saturated window for the
#   delivered-throughput comparison: burst + early drain, closing while
#   the run-to-completion baseline is still backlogged.  Outside a
#   saturated window both variants are arrival-bound and delivered
#   throughput is trivially equal — the win continuous batching buys is
#   exactly the slot-time the baseline wastes while saturated (idle slots
#   held by finished members until their group's longest request ends)
LOAD_WINDOW_S = 10.0       # autoscaler observation window
PREFILL_SPEEDUP = 8.0      # prefill processes tokens ~8x faster than decode
TOKENS_PER_POWER = 0.01    # catalog power -> tokens/sec per slot (a v5e
#   unit's TN power is ~2052, giving ~20 tok/s/slot: calibrated so the
#   burst saturates the pools and the scheduling discipline — not the
#   trace — dominates the comparison)


def make_trace(seed: int = 0) -> List[dict]:
    """Seeded bursty multi-region arrivals, sorted by time."""
    rng = np.random.default_rng(seed)
    regions = [r.region for r in REPLICAS]
    reqs = []
    t = 0.0
    while t < T_TRACE:
        t += float(rng.exponential(1.0 / BASE_RPS))
        if t >= T_TRACE:
            break
        reqs.append((t, regions[int(rng.integers(len(regions)))]))
    t = BURST[0]
    while t < BURST[1]:
        t += float(rng.exponential(1.0 / BURST[2]))
        if t >= BURST[1]:
            break
        reqs.append((t, "us-east"))
    reqs.sort()
    return [{"rid": i, "t": round(t, 6), "src": src,
             "prompt_len": int(rng.integers(16, 129)),
             "max_new": int(rng.integers(16, 257))}
            for i, (t, src) in enumerate(reqs)]


def true_mbps(a: str, b: str, t: float) -> float:
    """Ground-truth link bandwidth the transfers actually experience."""
    pair = tuple(sorted((a, b)))
    if pair == ("eu-west", "us-east") and t >= T_COLLAPSE:
        return COLLAPSE_MBPS
    return 100.0


def capacity_steps(trace: Sequence[dict]
                   ) -> Tuple[List[Tuple[float, int]], dict]:
    """Run the ServingElasticityController on windowed request rates.

    Returns the per-region pool-multiplier step function
    ``[(t_effective, replicas), ...]`` and the recorded
    observation/decision streams for the baseline JSON."""
    ctrl = ServingElasticityController(**AUTOSCALER_KNOBS)
    steps = [(0.0, ctrl.replicas)]
    observations, decisions = [], []
    n_windows = int(math.ceil(T_TRACE / LOAD_WINDOW_S))
    for w in range(n_windows):
        t0, t1 = w * LOAD_WINDOW_S, (w + 1) * LOAD_WINDOW_S
        rps = sum(1 for r in trace if t0 <= r["t"] < t1) / LOAD_WINDOW_S
        d = ctrl.handle(CloudEvent("load_changed", time_s=t1, rps=rps))
        observations.append([round(t1, 6), round(rps, 6)])
        decisions.append([round(t1, 6), d.old_replicas, d.new_replicas,
                          d.reason])
        if not d.is_noop:
            steps.append((t1, d.new_replicas))
    return steps, {"knobs": dict(AUTOSCALER_KNOBS),
                   "observations": observations, "decisions": decisions}


def _capacity(steps: Sequence[Tuple[float, int]], spec: ReplicaSpec,
              t: float) -> int:
    mult = steps[0][1]
    for t_eff, m in steps:
        if t_eff <= t:
            mult = m
    return mult * spec.n_slots


# ------------------------------------------------------------- simulator


class _Pool:
    """One region's serving pool in the discrete-event sim."""

    def __init__(self, spec: ReplicaSpec):
        self.spec = spec
        self.step_s = 1.0 / (spec.service_rate * TOKENS_PER_POWER)
        self.queue: deque = deque()
        self.live: Dict[int, int] = {}        # rid -> tokens remaining
        self.group: List[int] = []            # batch variant: current group
        self.busy = False


def simulate_serving(trace: Sequence[dict], mode: str, scheduler: str,
                     steps: Sequence[Tuple[float, int]]
                     ) -> Tuple[dict, List[dict], GeoRouter]:
    """Drive one variant through the trace; returns (metrics, the router
    event stream in invocation order, the router)."""
    router = GeoRouter(REPLICAS, mode=mode, **ROUTER_KNOBS)
    pools = {r.region: _Pool(r) for r in REPLICAS}
    by_rid = {r["rid"]: r for r in trace}
    placed: Dict[int, str] = {}
    events: List[dict] = []
    done: Dict[int, float] = {}
    heap: List[tuple] = []
    seq = 0

    def push(t: float, kind: str, data) -> None:
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, data))
        seq += 1

    def complete(rid: int, t: float) -> None:
        events.append({"op": "complete", "rid": rid})
        router.complete(rid)
        done[rid] = t

    for r in trace:
        push(r["t"], "arrive", r["rid"])

    while heap:
        t, _, kind, data = heapq.heappop(heap)
        if kind == "arrive":
            r = by_rid[data]
            events.append({"op": "route", "rid": r["rid"], "src": r["src"],
                           "prompt_len": r["prompt_len"],
                           "max_new": r["max_new"]})
            dst = router.route(r["rid"], r["src"], r["prompt_len"],
                               r["max_new"])
            placed[r["rid"]] = dst
            wire_mb = (r["prompt_len"] + r["max_new"]) * \
                ROUTER_KNOBS["mb_per_token"]
            if r["src"] == dst:
                push(t, "enqueue", r["rid"])
            else:
                transfer_s = wire_mb * 8.0 / true_mbps(r["src"], dst, t)
                push(t + transfer_s, "enqueue",
                     (r["rid"], r["src"], dst, wire_mb, transfer_s))
        elif kind == "enqueue":
            if isinstance(data, tuple):        # cross-region: bill the link
                rid, src, dst, wire_mb, transfer_s = data
                events.append({"op": "observe", "a": src, "b": dst,
                               "payload_mb": round(wire_mb, 9),
                               "seconds": round(transfer_s, 9)})
                router.observe_transfer(src, dst, round(wire_mb, 9),
                                        round(transfer_s, 9))
            else:
                rid = data
            pool = pools[placed[rid]]
            pool.queue.append(rid)
            if not pool.busy:
                pool.busy = True
                push(t, "tick", placed[rid])
        elif kind == "tick":
            pool = pools[data]
            cap = _capacity(steps, pool.spec, t)
            if scheduler == "continuous":
                for rid in [i for i, rem in pool.live.items() if rem <= 0]:
                    del pool.live[rid]
                    complete(rid, t)
                if pool.queue and len(pool.live) < cap:
                    rid = pool.queue.popleft()   # one prefill per boundary
                    pool.live[rid] = by_rid[rid]["max_new"]
                    prefill_s = by_rid[rid]["prompt_len"] * pool.step_s \
                        / PREFILL_SPEEDUP
                    push(t + prefill_s, "tick", data)
                elif pool.live:
                    for rid in pool.live:
                        pool.live[rid] -= 1
                    push(t + pool.step_s, "tick", data)
                else:
                    pool.busy = False
            else:                               # run-to-completion baseline
                if not pool.live and pool.group:
                    for rid in pool.group:      # results only at group end
                        complete(rid, t)
                    pool.group = []
                if not pool.live:
                    if not pool.queue:
                        pool.busy = False
                        continue
                    prefill_s = 0.0
                    while pool.queue and len(pool.live) < cap:
                        rid = pool.queue.popleft()
                        pool.live[rid] = by_rid[rid]["max_new"]
                        pool.group.append(rid)
                        prefill_s += by_rid[rid]["prompt_len"] * \
                            pool.step_s / PREFILL_SPEEDUP
                    push(t + prefill_s, "tick", data)
                else:
                    for rid in list(pool.live):
                        pool.live[rid] -= 1
                        if pool.live[rid] <= 0:
                            del pool.live[rid]  # done decoding; held to end
                    push(t + pool.step_s, "tick", data)

    lat = sorted(done[r["rid"]] - r["t"] for r in trace)
    n = len(lat)

    def pct(q: float) -> float:
        return lat[min(n - 1, max(0, math.ceil(q * n) - 1))]

    makespan = max(done.values())
    total_tokens = sum(r["max_new"] for r in trace)
    w0, w1 = BURST_WINDOW
    burst_tokens = sum(r["max_new"] for r in trace
                       if w0 <= done[r["rid"]] < w1)
    by_region: Dict[str, int] = {r.region: 0 for r in REPLICAS}
    pre = {r.region: 0 for r in REPLICAS}
    grace = {r.region: 0 for r in REPLICAS}
    post = {r.region: 0 for r in REPLICAS}
    for d in router.decisions:
        by_region[d["chosen"]] += 1
        if by_rid[d["rid"]]["src"] == "us-east":
            t_arr = by_rid[d["rid"]]["t"]
            side = (pre if t_arr < T_COLLAPSE else
                    grace if t_arr < T_COLLAPSE + GRACE_S else post)
            side[d["chosen"]] += 1
    metrics = {
        "makespan_s": round(makespan, 4),
        "tokens_per_sec": round(total_tokens / makespan, 4),
        "burst_tokens_per_sec": round(burst_tokens / (w1 - w0), 4),
        "latency_p50_s": round(pct(0.50), 4),
        "latency_p95_s": round(pct(0.95), 4),
        "latency_p99_s": round(pct(0.99), 4),
        "mean_latency_s": round(sum(lat) / n, 4),
        "routes_by_region": by_region,
        "us_east_routes_pre_collapse": pre,
        "us_east_routes_grace": grace,
        "us_east_routes_post_grace": post,
    }
    return metrics, events, router


# ------------------------------------------------------------------ bench


def bench_serving(seed: int = 0) -> Dict:
    trace = make_trace(seed)
    steps, autoscaler = capacity_steps(trace)

    batch, _, _ = simulate_serving(trace, "balanced", "batch", steps)
    cont, events, router = simulate_serving(trace, "balanced",
                                            "continuous", steps)
    modes = {}
    for mode in ROUTER_MODES:
        if mode == "balanced":
            modes[mode] = {k: cont[k] for k in
                           ("tokens_per_sec", "latency_p99_s",
                            "routes_by_region")}
            continue
        m, _, _ = simulate_serving(trace, mode, "continuous", steps)
        modes[mode] = {k: m[k] for k in ("tokens_per_sec", "latency_p99_s",
                                         "routes_by_region")}

    eu = cont["us_east_routes_post_grace"].get("eu-west", 0)
    eu_pre = cont["us_east_routes_pre_collapse"].get("eu-west", 0)
    scaled_up = any(d[2] > d[1] for d in autoscaler["decisions"])
    result = {
        "scenario": {
            "seed": seed,
            "replicas": [{"region": r.region, "device": r.device,
                          "units": r.units, "n_slots": r.n_slots,
                          "cost_per_unit_hour": r.cost_per_unit_hour}
                         for r in REPLICAS],
            "n_requests": len(trace),
            "total_tokens": sum(r["max_new"] for r in trace),
            "trace_s": T_TRACE,
            "burst": f"+{BURST[2]:g}rps us-east "
                     f"@[{BURST[0]:g},{BURST[1]:g}]s",
            "link_collapse": f"us-east<->eu-west 100->{COLLAPSE_MBPS:g}Mbps"
                             f"@{T_COLLAPSE:g}s",
            "router_knobs": dict(ROUTER_KNOBS),
            "prefill_speedup": PREFILL_SPEEDUP,
            "load_window_s": LOAD_WINDOW_S,
        },
        "router": {"mode": "balanced", "events": events,
                   "decisions": router.decisions},
        "autoscaler": autoscaler,
        "variants": {"batch": batch, "continuous": cont},
        "modes": modes,
        "throughput_speedup": round(cont["burst_tokens_per_sec"]
                                    / batch["burst_tokens_per_sec"], 3),
        "p99_improvement": round(batch["latency_p99_s"]
                                 / cont["latency_p99_s"], 3),
        "acceptance": {
            "continuous_beats_batch_tokens_per_sec":
                cont["burst_tokens_per_sec"] > batch["burst_tokens_per_sec"],
            "continuous_beats_batch_p99":
                cont["latency_p99_s"] < batch["latency_p99_s"],
            "router_reroutes_on_link_collapse": eu == 0 and eu_pre > 0,
            "balanced_beats_nearest_p99":
                cont["latency_p99_s"] < modes["nearest"]["latency_p99_s"],
            "autoscaler_scales_up_on_burst": scaled_up,
        },
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(result, f, indent=1)
    return result


def print_report(r: Dict) -> None:
    print("=== serving: continuous batching vs run-to-completion ===")
    s = r["scenario"]
    print(f"  trace: {s['n_requests']} requests / {s['total_tokens']} "
          f"tokens over {s['trace_s']:.0f}s, burst {s['burst']}")
    print(f"  chaos: {s['link_collapse']}")
    print(f"  {'':12s} {'burst tok/s':>11s} {'p50':>8s} {'p95':>8s} "
          f"{'p99':>8s} {'makespan':>10s}")
    for label in ("batch", "continuous"):
        v = r["variants"][label]
        print(f"  {label:12s} {v['burst_tokens_per_sec']:>11.1f} "
              f"{v['latency_p50_s']:>7.2f}s {v['latency_p95_s']:>7.2f}s "
              f"{v['latency_p99_s']:>7.2f}s {v['makespan_s']:>9.1f}s")
    print(f"  -> {r['throughput_speedup']}x delivered tokens/sec in the "
          f"burst window, {r['p99_improvement']}x p99 improvement")
    print(f"  router modes ({len(r['router']['decisions'])} decisions "
          f"recorded):")
    for mode, m in r["modes"].items():
        print(f"    {mode:10s} {m['tokens_per_sec']:>8.1f} tok/s  "
              f"p99 {m['latency_p99_s']:>6.2f}s  {m['routes_by_region']}")
    ups = [d for d in r["autoscaler"]["decisions"] if d[2] > d[1]]
    print(f"  autoscaler: {len(r['autoscaler']['decisions'])} observations,"
          f" {len(ups)} scale-up(s): "
          + "; ".join(f"{d[1]}->{d[2]}@{d[0]:.0f}s" for d in ups))
    print(f"  acceptance: {r['acceptance']}")
    print(f"  written: {os.path.relpath(OUT_PATH)}")


def compare(path_a: str, path_b: str) -> None:
    a, b = json.load(open(path_a)), json.load(open(path_b))
    print(f"{'metric':28s} {os.path.basename(path_a):>16s} "
          f"{os.path.basename(path_b):>16s}")
    for key in ("throughput_speedup", "p99_improvement"):
        print(f"{key:28s} {a[key]:>16} {b[key]:>16}")
    for label in ("batch", "continuous"):
        for key in ("tokens_per_sec", "latency_p99_s", "makespan_s"):
            print(f"{label}.{key:22s} {a['variants'][label][key]:>16} "
                  f"{b['variants'][label][key]:>16}")


def main(argv: Sequence[str] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"),
                    help="diff two BENCH_serving.json files instead")
    args = ap.parse_args(argv)
    if args.compare:
        compare(*args.compare)
        return {}
    r = bench_serving(seed=args.seed)
    print_report(r)
    return r


if __name__ == "__main__":
    main()
