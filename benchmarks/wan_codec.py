"""Fused WAN payload codec microbenchmark.

Measures the three acceptance axes of the codec against its baselines:

1. **Encode kernel speedup** — the single-pass threshold-refinement kernel
   (``wan_codec.wan_encode_pallas``, which also quantizes) vs the legacy
   iterative-argmax kernel (``topk_compress.topk_compress_pallas``,
   selection only) at k/n = 1% on a >=1M-element buffer, both in Pallas
   interpret mode on CPU.  Target: >= 5x.
2. **Bytes on wire** — dense fp32 vs sparse fp32 (value+index pairs) vs the
   codec's int8+u16+scales format at equal sync interval
   (``SyncConfig.payload_mb``).  Target: >= 8x below dense.
3. **Convergence with error feedback** — compressed-with-EF ASGD-GA vs
   dense ASGD-GA on the emulated 2-pod LeNet run.  The operational
   criterion is "within 5% of dense" measured on the **loss-reduction
   scale**: (init - ef_final) >= 0.95 * (init - dense_final).  A raw ratio
   of final losses is ill-conditioned here — both runs converge to ~0.1%
   of the initial loss, where the ratio is seed noise; both numbers are
   reported.

Also reports end-to-end emulated step+sync wall time for dense / legacy
top-k / fused codec sync on the tiny preset, so payload savings can be
weighed against encode cost on the critical path.

Run:  PYTHONPATH=src python -m benchmarks.wan_codec
      PYTHONPATH=src python -m benchmarks.wan_codec --compare A.json B.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_DIR = os.path.join(HERE, "..", "experiments", "bench")
OUT_PATH = os.path.join(OUT_DIR, "BENCH_wan_codec.json")

N = 1 << 20              # encode benchmark buffer (>= 1M elements)
FRAC = 0.01              # k/n for the kernel comparison
MODEL_MB = 44.6          # ResNet18 gradient size, paper Table III ballpark
REPS = 5


def _timeit(fn, reps: int = REPS) -> float:
    fn()                                     # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def bench_encode_kernel() -> Dict:
    from repro.kernels.topk_compress import topk_compress_pallas
    from repro.kernels.wan_codec import (k_per_block, wan_decode_pallas,
                                         wan_encode_pallas)

    x = jnp.asarray(np.random.default_rng(0).normal(size=(N,)), jnp.float32)
    k = int(N * FRAC)
    t_old = _timeit(lambda: topk_compress_pallas(x, k, block=1024,
                                                 interpret=True))
    kb = k_per_block(4096, FRAC)
    t_new = _timeit(lambda: wan_encode_pallas(x, kb, block=4096,
                                              interpret=True))
    q, idx, scales = wan_encode_pallas(x, kb, block=4096, interpret=True)
    t_dec = _timeit(lambda: wan_decode_pallas(q, idx, scales, N, block=4096,
                                              interpret=True))
    return {
        "n": N, "k_over_n": FRAC,
        "iterative_argmax_ms": round(t_old * 1e3, 2),
        "fused_encode_ms": round(t_new * 1e3, 2),
        "fused_decode_ms": round(t_dec * 1e3, 2),
        "encode_speedup": round(t_old / t_new, 2),
    }


def bench_bytes_on_wire() -> Dict:
    from repro.core.cost import tier_payload_table
    from repro.core.sync import SyncConfig

    interval = 8
    dense = SyncConfig("asgd_ga", interval)
    sparse = SyncConfig("asgd_ga", interval, compress_topk=FRAC)
    codec = SyncConfig("asgd_ga", interval, compress_topk=FRAC,
                       quantize_int8=True)
    fp8 = SyncConfig("asgd_ga", interval, compress_topk=FRAC,
                     quantize_int8=True, value_dtype="fp8")
    int4 = SyncConfig("asgd_ga", interval, compress_topk=FRAC,
                      quantize_int8=True, value_dtype="int4")
    rows = {
        "dense_fp32_mb": dense.payload_mb(MODEL_MB),
        "sparse_fp32_mb": sparse.payload_mb(MODEL_MB),
        "codec_int8_mb": codec.payload_mb(MODEL_MB),
        "codec_fp8_mb": fp8.payload_mb(MODEL_MB),
        "codec_int4_mb": int4.payload_mb(MODEL_MB),
    }
    rows = {k: round(v, 4) for k, v in rows.items()}
    rows["model_mb"] = MODEL_MB
    rows["interval"] = interval
    rows["reduction_vs_dense"] = round(
        rows["dense_fp32_mb"] / rows["codec_int8_mb"], 1)
    rows["reduction_vs_sparse_fp32"] = round(
        rows["sparse_fp32_mb"] / rows["codec_int8_mb"], 1)
    rows["int4_reduction_vs_dense"] = round(
        rows["dense_fp32_mb"] / rows["codec_int4_mb"], 1)
    # the controller's full price list (per-tier, per-step at this interval)
    rows["tier_table"] = tier_payload_table(MODEL_MB, FRAC,
                                            interval=interval)
    return rows


def bench_tier_encode() -> Dict:
    """Per-tier encode/decode wall time on the 1M buffer — the precision
    ladder costs (almost) nothing on the compute side: all tiers share the
    selection kernel and differ only in the fused value encoding."""
    from repro.kernels.wan_codec import (k_per_block, wan_decode_pallas,
                                         wan_encode_pallas)

    x = jnp.asarray(np.random.default_rng(0).normal(size=(N,)), jnp.float32)
    kb = k_per_block(4096, FRAC)
    out = {}
    for dt in ("int8", "fp8", "int4"):
        t_enc = _timeit(lambda: wan_encode_pallas(
            x, kb, block=4096, value_dtype=dt, interpret=True), reps=3)
        q, idx, scales = wan_encode_pallas(x, kb, block=4096, value_dtype=dt,
                                           interpret=True)
        t_dec = _timeit(lambda: wan_decode_pallas(
            q, idx, scales, N, block=4096, value_dtype=dt, interpret=True),
            reps=3)
        out[dt] = {"encode_ms": round(t_enc * 1e3, 2),
                   "decode_ms": round(t_dec * 1e3, 2),
                   "payload_bytes_per_elem": 1.0 if dt != "int4" else 0.5}
    return out


def _lenet_run(sync, steps: int = 120):
    from repro.core.sync import SyncConfig  # noqa: F401  (sync is one)
    from repro.data.pipeline import GeoDataset, synthetic_classification
    from repro.models.reference import PAPER_MODELS
    from repro.training.trainer import (Trainer, TrainerConfig,
                                        stack_pod_batches)

    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(1500, m["input_shape"], m["n_classes"],
                                    seed=0)
    geo = GeoDataset.partition(data, ["sh", "cq"], [2, 1])
    loaders = [geo.loader("sh", 32, seed=0), geo.loader("cq", 32, seed=1)]
    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05, sync=sync))
    st = tr.init_state(jax.random.key(0))
    st, hist = tr.fit(st, lambda s: stack_pod_batches(
        [next(l) for l in loaders]), steps)
    return hist["loss"][0], float(np.mean(hist["loss"][-10:]))


def bench_ef_convergence() -> Dict:
    from repro.core.sync import SyncConfig

    first, dense = _lenet_run(SyncConfig("asgd_ga", 4))
    _, ef = _lenet_run(SyncConfig(
        "asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
        error_feedback=True, codec_block=1024, overlap_chunks=2))
    _, no_ef = _lenet_run(SyncConfig(
        "asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
        codec_block=1024))
    red = first - dense
    return {
        "initial_loss": round(first, 4),
        "dense_final_loss": round(dense, 6),
        "ef_final_loss": round(ef, 6),
        "no_ef_final_loss": round(no_ef, 6),
        "ef_loss_reduction_frac_of_dense": round((first - ef) / red, 4),
        "no_ef_loss_reduction_frac_of_dense": round((first - no_ef) / red, 4),
        "ef_final_over_dense_final": round(ef / dense, 4),
    }


def bench_step_time() -> Dict:
    """Emulated end-to-end step+sync wall time, tiny preset, 2 pods."""
    from repro.core.sync import SyncConfig
    from repro.data.pipeline import TokenStream
    from repro.launch.train import preset_tiny
    from repro.models.registry import get_model_fns
    from repro.training.trainer import Trainer, TrainerConfig

    cfg = preset_tiny()
    fns = get_model_fns("transformer")
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=64, batch_size=4,
                        seed=7, shard=0, n_shards=1)

    def batches(step):
        b = stream.batch(step)
        return {k: jnp.asarray(np.stack([v, v])) for k, v in b.items()}

    variants = {
        "dense": SyncConfig("asgd_ga", 4),
        "legacy_topk_fp32": SyncConfig("asgd_ga", 4, compress_topk=FRAC),
        "fused_codec": SyncConfig("asgd_ga", 4, compress_topk=FRAC,
                                  quantize_int8=True, error_feedback=True,
                                  overlap_chunks=4),
    }
    out = {}
    for name, sync in variants.items():
        tr = Trainer(lambda p, b: fns.loss_fn(p, cfg, b),
                     lambda k: fns.init_params(k, cfg),
                     TrainerConfig(n_pods=2, optimizer="sgd", lr=0.01,
                                   sync=sync))
        st = tr.init_state(jax.random.key(0))
        for step in range(4):                 # compile both jitted paths
            st, _ = tr.train_step(st, batches(step))
            st = tr.maybe_sync(st, step)
        t0 = time.perf_counter()
        steps = 8
        for step in range(4, 4 + steps):
            st, _ = tr.train_step(st, batches(step))
            st = tr.maybe_sync(st, step)
        jax.block_until_ready(st.params)
        out[name] = round((time.perf_counter() - t0) / steps * 1e3, 1)
    return {"step_plus_sync_ms": out}


def run_bench() -> Dict:
    report = {
        "encode_kernel": bench_encode_kernel(),
        "tier_encode": bench_tier_encode(),
        "bytes_on_wire": bench_bytes_on_wire(),
        "ef_convergence": bench_ef_convergence(),
        "end_to_end": bench_step_time(),
    }
    report["acceptance"] = {
        "encode_speedup_ge_5x":
            report["encode_kernel"]["encode_speedup"] >= 5.0,
        "bytes_reduction_ge_8x":
            report["bytes_on_wire"]["reduction_vs_dense"] >= 8.0,
        "int4_below_int8_bytes":
            report["bytes_on_wire"]["codec_int4_mb"]
            < report["bytes_on_wire"]["codec_int8_mb"],
        "ef_within_5pct_of_dense_loss_reduction":
            report["ef_convergence"]["ef_loss_reduction_frac_of_dense"]
            >= 0.95,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    return report


def _print_report(r: Dict) -> None:
    enc = r["encode_kernel"]
    wire = r["bytes_on_wire"]
    conv = r["ef_convergence"]
    print(f"encode kernel  : {enc['iterative_argmax_ms']} ms (iterative) -> "
          f"{enc['fused_encode_ms']} ms (fused)  "
          f"[{enc['encode_speedup']}x]")
    print(f"bytes on wire  : {wire['dense_fp32_mb']} MB dense -> "
          f"{wire['codec_int8_mb']} MB int8 / {wire['codec_fp8_mb']} MB fp8 "
          f"/ {wire['codec_int4_mb']} MB int4  "
          f"[{wire['reduction_vs_dense']}x / "
          f"{wire['int4_reduction_vs_dense']}x]")
    tiers = r["tier_encode"]
    print("tier encode ms : " + "  ".join(
        f"{d}={tiers[d]['encode_ms']}" for d in tiers))
    print(f"EF convergence : {conv['ef_loss_reduction_frac_of_dense'] * 100:.1f}% "
          f"of dense loss reduction "
          f"(no-EF: {conv['no_ef_loss_reduction_frac_of_dense'] * 100:.1f}%)")
    print(f"step+sync (ms) : {r['end_to_end']['step_plus_sync_ms']}")
    print(f"acceptance     : {r['acceptance']}")


def _compare(a_path: str, b_path: str) -> None:
    with open(a_path) as f:
        a = json.load(f)
    with open(b_path) as f:
        b = json.load(f)
    keys = [("encode_kernel", "encode_speedup"),
            ("bytes_on_wire", "reduction_vs_dense"),
            ("ef_convergence", "ef_loss_reduction_frac_of_dense")]
    print(f"{'metric':42s} {'A':>10s} {'B':>10s}")
    for sec, key in keys:
        print(f"{sec + '.' + key:42s} {a[sec][key]:>10} {b[sec][key]:>10}")


def main(argv: Sequence[str] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"),
                    help="diff two BENCH_wan_codec.json files instead")
    args = ap.parse_args(argv)
    if args.compare:
        _compare(*args.compare)
        return {}
    report = run_bench()                    # writes BENCH_wan_codec.json
    _print_report(report)
    print(f"wrote {os.path.relpath(OUT_PATH, os.path.join(HERE, '..'))}")
    return report


if __name__ == "__main__":
    main()
