"""Paper-experiment reproductions (one function per paper table/figure).

All experiments use the paper's own measured inputs — Table I device
quantifications, Table III model/gradient sizes, 100 Mbps WAN — with
iteration times calibrated to the paper's small evaluation models.  Real
training runs (usability/accuracy panels) use the actual SPMD sync code on
emulated pods; wall-clock/cost panels use the WAN event simulator, since a
CPU container has no WAN.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.cost import cost_report
from repro.core.scheduler import (CATALOG, CloudResources, optimal_matching,
                                  predict_times, waiting_fraction)
from repro.core.sync import SyncConfig
from repro.core.wan import SimCloud, WANConfig, compare_strategies, simulate
from repro.data.pipeline import GeoDataset, synthetic_classification
from repro.models.reference import PAPER_MODELS, param_mb
from repro.training.trainer import (Trainer, TrainerConfig, accuracy_eval,
                                    stack_pod_batches)

# calibrated per-iteration compute times for the paper's eval models on
# 12 CPU cores (ElasticDL/TF serverless workers; calibrated so the baseline
# compute:WAN ratio reproduces the paper's measured Fig 10 speedups —
# 1.2x / 1.2x / 1.7x at sync frequency 8)
ITER_S = {"lenet": 0.54, "resnet": 0.70, "deepfm": 0.56}
WAN = WANConfig(bandwidth_mbps=100.0, latency_s=0.05, fluctuation=0.25,
                overlap=0.55, seed=0)


# ---------------------------------------------------------------- Table I


def bench_table1() -> Dict:
    """Device quantification table (TN / IN / IN-TN ratio)."""
    rows = {}
    for name in ("icelake", "cascade", "skylake", "t4", "v100"):
        d = CATALOG[name]
        rows[name] = {"TN": round(d.tn, 3), "IN": round(d.in_ or 0, 3),
                      "IN/TN": round(d.in_tn_ratio or 0, 3)}
    # paper's headline checks
    paper = {"cascade": (0.938, 0.666, 0.710), "skylake": (1.167, 0.973, 0.834),
             "t4": (57.854, 59.629, 1.031), "v100": (139.010, 154.042, 1.108)}
    err = max(abs(rows[k]["TN"] - v[0]) / v[0] for k, v in paper.items())
    return {"rows": rows, "max_tn_rel_err_vs_paper": round(err, 4)}


# ------------------------------------------------------------------ Fig 7


def bench_usability(steps: int = 120, model: str = "lenet") -> Dict:
    """Usability: 2-region Cloudless-Training (async SGD baseline sync) vs
    trivial single-cloud PS training, equal total resources — accuracy and
    loss trends must match (paper Fig 7)."""
    m = PAPER_MODELS[model]
    fv = 5400 if model == "deepfm" else None
    data = synthetic_classification(3000, m["input_shape"], m["n_classes"],
                                    seed=0, feature_vocab=fv)
    test = synthetic_classification(600, m["input_shape"], m["n_classes"],
                                    seed=1, feature_vocab=fv)
    loss_fn = lambda p, b: (m["loss"](p, b), {})  # noqa: E731

    def run(n_pods: int) -> Dict:
        geo = GeoDataset.partition(data, [f"r{i}" for i in range(n_pods)],
                                   [1] * n_pods)
        loaders = [geo.loader(f"r{i}", 32, seed=i) for i in range(n_pods)]
        tr = Trainer(loss_fn, m["init"],
                     TrainerConfig(n_pods=n_pods, optimizer="sgd", lr=0.05,
                                   sync=SyncConfig("asgd", 1)))
        st = tr.init_state(jax.random.key(0))
        st, hist = tr.fit(
            st, lambda s: stack_pod_batches([next(l) for l in loaders]),
            steps, eval_fn=accuracy_eval(m["apply"], test), eval_every=steps)
        return {"acc": hist["eval"][-1][1],
                "loss": float(np.mean(hist["loss"][-10:]))}

    trivial = run(1)
    cloudless = run(2)
    return {"model": model, "trivial": trivial, "cloudless": cloudless,
            "acc_gap": round(abs(cloudless["acc"] - trivial["acc"]), 4)}


# ------------------------------------------------------- Fig 8 / Table IV


SCHED_CASES = [
    # (id, data ratio SH:CQ, device types, paper cost reduction ranges)
    (1, (1.0, 1.0), ("cascade", "sky")),
    (2, (2.0, 1.0), ("cascade", "cascade")),
    (3, (2.0, 1.0), ("cascade", "sky")),
]


def bench_scheduling(model: str = "resnet", n_iters: int = 300) -> Dict:
    """Elastic scheduling vs greedy baseline: waiting-time and cost
    reduction across the paper's three cases (Fig 8), with the makespan
    pinned by the straggler either way."""
    grad_mb = PAPER_MODELS[model]["grad_mb"]
    out = {}
    for cid, ratio, devs in SCHED_CASES:
        clouds = [CloudResources("sh", ((devs[0], 6),), data_size=ratio[0]),
                  CloudResources("cq", ((devs[1], 6),), data_size=ratio[1])]
        plans = optimal_matching(clouds)

        def sim(alloc_units, label):
            # iteration time scales inversely with allocated power and
            # proportionally with the local shard size
            sims = []
            for c, units in zip(clouds, alloc_units):
                dev = c.devices[0][0]
                power = units * CATALOG[dev].power()
                t = ITER_S[model] * (c.data_size / (ratio[0] + ratio[1])) \
                    / (power / (6 * CATALOG["cascade"].power()))
                sims.append(SimCloud(c.region, iter_time_s=t, units=2 * units))
            return simulate(sims, SyncConfig("asgd", 1), n_iters=n_iters,
                            model_mb=grad_mb, wan=WAN)

        base = sim([6, 6], "greedy")
        plan_units = [dict(p.allocation).get(d, 0)
                      for p, d in zip(plans, devs)]
        elastic = sim(plan_units, "elastic")

        units_b = {"sh": 12, "cq": 12}
        units_e = {"sh": 2 * plan_units[0], "cq": 2 * plan_units[1]}
        rates = {"sh": 1.0, "cq": 1.0}
        rb = cost_report(base, units_b, rates)
        re = cost_report(elastic, units_e, rates)
        wait_b = sum(c.wait_s for c in base.clouds)
        wait_e = sum(c.wait_s for c in elastic.clouds)
        out[f"case{cid}"] = {
            "plan_cores": {p.region: 2 * u for p, u in zip(plans, plan_units)},
            "wait_reduction": round(1 - wait_e / max(wait_b, 1e-9), 3),
            "cost_reduction": round(re.reduction_vs(rb), 3),
            "makespan_ratio": round(elastic.makespan_s / base.makespan_s, 3),
        }
    return out


# ----------------------------------------------------------------- Fig 10


def bench_sync(n_iters: int = 400) -> Dict:
    """Synchronization strategies: speedup + communication-time reduction vs
    per-step async-SGD baseline at frequencies 4 and 8 (paper Fig 10:
    1.2x / 1.2x / 1.7x for LeNet / ResNet / DeepFM; comm time -46..-73%)."""
    out = {}
    for model in ("lenet", "resnet", "deepfm"):
        grad_mb = PAPER_MODELS[model]["grad_mb"]
        clouds = [SimCloud("sh", iter_time_s=ITER_S[model] * 1.2, units=12),
                  SimCloud("cq", iter_time_s=ITER_S[model], units=12)]
        res = compare_strategies(clouds, n_iters=n_iters, model_mb=grad_mb,
                                 intervals=(4, 8), wan=WAN)
        base = res["asgd"]
        rows = {}
        for key, r in res.items():
            rows[key] = {
                "speedup": round(base.makespan_s / r.makespan_s, 3),
                "comm_reduction": round(
                    1 - r.clouds[0].comm_s / base.clouds[0].comm_s, 3),
                "traffic_mb": round(r.total_traffic_mb, 1),
            }
        out[model] = rows
    return out


# ----------------------------------------------------------------- Fig 11


def bench_sma(steps: int = 150) -> Dict:
    """SMA accuracy study (self-hosted env): real training on emulated pods.
    Paper: SMA's barrier average gives the best accuracy; its wall-clock is
    baseline-like (simulated here)."""
    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(2000, m["input_shape"], m["n_classes"],
                                    seed=0)
    test = synthetic_classification(500, m["input_shape"], m["n_classes"],
                                    seed=1)
    geo = GeoDataset.partition(data, ["bj", "sh"], [1, 1])
    loss_fn = lambda p, b: (m["loss"](p, b), {})  # noqa: E731

    accs, losses = {}, {}
    for strat, k in (("asgd", 1), ("asgd_ga", 8), ("ama", 8), ("sma", 8)):
        loaders = [geo.loader("bj", 32, seed=0), geo.loader("sh", 32, seed=1)]
        tr = Trainer(loss_fn, m["init"],
                     TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                                   sync=SyncConfig(strat, k)))
        st = tr.init_state(jax.random.key(0))
        st, hist = tr.fit(
            st, lambda s: stack_pod_batches([next(l) for l in loaders]),
            steps, eval_fn=accuracy_eval(m["apply"], test), eval_every=steps)
        accs[f"{strat}@{k}"] = round(hist["eval"][-1][1], 4)
        losses[f"{strat}@{k}"] = round(float(np.mean(hist["loss"][-10:])), 4)

    # self-hosted wall clock (10x bandwidth, lower latency)
    wan = WANConfig(bandwidth_mbps=1000, latency_s=0.01, fluctuation=0.1,
                    seed=0)
    clouds = [SimCloud("bj", iter_time_s=ITER_S["lenet"], units=12),
              SimCloud("sh", iter_time_s=ITER_S["lenet"], units=12)]
    times = {f"{s}@{k}": round(simulate(
        clouds, SyncConfig(s, k), n_iters=steps, model_mb=0.4,
        wan=wan).makespan_s, 2)
        for s, k in (("asgd", 1), ("asgd_ga", 8), ("ama", 8), ("sma", 8))}
    return {"accuracy": accs, "final_loss": losses, "sim_makespan_s": times}
