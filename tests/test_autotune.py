"""Adaptive WAN sync autotuner: ladder construction, control law (guard /
pressure / headroom / interval budget), retune state carry-over, bandwidth
traces, and the EF-guard safety property (hypothesis, optional extra).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import (AdaptiveSyncController, BucketStats,
                                 WanProbe, build_ladder)
from repro.core.control_plane import CloudEvent, EventBus
from repro.core.sync import (CODEC_TIERS, SyncConfig, apply_sync,
                             init_sync_state, on_step_gradients,
                             retune_sync_state)
from repro.core.wan import BandwidthTrace, SimCloud, WANConfig, simulate

BASE = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                  error_feedback=True)


def _ctrl(**kw):
    kw.setdefault("model_mb", 44.6)
    kw.setdefault("compute_step_s", 0.5)
    return AdaptiveSyncController(BASE, kw.pop("model_mb"),
                                  kw.pop("compute_step_s"), **kw)


# ------------------------------------------------------------------ ladder


def test_ladder_sorted_by_payload_descending():
    ladder = build_ladder(BASE, (0.05, 0.02, 0.01), ("int8", "fp8", "int4"))
    payloads = [c.payload_mb(1.0) for c in ladder]
    assert payloads == sorted(payloads, reverse=True)
    assert len(ladder) == 9
    # byte-equal rungs (int8 vs fp8 at the same frac) order int8 first
    for a, b in zip(ladder, ladder[1:]):
        if a.payload_mb(1.0) == b.payload_mb(1.0):
            assert (CODEC_TIERS.index(a.value_dtype)
                    < CODEC_TIERS.index(b.value_dtype))
    # every rung is a valid, codec-enabled config
    assert all(c.uses_codec and c.error_feedback for c in ladder)


def test_controller_requires_codec_with_ef():
    with pytest.raises(ValueError, match="asgd_ga"):
        AdaptiveSyncController(SyncConfig("asgd_ga", 4), 44.6, 0.5)
    with pytest.raises(ValueError, match="error_feedback"):
        AdaptiveSyncController(
            SyncConfig("asgd_ga", 4, compress_topk=0.05,
                       quantize_int8=True), 44.6, 0.5)
    with pytest.raises(ValueError, match="ef_guard"):
        _ctrl(ef_guard=1.5)


# ------------------------------------------------------------- control law


def test_guard_trip_deescalates_immediately():
    c = _ctrl()
    c.rung = 3
    c.current = c.ladder[3]
    u = c.update(0, BucketStats(msg_norm=1.0, resid_norm=0.95))
    assert u is not None and u.reason == "ef-guard"
    assert c.rung == 2
    # at rung 0 the guard clamps (nowhere safer to go) but never escalates
    c.rung = 0
    for step in range(8):
        c.update(step, BucketStats(1.0, 0.95))
        assert c.rung == 0


def test_wan_pressure_escalates_with_hysteresis():
    c = _ctrl(hysteresis=2)
    for _ in range(6):
        c.observe_wan(5.0)                 # 44.6 MB model on a 5 Mbps link
    calm = BucketStats(1.0, 0.3)
    r0 = c.rung
    c.update(0, calm)                      # pressure streak 1: interval only
    assert c.interval == c.interval_budget and c.rung == r0
    u = c.update(1, calm)                  # streak 2 -> escalate
    assert u is not None and u.reason == "wan-pressure"
    # direct jump: straight to the least aggressive rung whose fitted
    # interval respects the staleness budget (no transit rungs, each of
    # which would pay a transfer on the slow link)
    assert c.rung > r0
    assert (c._fit_interval(c.ladder[c.rung]) <= c.interval_budget
            or c.rung == len(c.ladder) - 1)
    for r in range(r0 + 1, c.rung):
        assert c._fit_interval(c.ladder[r]) > c.interval_budget


def test_no_escalation_without_guard_calm():
    """WAN pressure never overrides a stressed guard: ratio above
    escalate_margin * ef_guard blocks the rung increase."""
    c = _ctrl(hysteresis=1, ef_guard=0.9, escalate_margin=0.8)
    for _ in range(6):
        c.observe_wan(2.0)
    stressed = BucketStats(1.0, 0.8)       # 0.8 >= 0.72 margin, < 0.9 guard
    r0 = c.rung
    for step in range(6):
        c.update(step, stressed)
    assert c.rung == r0


def test_headroom_deescalates():
    c = _ctrl(hysteresis=2)
    c.rung = 4
    c.current = c.ladder[4]
    for _ in range(6):
        c.observe_wan(10_000.0)            # fat pipe: fidelity is free
    calm = BucketStats(1.0, 0.2)
    rungs = [c.rung]
    for step in range(10):
        c.update(step, calm)
        rungs.append(c.rung)
    assert c.rung < 4 and min(rungs) == c.rung


def test_interval_budget_caps_all_but_last_rung():
    c = _ctrl()
    for _ in range(6):
        c.observe_wan(0.5)                 # absurdly slow link
    c.update(0, BucketStats(1.0, 0.2))
    assert c.interval <= c.interval_budget
    # at the last rung the interval may exceed the budget (escape valve)
    c.rung = len(c.ladder) - 1
    c.current = c.ladder[-1]
    c._calm_streak = c._pressure_streak = 0
    c.update(1, BucketStats(1.0, 0.2))
    assert c.interval_budget < c.interval <= c.max_interval


def test_no_reading_holds_rung():
    """msg_norm == 0 means no telemetry yet (first interval / post-resize):
    the controller must not move the rung on it."""
    c = _ctrl()
    r0 = c.rung
    for _ in range(4):
        c.observe_wan(1.0)
    for step in range(5):
        c.update(step, BucketStats(0.0, 0.0))
    assert c.rung == r0                    # no escalation without a reading


# ----------------------------------------------------------- probes / bus


def test_probe_ema_and_fluctuation():
    c = _ctrl(probe_alpha=0.5)
    c.observe_wan(100.0)
    assert c.probe == WanProbe(100.0, 0.0)
    c.observe_wan(100.0)
    assert c.probe.fluctuation == 0.0
    c.observe_wan(25.0)
    assert 25.0 < c.probe.bandwidth_mbps < 100.0
    assert c.probe.fluctuation > 0.2


def test_resync_reanchors_belief():
    """An elasticity reconfig that rewrites the live sync settings must
    re-anchor the controller, or it reasons about knobs no longer running
    (and emits no update because *its* state never changed)."""
    from dataclasses import replace

    c = _ctrl()
    ext = replace(BASE, compress_topk=0.01, value_dtype="int4", interval=64)
    c.resync(ext)
    assert c.interval == 64
    assert c.ladder[c.rung].compress_topk == 0.01
    assert c.ladder[c.rung].value_dtype == "int4"
    # with a fat pipe, the next update pulls the interval back down
    for _ in range(6):
        c.observe_wan(10_000.0)
    u = c.update(0, BucketStats(1.0, 0.3))
    assert u is not None and u.sync.interval < 64


def test_eventbus_feeds_probe():
    bus = EventBus()
    c = _ctrl(bus=bus)
    bus.publish(CloudEvent("bandwidth_changed", bandwidth_mbps=42.0))
    assert c.probe.bandwidth_mbps == 42.0


# ------------------------------------------------- stats from sync state


def _grads(n_pods=2, seed=1):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n_pods, 300, 40)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n_pods, 77)), jnp.float32)}


def test_bucket_stats_from_sync_state():
    g = _grads()
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                     error_feedback=True, codec_block=512)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    # before any sync: no reading
    assert BucketStats.from_sync_state(st).msg_norm == 0.0
    _, st = on_step_gradients(cfg, g, st)
    _, st = apply_sync(cfg, p, st, lr=1.0)
    stats = BucketStats.from_sync_state(st)
    assert stats.msg_norm > 0 and 0 < stats.ef_ratio < 1
    assert 0 < stats.energy_capture < 1
    # worst pod governs: the reported ratio is the max across pods
    ratios = np.asarray(st.resid_norm) / np.asarray(st.msg_norm)
    assert stats.ef_ratio == pytest.approx(float(ratios.max()), rel=1e-6)


def test_retune_preserves_ef_residual_across_tiers():
    g = _grads()
    cfg8 = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                      error_feedback=True, codec_block=512)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg8, p)
    _, st = on_step_gradients(cfg8, g, st)
    _, st = apply_sync(cfg8, p, st, lr=1.0)
    cfg4 = SyncConfig("asgd_ga", 2, compress_topk=0.02, quantize_int8=True,
                      value_dtype="int4", error_feedback=True,
                      codec_block=512)
    st2 = retune_sync_state(cfg4, cfg8, st, p)
    # the residual is tier-independent (dense bucket coords): carried over
    np.testing.assert_array_equal(np.asarray(st2.ef_residual),
                                  np.asarray(st.ef_residual))
    assert int(st2.tier[0]) == cfg4.tier   # one bucket under "single"
    # EF off drops the buffer; EF back on re-arms it at zero
    cfg_no_ef = SyncConfig("asgd_ga", 2, compress_topk=0.02,
                           quantize_int8=True, codec_block=512)
    st3 = retune_sync_state(cfg_no_ef, cfg4, st2, p)
    assert st3.ef_residual.shape[1] == 0
    st4 = retune_sync_state(cfg4, cfg_no_ef, st3, p)
    assert st4.ef_residual.shape == st.ef_residual.shape
    assert float(jnp.abs(st4.ef_residual).max()) == 0.0
    # strategy changes are reconfigurations, not retunes
    with pytest.raises(ValueError, match="strategy"):
        retune_sync_state(SyncConfig("ama", 2), cfg4, st2, p)


def test_trainer_retune_keeps_training():
    from repro.training.trainer import Trainer, TrainerConfig

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def init_fn(key):
        return {"w": jax.random.normal(key, (8, 1)) * 0.1}

    tr = Trainer(loss_fn, init_fn,
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05, sync=BASE))
    st = tr.init_state(jax.random.key(0))
    rng = np.random.default_rng(0)

    def batch():
        x = rng.normal(size=(2, 16, 8)).astype(np.float32)
        y = (x.sum(-1, keepdims=True) * 0.3).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    for step in range(4):
        st, m = tr.train_step(st, batch())
        st = tr.maybe_sync(st, step)
    new_sync = SyncConfig("asgd_ga", 2, compress_topk=0.01,
                          quantize_int8=True, value_dtype="int4",
                          error_feedback=True)
    tr2, st2 = tr.retune(st, new_sync)
    # params/opt pass through untouched; tier updated; residual carried
    for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(st.sync_state.ef_residual),
                                  np.asarray(st2.sync_state.ef_residual))
    assert int(st2.sync_state.tier[0]) == new_sync.tier
    losses = []
    for step in range(4, 10):
        st2, m = tr2.train_step(st2, batch())
        st2 = tr2.maybe_sync(st2, step)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()


# ------------------------------------------------------- bandwidth traces


def test_bandwidth_trace_lookup_and_events():
    tr = BandwidthTrace(times_s=(0.0, 30.0, 60.0), mbps=(100.0, 10.0, 80.0))
    assert tr.at(0.0) == 100.0 and tr.at(29.9) == 100.0
    assert tr.at(30.0) == 10.0 and tr.at(1e9) == 80.0
    assert tr.at_step(7, 5.0) == 10.0      # 35 s -> second segment
    evs = tr.to_events()
    assert [e.bandwidth_mbps for e in evs] == [10.0, 80.0]
    assert all(e.kind == "bandwidth_changed" for e in evs)
    with pytest.raises(ValueError):
        BandwidthTrace(times_s=(1.0,), mbps=(5.0,))      # must start at 0
    with pytest.raises(ValueError):
        BandwidthTrace(times_s=(0.0, 0.0), mbps=(5.0, 6.0))


def test_fluctuating_trace_is_valid_and_seeded():
    a = BandwidthTrace.fluctuating(seed=3, duration_s=300.0)
    b = BandwidthTrace.fluctuating(seed=3, duration_s=300.0)
    assert a == b
    assert len(a.mbps) >= 5 and all(m > 0 for m in a.mbps)
    assert len(set(a.mbps)) > 1                          # actually fluctuates


def test_simulate_accepts_trace():
    clouds = [SimCloud("sh", 1.0), SimCloud("cq", 1.2)]
    tr = BandwidthTrace(times_s=(0.0, 20.0), mbps=(100.0, 10.0))
    r1 = simulate(clouds, SyncConfig("asgd_ga", 4), n_iters=50,
                  model_mb=44.6, wan=WANConfig(fluctuation=0.0), trace=tr)
    r2 = simulate(clouds, SyncConfig("asgd_ga", 4), n_iters=50,
                  model_mb=44.6, wan=WANConfig(fluctuation=0.0))
    assert r1.makespan_s > r2.makespan_s  # the 10 Mbps tail hurts


# ------------------------------------------------------- safety property


def test_guard_never_violated_on_random_traces():
    """The EF-guard invariant on random WAN traces + random stats streams:
    the controller NEVER escalates while the observed ratio is at/above the
    escalation margin, and always de-escalates (or clamps at rung 0) when
    the guard trips.  Runs under hypothesis when installed, else a seeded
    1000-case fallback exercises the same invariant."""
    def run_case(seed):
        rng = np.random.default_rng(seed)
        c = _ctrl(hysteresis=int(rng.integers(1, 4)),
                  ef_guard=float(rng.uniform(0.5, 0.95)))
        trace = BandwidthTrace.fluctuating(
            base_mbps=float(rng.uniform(5, 200)), seed=seed,
            duration_s=600.0, sigma=float(rng.uniform(0.2, 1.2)))
        t = 0.0
        for i in range(40):
            t += float(rng.uniform(1, 30))
            c.observe_wan(trace.at(t))
            ratio = float(rng.uniform(0.0, 1.0))
            before = c.rung
            c.update(i, BucketStats(msg_norm=1.0, resid_norm=ratio))
            if ratio >= c.ef_guard:
                assert c.rung == max(0, before - 1), \
                    f"guard trip must de-escalate (seed {seed}, step {i})"
            elif ratio >= c.escalate_margin * c.ef_guard:
                assert c.rung <= before, \
                    f"escalated under guard stress (seed {seed}, step {i})"
            assert 0 <= c.rung < len(c.ladder)
            assert c.min_interval <= c.interval <= c.max_interval

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st_
    except ImportError:
        for seed in range(1000):
            run_case(seed)
        return

    @settings(max_examples=200, deadline=None)
    @given(st_.integers(0, 2 ** 31 - 1))
    def prop(seed):
        run_case(seed)

    prop()
