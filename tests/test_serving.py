"""Serving engine tests: generate correctness, batching, long-window decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as T
from repro.models.registry import get_model_fns
from repro.serving.engine import BatchScheduler, ServingEngine


@pytest.fixture(scope="module")
def granite():
    arch = get_arch("granite-8b")
    cfg = arch.smoke
    params = T.init_params(jax.random.key(0), cfg)
    return arch, cfg, params


def test_greedy_generate_matches_manual_loop(granite):
    arch, cfg, params = granite
    engine = ServingEngine(arch, params, cache_len=24, use_smoke=True)
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    gen = engine.generate(prompt, 6)
    assert gen.tokens.shape == (2, 6)

    # manual teacher-forced argmax using full forward each step
    toks = np.asarray(prompt)
    outs = []
    for _ in range(6):
        logits, _ = T.forward(params, cfg, jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab_size], -1))
        outs.append(nxt)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(gen.tokens, np.stack(outs, 1))


def test_temperature_sampling_within_vocab(granite):
    arch, cfg, params = granite
    engine = ServingEngine(arch, params, cache_len=16, use_smoke=True)
    prompt = jax.random.randint(jax.random.key(2), (1, 4), 0, cfg.vocab_size)
    gen = engine.generate(prompt, 8, temperature=1.0, key=jax.random.key(3))
    assert gen.tokens.min() >= 0 and gen.tokens.max() < cfg.vocab_size


def test_batch_scheduler_completes_all(granite):
    arch, cfg, params = granite
    engine = ServingEngine(arch, params, cache_len=24, use_smoke=True)
    sched = BatchScheduler(engine, batch_size=3)
    rng = np.random.default_rng(0)
    rids = [sched.submit(rng.integers(0, cfg.vocab_size, n).astype(np.int32), 4)
            for n in (5, 8, 8, 3, 6, 8, 2)]
    results = sched.run()
    assert set(results) == set(rids)
    assert all(len(v) == 4 for v in results.values())


def test_ssm_engine_generates():
    arch = get_arch("mamba2-1.3b")
    cfg = arch.smoke
    fns = get_model_fns(arch.module)
    params = fns.init_params(jax.random.key(0), cfg)
    engine = ServingEngine(arch, params, cache_len=16, use_smoke=True)
    prompt = jax.random.randint(jax.random.key(1), (2, 6), 0, cfg.vocab_size)
    gen = engine.generate(prompt, 5)
    assert gen.tokens.shape == (2, 5)
