"""Serving plane tests: engine correctness, slot-pool invariants, the
padding regression, router determinism + reroute, replica autoscaling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.control_plane import (CloudEvent, EventBus,
                                      ServingElasticityController,
                                      TRAINING_EVENT_KINDS)
from repro.models import transformer as T
from repro.models.registry import get_model_fns
from repro.serving.engine import (BatchScheduler, ContinuousEngine,
                                  ContinuousScheduler, ServingEngine)
from repro.serving.router import GeoRouter, ReplicaSpec, replay_decisions


@pytest.fixture(scope="module")
def granite():
    arch = get_arch("granite-8b")
    cfg = arch.smoke
    params = T.init_params(jax.random.key(0), cfg)
    return arch, cfg, params


def test_greedy_generate_matches_manual_loop(granite):
    arch, cfg, params = granite
    engine = ServingEngine(arch, params, cache_len=24, use_smoke=True)
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    gen = engine.generate(prompt, 6)
    assert gen.tokens.shape == (2, 6)

    # manual teacher-forced argmax using full forward each step
    toks = np.asarray(prompt)
    outs = []
    for _ in range(6):
        logits, _ = T.forward(params, cfg, jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab_size], -1))
        outs.append(nxt)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(gen.tokens, np.stack(outs, 1))


def test_temperature_sampling_within_vocab(granite):
    arch, cfg, params = granite
    engine = ServingEngine(arch, params, cache_len=16, use_smoke=True)
    prompt = jax.random.randint(jax.random.key(2), (1, 4), 0, cfg.vocab_size)
    gen = engine.generate(prompt, 8, temperature=1.0, key=jax.random.key(3))
    assert gen.tokens.min() >= 0 and gen.tokens.max() < cfg.vocab_size


def test_batch_scheduler_completes_all(granite):
    arch, cfg, params = granite
    engine = ServingEngine(arch, params, cache_len=24, use_smoke=True)
    sched = BatchScheduler(engine, batch_size=3)
    rng = np.random.default_rng(0)
    rids = [sched.submit(rng.integers(0, cfg.vocab_size, n).astype(np.int32), 4)
            for n in (5, 8, 8, 3, 6, 8, 2)]
    results = sched.run()
    assert set(results) == set(rids)
    assert all(len(v) == 4 for v in results.values())


def test_ssm_engine_generates():
    arch = get_arch("mamba2-1.3b")
    cfg = arch.smoke
    fns = get_model_fns(arch.module)
    params = fns.init_params(jax.random.key(0), cfg)
    engine = ServingEngine(arch, params, cache_len=16, use_smoke=True)
    prompt = jax.random.randint(jax.random.key(1), (2, 6), 0, cfg.vocab_size)
    gen = engine.generate(prompt, 5)
    assert gen.tokens.shape == (2, 5)


# ---------------------------------------------------------------------------
# the padding regression + slot-pool invariants
# ---------------------------------------------------------------------------


def test_batch_matches_solo_generation(granite):
    # THE padding regression: the old batcher left-padded mixed-length
    # prompts with zeros and fed them to prefill unmasked, so a short
    # prompt's tokens depended on its neighbours' lengths.  Batched
    # output must now equal solo generation token-for-token.
    arch, cfg, params = granite
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (2, 8, 5, 3, 8, 6)]       # deliberately mixed
    engine = ServingEngine(arch, params, cache_len=16, use_smoke=True)
    sched = BatchScheduler(engine, batch_size=3)
    rids = [sched.submit(p, 4) for p in prompts]
    batched = sched.run()

    solo = ServingEngine(arch, params, cache_len=16, use_smoke=True)
    for rid, p in zip(rids, prompts):
        ref = solo.generate(jnp.asarray(p)[None], 4).tokens[0]
        np.testing.assert_array_equal(
            batched[rid], ref,
            err_msg=f"prompt len {p.size} diverged from solo generation")


def test_insert_never_clobbers_live_slot(granite):
    arch, cfg, params = granite
    eng = ContinuousEngine(arch, params, n_slots=2, cache_len=16,
                           use_smoke=True)
    rng = np.random.default_rng(0)
    p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    s0 = eng.insert(p(4), 8, rid=0)
    with pytest.raises(RuntimeError, match="clobber"):
        eng.insert(p(4), 8, rid=1, slot=s0)
    eng.insert(p(5), 8, rid=1)
    with pytest.raises(RuntimeError, match="free slot"):
        eng.insert(p(3), 8, rid=2)
    # invalid requests are rejected before touching the pool
    with pytest.raises(ValueError, match="non-empty"):
        eng.insert(np.zeros(0, np.int32), 4)
    with pytest.raises(ValueError, match="cache_len"):
        eng.insert(p(8), 99)
    assert eng.live_slots == [0, 1]


def test_evict_frees_exactly_one_slot(granite):
    arch, cfg, params = granite
    eng = ContinuousEngine(arch, params, n_slots=3, cache_len=16,
                           use_smoke=True)
    rng = np.random.default_rng(1)
    for r in range(3):
        eng.insert(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                   8, rid=r)
    before = {i: eng.slots[i].rid for i in eng.live_slots}
    eng.evict(1)
    assert eng.free_slots == [1]
    assert {i: eng.slots[i].rid for i in eng.live_slots} == \
        {i: r for i, r in before.items() if i != 1}
    with pytest.raises(RuntimeError, match="already free"):
        eng.evict(1)


def test_decode_bit_identical_under_concurrent_insert(granite):
    # slot independence: slot 0's tokens must not change when another
    # request is prefilled+inserted into slot 1 mid-decode
    arch, cfg, params = granite
    rng = np.random.default_rng(2)
    pa = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, 3).astype(np.int32)

    alone = ContinuousEngine(arch, params, n_slots=2, cache_len=16,
                             use_smoke=True)
    alone.insert(pa, 8, rid=0)
    ref = None
    while ref is None:
        for f in alone.step():
            if f.rid == 0:
                ref = f.tokens

    shared = ContinuousEngine(arch, params, n_slots=2, cache_len=16,
                              use_smoke=True)
    shared.insert(pa, 8, rid=0)
    shared.step()                       # slot 0 decodes alone once...
    shared.insert(pb, 8, rid=1)         # ...then a neighbour moves in
    got = {}
    while len(got) < 2:
        for f in shared.step():
            got[f.rid] = f.tokens
    np.testing.assert_array_equal(got[0], ref)


def test_eos_evicts_slot_early(granite):
    arch, cfg, params = granite
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    free = ContinuousEngine(arch, params, n_slots=1, cache_len=16,
                            use_smoke=True)
    free.insert(prompt, 6, rid=0)
    full = None
    while full is None:
        for f in free.step():
            full = f.tokens
    assert full.size == 6 and len(set(full.tolist())) > 1

    eos = int(full[2])                  # a token the run actually emits
    eng = ContinuousEngine(arch, params, n_slots=1, cache_len=16,
                           use_smoke=True, eos_id=eos)
    eng.insert(prompt, 6, rid=0)
    fin = None
    while fin is None:
        for f in eng.step():
            fin = f
    assert fin.reason == "eos"
    assert fin.tokens[-1] == eos and fin.tokens.size == 3
    assert eng.free_slots == [0]        # the slot is immediately reusable


def test_scheduler_interleaves_prefill_with_decode(granite):
    # decoupled queues: with more requests than slots the history must
    # show prefill-inserts *between* decode steps (no drain-then-refill),
    # and never two prefills back to back
    arch, cfg, params = granite
    eng = ContinuousEngine(arch, params, n_slots=2, cache_len=16,
                           use_smoke=True)
    sched = ContinuousScheduler(eng)
    rng = np.random.default_rng(4)
    rids = [sched.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                         m) for m in (6, 3, 5, 2, 4)]
    results = sched.run()
    assert set(results) == set(rids)
    kinds = [h[0] for h in sched.history]
    first_decode = kinds.index("decode")
    assert "prefill" in kinds[first_decode:]
    for a, b in zip(kinds, kinds[1:]):
        assert not (a == "prefill" and b == "prefill")


# ---------------------------------------------------------------------------
# geo router + replica autoscaler (pure host-side — no model involved)
# ---------------------------------------------------------------------------

REPLICAS = [ReplicaSpec(region="us-east", cost_per_unit_hour=3.0),
            ReplicaSpec(region="eu-west", units=2, cost_per_unit_hour=2.0)]


def _events(n=20, seed=5):
    rng = np.random.default_rng(seed)
    evs = [{"op": "observe", "a": "us-east", "b": "eu-west",
            "payload_mb": 4.0, "seconds": 0.32}]
    for rid in range(n):
        evs.append({"op": "route", "rid": rid,
                    "src": ("us-east", "eu-west")[int(rng.integers(2))],
                    "prompt_len": int(rng.integers(8, 128)),
                    "max_new": int(rng.integers(16, 256))})
        if rid >= 3:
            evs.append({"op": "complete", "rid": rid - 3})
    return evs


def test_router_decisions_deterministic_under_seeded_trace():
    evs = _events()
    a = replay_decisions(REPLICAS, "balanced", evs)
    b = replay_decisions(REPLICAS, "balanced", evs)
    assert a == b and len(a) == 20
    # and not degenerate: the balanced objective spreads the load
    assert len({d["chosen"] for d in a}) == 2
    # a duplicate rid is a caller bug, not a silent double-booking
    r = GeoRouter(REPLICAS, mode="balanced")
    r.route(0, "us-east", 16, 32)
    with pytest.raises(ValueError, match="rid 0"):
        r.route(0, "us-east", 16, 32)


def test_router_reroutes_after_link_collapse():
    # belief-driven placement: an idle local replica wins, a queued one
    # spills over the healthy link, and after ONE collapsed transfer the
    # cliff-snap reprices the link and us-east traffic stays home even
    # though the local queue is still there
    r = GeoRouter(REPLICAS, mode="balanced")
    r.observe_transfer("us-east", "eu-west", payload_mb=4.0, seconds=0.32)
    assert r.route(0, "us-east", 64, 256) == "us-east"   # idle, local
    assert r.route(1, "us-east", 64, 256) == "eu-west"   # queue spill
    r.observe_transfer("us-east", "eu-west", payload_mb=4.0, seconds=320.0)
    assert r.route(2, "us-east", 64, 256) == "us-east"   # rerouted home
    d1, d2 = r.decisions[1], r.decisions[2]
    assert d2["scores"]["eu-west"]["net_s"] > \
        100 * d1["scores"]["eu-west"]["net_s"]


def test_serving_autoscaler_hysteresis():
    ctrl = ServingElasticityController(replicas=1, max_replicas=4,
                                       target_rps_per_replica=4.0,
                                       hysteresis=2)
    up = ctrl.handle(CloudEvent("load_changed", time_s=0.0, rps=10.0))
    assert ctrl.replicas == 3 and not up.is_noop      # immediate scale-up
    hold = ctrl.handle(CloudEvent("load_changed", time_s=1.0, rps=2.0))
    assert hold.is_noop and ctrl.replicas == 3        # calm streak 1 of 2
    down = ctrl.handle(CloudEvent("load_changed", time_s=2.0, rps=2.0))
    assert ctrl.replicas == 1 and down.new_replicas == 1
    with pytest.raises(ValueError, match="rps"):
        ctrl.handle(CloudEvent("load_changed", time_s=3.0))


def test_load_events_never_reach_training_controller():
    # two planes, one bus: the load_changed kind is partitioned away from
    # the training controllers' subscription
    assert "load_changed" not in TRAINING_EVENT_KINDS
    bus = EventBus()
    ctrl = ServingElasticityController(replicas=1, max_replicas=2, bus=bus)
    seen = []
    for kind in TRAINING_EVENT_KINDS:
        bus.subscribe(kind, seen.append)
    bus.publish(CloudEvent("load_changed", time_s=0.0, rps=9.0))
    assert ctrl.replicas == 2 and seen == []
