"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.sync import SyncConfig, apply_sync, init_sync_state
from repro.kernels import ref
from repro.models.layers import rmsnorm, rmsnorm_init, _softcap
from repro.models.ssm import ssd_chunked
from repro.models.transformer import softmax_cross_entropy

_f32 = st.floats(-3.0, 3.0, allow_nan=False, width=32)


def _arr(draw, shape, elements=_f32):
    return jnp.asarray(
        draw(st.lists(elements, min_size=int(np.prod(shape)),
                      max_size=int(np.prod(shape))))).reshape(shape)


# ------------------------------------------------------------- sync algebra


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 1000))
def test_sma_preserves_parameter_mean(n_pods, seed):
    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.normal(size=(n_pods, 3, 2)), jnp.float32)}
    cfg = SyncConfig("sma", 2)
    out, _ = apply_sync(cfg, p, init_sync_state(cfg, p))
    np.testing.assert_allclose(np.mean(np.asarray(out["w"]), 0),
                               np.mean(np.asarray(p["w"]), 0), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 1000))
def test_ama_preserves_parameter_mean(n_pods, seed):
    """Gossip (pairwise ring) averaging conserves the global mean exactly."""
    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.normal(size=(n_pods, 4)), jnp.float32)}
    cfg = SyncConfig("ama", 2)
    out, _ = apply_sync(cfg, p, init_sync_state(cfg, p))
    np.testing.assert_allclose(np.mean(np.asarray(out["w"]), 0),
                               np.mean(np.asarray(p["w"]), 0), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 5), st.integers(0, 100))
def test_repeated_ama_converges_to_consensus_iff_coprime(n_pods, shift, seed):
    """Gossip averaging mixes to consensus exactly when gcd(shift, n) == 1
    (otherwise the ring decomposes into disjoint subrings) — the topology
    constraint the control-plane communicator must respect."""
    import math
    shift = shift % n_pods or 1
    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.normal(size=(n_pods, 2)), jnp.float32)}
    cfg = SyncConfig("ama", 1, peer_shift=shift)
    st_ = init_sync_state(cfg, p)
    for _ in range(80):
        p, st_ = apply_sync(cfg, p, st_)
    spread = float(np.asarray(p["w"]).std(axis=0).max())
    if math.gcd(shift, n_pods) == 1:
        assert spread < 1e-2
    # with gcd > 1 the subring means may legitimately differ; no assertion


# ----------------------------------------------------------------- numerics


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_ce_matches_naive_softmax(seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 3, 8)) * 3, jnp.float32)
    labels = jnp.asarray(rng.integers(0, 8, size=(2, 3)), jnp.int32)
    ours = float(softmax_cross_entropy(logits, labels))
    p = jax.nn.softmax(logits, -1)
    naive = float(-jnp.mean(jnp.log(
        jnp.take_along_axis(p, labels[..., None], -1)[..., 0] + 1e-30)))
    assert abs(ours - naive) < 1e-3


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_rmsnorm_scale_invariance(seed):
    """RMSNorm(c*x) == RMSNorm(x) for any positive scalar c."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 5, 16)) + 0.1, jnp.float32)
    params = rmsnorm_init(16, jnp.float32)
    c = float(rng.uniform(0.1, 10.0))
    np.testing.assert_allclose(np.asarray(rmsnorm(params, x * c)),
                               np.asarray(rmsnorm(params, x)),
                               atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.floats(1.0, 100.0), st.integers(0, 1000))
def test_softcap_bounded_and_monotone(cap, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.sort(rng.normal(size=32) * 100), jnp.float32)
    y = np.asarray(_softcap(x, cap))
    assert np.all(np.abs(y) <= cap + 1e-5)
    assert np.all(np.diff(y) >= -1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_ssd_linear_in_x(seed):
    """SSD output is linear in x for fixed (a, B, C)."""
    rng = np.random.default_rng(seed)
    shape = (1, 32, 2, 4)
    x1 = jnp.asarray(rng.normal(size=shape), jnp.float32)
    x2 = jnp.asarray(rng.normal(size=shape), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.normal(size=(1, 32, 2)), jnp.float32)) * 0.1
    Bm = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    y1, _ = ssd_chunked(x1, a, Bm, Cm, chunk=8)
    y2, _ = ssd_chunked(x2, a, Bm, Cm, chunk=8)
    y12, _ = ssd_chunked(2.0 * x1 + x2, a, Bm, Cm, chunk=8)
    np.testing.assert_allclose(np.asarray(y12), np.asarray(2 * y1 + y2),
                               atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(16, 512), st.integers(1, 32), st.integers(0, 1000))
def test_topk_energy_never_exceeds_exact(n, k, seed):
    k = min(k, n)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    db = ref.topk_decompress(*ref.topk_block(x, k, block=64), n)
    de = ref.topk_decompress(*ref.topk_exact(x, k), n)
    assert float(jnp.sum(db ** 2)) <= float(jnp.sum(de ** 2)) + 1e-5
    # decompressed entries are a subset of x's entries
    d = np.asarray(db)
    xs = np.asarray(x)
    nz = d != 0
    np.testing.assert_allclose(d[nz], xs[nz])
