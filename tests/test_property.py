"""Hypothesis property tests on system invariants.

The whole module is skipped cleanly when hypothesis is not installed (it is
an optional extra — ``pip install -e '.[property]'``), so the tier-1 command
collects without it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (CloudResources, load_power,
                                  optimal_matching, plan_batch_split)
from repro.core.sync import SyncConfig, apply_sync, init_sync_state
from repro.kernels import ref
from repro.models.layers import rmsnorm, rmsnorm_init, _softcap
from repro.models.ssm import ssd_chunked
from repro.models.transformer import softmax_cross_entropy

_f32 = st.floats(-3.0, 3.0, allow_nan=False, width=32)


def _arr(draw, shape, elements=_f32):
    return jnp.asarray(
        draw(st.lists(elements, min_size=int(np.prod(shape)),
                      max_size=int(np.prod(shape))))).reshape(shape)


# ------------------------------------------------------------- sync algebra


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 1000))
def test_sma_preserves_parameter_mean(n_pods, seed):
    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.normal(size=(n_pods, 3, 2)), jnp.float32)}
    cfg = SyncConfig("sma", 2)
    out, _ = apply_sync(cfg, p, init_sync_state(cfg, p))
    np.testing.assert_allclose(np.mean(np.asarray(out["w"]), 0),
                               np.mean(np.asarray(p["w"]), 0), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(0, 1000))
def test_ama_preserves_parameter_mean(n_pods, seed):
    """Gossip (pairwise ring) averaging conserves the global mean exactly."""
    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.normal(size=(n_pods, 4)), jnp.float32)}
    cfg = SyncConfig("ama", 2)
    out, _ = apply_sync(cfg, p, init_sync_state(cfg, p))
    np.testing.assert_allclose(np.mean(np.asarray(out["w"]), 0),
                               np.mean(np.asarray(p["w"]), 0), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 5), st.integers(0, 100))
def test_repeated_ama_converges_to_consensus_iff_coprime(n_pods, shift, seed):
    """Gossip averaging mixes to consensus exactly when gcd(shift, n) == 1
    (otherwise the ring decomposes into disjoint subrings) — the topology
    constraint the control-plane communicator must respect."""
    import math
    shift = shift % n_pods or 1
    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.normal(size=(n_pods, 2)), jnp.float32)}
    cfg = SyncConfig("ama", 1, peer_shift=shift)
    st_ = init_sync_state(cfg, p)
    for _ in range(80):
        p, st_ = apply_sync(cfg, p, st_)
    spread = float(np.asarray(p["w"]).std(axis=0).max())
    if math.gcd(shift, n_pods) == 1:
        assert spread < 1e-2
    # with gcd > 1 the subring means may legitimately differ; no assertion


# ----------------------------------------------------------------- numerics


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_ce_matches_naive_softmax(seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 3, 8)) * 3, jnp.float32)
    labels = jnp.asarray(rng.integers(0, 8, size=(2, 3)), jnp.int32)
    ours = float(softmax_cross_entropy(logits, labels))
    p = jax.nn.softmax(logits, -1)
    naive = float(-jnp.mean(jnp.log(
        jnp.take_along_axis(p, labels[..., None], -1)[..., 0] + 1e-30)))
    assert abs(ours - naive) < 1e-3


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_rmsnorm_scale_invariance(seed):
    """RMSNorm(c*x) == RMSNorm(x) for any positive scalar c."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 5, 16)) + 0.1, jnp.float32)
    params = rmsnorm_init(16, jnp.float32)
    c = float(rng.uniform(0.1, 10.0))
    np.testing.assert_allclose(np.asarray(rmsnorm(params, x * c)),
                               np.asarray(rmsnorm(params, x)),
                               atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.floats(1.0, 100.0), st.integers(0, 1000))
def test_softcap_bounded_and_monotone(cap, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.sort(rng.normal(size=32) * 100), jnp.float32)
    y = np.asarray(_softcap(x, cap))
    assert np.all(np.abs(y) <= cap + 1e-5)
    assert np.all(np.diff(y) >= -1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_ssd_linear_in_x(seed):
    """SSD output is linear in x for fixed (a, B, C)."""
    rng = np.random.default_rng(seed)
    shape = (1, 32, 2, 4)
    x1 = jnp.asarray(rng.normal(size=shape), jnp.float32)
    x2 = jnp.asarray(rng.normal(size=shape), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.normal(size=(1, 32, 2)), jnp.float32)) * 0.1
    Bm = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    y1, _ = ssd_chunked(x1, a, Bm, Cm, chunk=8)
    y2, _ = ssd_chunked(x2, a, Bm, Cm, chunk=8)
    y12, _ = ssd_chunked(2.0 * x1 + x2, a, Bm, Cm, chunk=8)
    np.testing.assert_allclose(np.asarray(y12), np.asarray(2 * y1 + y2),
                               atol=1e-3)


# --------------------------------------------------- scheduler (Algorithm 1)
# (moved from test_scheduler.py so the tier-1 run collects hypothesis-free)

_dev = st.sampled_from(["icelake", "cascade", "skylake", "t4", "v100"])


@st.composite
def _sched_clouds(draw):
    n = draw(st.integers(2, 4))
    out = []
    for i in range(n):
        dev = draw(_dev)
        units = draw(st.integers(1, 6))
        data = draw(st.floats(0.5, 4.0))
        out.append(CloudResources(f"c{i}", ((dev, units),), data_size=data))
    return out


@settings(max_examples=40, deadline=None)
@given(_sched_clouds())
def test_plan_never_exceeds_available(clouds):
    plans = optimal_matching(clouds)
    for c, p in zip(clouds, plans):
        avail = dict(c.devices)
        for dev, n in p.allocation:
            assert 1 <= n <= avail[dev]


@settings(max_examples=40, deadline=None)
@given(_sched_clouds())
def test_plan_lp_at_least_straggler(clouds):
    """No planned cloud becomes a worse straggler than the reference."""
    full = [load_power(c.devices, c.data_size) for c in clouds]
    ref_lp = min(full)
    plans = optimal_matching(clouds)
    for p in plans:
        assert p.load_power >= ref_lp - 1e-9


@settings(max_examples=40, deadline=None)
@given(_sched_clouds())
def test_plan_weakly_reduces_units(clouds):
    plans = optimal_matching(clouds)
    for c, p in zip(clouds, plans):
        assert p.units <= sum(n for _, n in c.devices)


@settings(max_examples=40, deadline=None)
@given(_sched_clouds())
def test_straggler_keeps_full_allocation(clouds):
    full = [load_power(c.devices, c.data_size) for c in clouds]
    i = full.index(min(full))
    plans = optimal_matching(clouds)
    assert plans[i].allocation == clouds[i].devices


@settings(max_examples=40, deadline=None)
@given(_sched_clouds())
def test_incremental_matching_equals_full(clouds):
    """The elasticity engine's incremental path is output-identical to a
    fresh Algorithm 1 run, whatever previous plan it is given."""
    from repro.core.scheduler import incremental_matching
    fresh = optimal_matching(clouds)
    # warm-start from a plan for a perturbed picture (first cloud removed)
    prev = optimal_matching(clouds[1:]) if len(clouds) > 1 else None
    inc = incremental_matching(clouds, prev=prev)
    assert [p.allocation for p in inc] == [p.allocation for p in fresh]
    # warm-start from the exact same picture reuses everything
    inc2 = incremental_matching(clouds, prev=fresh)
    assert [p.allocation for p in inc2] == [p.allocation for p in fresh]


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 512), st.lists(st.floats(0.1, 10.0), min_size=2,
                                     max_size=8))
def test_batch_split_sums_and_positive(batch, powers):
    if batch < len(powers):
        batch = len(powers)
    split = plan_batch_split(batch, powers)
    assert sum(split) == batch
    assert all(s >= 1 for s in split)


@settings(max_examples=20, deadline=None)
@given(st.integers(16, 512), st.integers(1, 32), st.integers(0, 1000))
def test_topk_energy_never_exceeds_exact(n, k, seed):
    k = min(k, n)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    db = ref.topk_decompress(*ref.topk_block(x, k, block=64), n)
    de = ref.topk_decompress(*ref.topk_exact(x, k), n)
    assert float(jnp.sum(db ** 2)) <= float(jnp.sum(de ** 2)) + 1e-5
    # decompressed entries are a subset of x's entries
    d = np.asarray(db)
    xs = np.asarray(x)
    nz = d != 0
    np.testing.assert_allclose(d[nz], xs[nz])


# ----------------------------------------------- checkpoint save/restore


def _random_tree(n_pods, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == "int32":
        def leaf(*shape):
            return jnp.asarray(rng.integers(-1000, 1000,
                                            size=(n_pods,) + shape),
                               jnp.int32)
    else:
        def leaf(*shape):
            return jnp.asarray(rng.normal(size=(n_pods,) + shape),
                               getattr(jnp, dtype))
    return {"w": leaf(4, 3), "nested": {"m": leaf(4, 3), "v": leaf(2)},
            "b": leaf(5)}


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5),
       st.sampled_from(["float32", "bfloat16", "int32"]),
       st.integers(0, 10_000))
def test_checkpoint_save_restore_identity(n_pods, dtype, seed):
    """save -> restore is the identity for every dtype and pod count —
    bf16 rides through the fp32 upcast losslessly and comes back bf16."""
    import shutil
    import tempfile

    from repro.checkpoint import checkpoint as ckpt

    tree = _random_tree(n_pods, dtype, seed)
    d = tempfile.mkdtemp(prefix="ckpt_prop_")
    try:
        ckpt.save(d, tree, step=seed)
        out, step = ckpt.restore(d, jax.tree.map(jnp.zeros_like, tree))
        assert step == seed
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    finally:
        shutil.rmtree(d, ignore_errors=True)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4),
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 10_000))
def test_async_snapshot_equals_blocking_save(n_pods, dtype, seed):
    """An engine snapshot commits exactly what a blocking save of the same
    tree at the same step writes: restored trees are bit-identical and the
    manifests agree on keys/dtypes/shapes/step."""
    import shutil
    import tempfile

    from repro.checkpoint import checkpoint as ckpt
    from repro.checkpoint.async_engine import (AsyncCheckpointEngine,
                                               blocking_equivalent)

    tree = _random_tree(n_pods, dtype, seed)
    root = tempfile.mkdtemp(prefix="ckpt_async_prop_")
    try:
        eng = AsyncCheckpointEngine(f"{root}/a", keep=1)
        eng.snapshot(tree, seed)
        eng.wait()
        _, apath = eng.last_durable()
        bpath = blocking_equivalent(tree, seed, f"{root}/b")
        like = jax.tree.map(jnp.zeros_like, tree)
        a, astep = ckpt.restore(apath, like)
        b, bstep = ckpt.restore(bpath, like)
        assert astep == bstep == seed
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
        ma, mb = ckpt.load_manifest(apath), ckpt.load_manifest(bpath)
        assert all(ma[k] == mb[k]
                   for k in ("keys", "dtypes", "shapes", "step"))
        eng.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(0, 10_000))
def test_checkpoint_pod_resize_mean_preserves_global_mean(n_old, n_new,
                                                          seed):
    """restore(pod_resize="mean") preserves the global parameter mean for
    every (n_old -> n_new) transition — the invariant live migration and
    pause-and-restore both inherit from the same transform."""
    import shutil
    import tempfile

    from repro.checkpoint import checkpoint as ckpt

    tree = _random_tree(n_old, "float32", seed)
    d = tempfile.mkdtemp(prefix="ckpt_resize_prop_")
    try:
        ckpt.save(d, tree, step=0)
        like = jax.tree.map(
            lambda x: jnp.zeros((n_new,) + x.shape[1:], x.dtype), tree)
        out, _ = ckpt.restore(d, like, pod_resize="mean")
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_allclose(
                np.asarray(b, np.float32).mean(axis=0),
                np.asarray(a, np.float32).mean(axis=0),
                rtol=2e-5, atol=2e-6)
    finally:
        shutil.rmtree(d, ignore_errors=True)
