"""Multi-device integration tests, run in SUBPROCESSES so the fake-device
XLA flag never leaks into the main test process (smoke tests must see the
1 real CPU device).

Verifies on an 8-device (2 pods x 2 data x 2 model) debug mesh that:
- the stacked-pod train step lowers, compiles AND EXECUTES with the real
  sharding rules;
- the sync step emits pod-axis collectives (collective-permute for the
  one-peer ring / all-reduce for SMA) — the paper's WAN round on ICI;
- executed multi-device training is numerically identical to the
  single-device pod emulation.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_PRELUDE = """
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch
from repro.core.sync import SyncConfig
from repro.launch import context as C
from repro.launch.shapes import InputShape, train_batch_specs
from repro.sharding.rules import axis_rules
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
"""


@pytest.mark.parametrize("arch_name,strategy", [
    ("granite-8b", "ama"),
    ("qwen3-moe-30b-a3b", "asgd_ga"),
    ("mamba2-1.3b", "sma"),
])
def test_debug_mesh_train_and_sync_execute(arch_name, strategy):
    code = _PRELUDE + textwrap.dedent(f"""
    import dataclasses
    from repro.launch import shapes as S
    arch = get_arch("{arch_name}")
    setup = C.make_train_setup(arch, mesh, sync=SyncConfig("{strategy}", 2),
                               optimizer="sgd", smoke=True)
    shape = InputShape("dbg", 32, 8, "train")
    smoke_arch = dataclasses.replace(arch, config=setup.cfg)
    bspecs = S.train_batch_specs(smoke_arch, shape, 2)
    bshard = C.batch_sharding(bspecs, mesh, setup.rules, stacked=True)

    with axis_rules(setup.rules, mesh):
        jf = jax.jit(setup.trainer._train_step_impl,
                     in_shardings=(setup.state_sharding, bshard),
                     out_shardings=(setup.state_sharding, None))
        js = jax.jit(setup.trainer._sync_step_impl,
                     in_shardings=(setup.state_sharding,),
                     out_shardings=setup.state_sharding)
        with mesh:
            state = jax.jit(setup.trainer.init_state,
                            out_shardings=setup.state_sharding
                            )(jax.random.key(0))
            batch = {{k: jax.device_put(
                jax.random.randint(jax.random.key(1), v.shape, 0, 64)
                if v.dtype == jnp.int32 else
                jax.random.normal(jax.random.key(1), v.shape) * 0.1,
                bshard[k]) for k, v in bspecs.items()}}
            state2, metrics = jf(state, batch)
            hlo = js.lower(state2).compile().as_text()
            state3 = js(state2)
    loss = float(metrics["loss"])
    print(json.dumps({{
        "loss_finite": bool(np.isfinite(loss)),
        "step": int(state2.step),
        "permutes": hlo.count("collective-permute"),
        "all_reduces": hlo.count("all-reduce"),
        "params_finite": all(bool(jnp.isfinite(x).all())
                             for x in jax.tree.leaves(state3.params)),
    }}))
    """)
    res = _run(code)
    assert res["loss_finite"] and res["params_finite"]
    assert res["step"] == 1
    if strategy in ("ama", "asgd_ga"):
        assert res["permutes"] > 0, "ring send must lower to collective-permute"
    else:
        assert res["all_reduces"] > 0, "SMA must lower to all-reduce"


def test_multi_device_matches_single_device_emulation():
    """The 8-device sharded execution computes the same training trajectory
    as the single-device stacked emulation (same seeds, same batches)."""
    code = _PRELUDE + textwrap.dedent("""
    import dataclasses
    from repro.launch import shapes as S
    arch = get_arch("granite-8b")
    setup = C.make_train_setup(arch, mesh, sync=SyncConfig("ama", 2),
                               optimizer="sgd", lr=0.05, smoke=True)
    smoke_arch = dataclasses.replace(arch, config=setup.cfg)
    shape = InputShape("dbg", 16, 8, "train")
    bspecs = S.train_batch_specs(smoke_arch, shape, 2)
    bshard = C.batch_sharding(bspecs, mesh, setup.rules, stacked=True)

    def batches(step):
        k = jax.random.key(100 + step)
        return {"tokens": jax.random.randint(k, bspecs["tokens"].shape, 0,
                                             setup.cfg.vocab_size),
                "labels": jax.random.randint(jax.random.fold_in(k, 1),
                                             bspecs["labels"].shape, 0,
                                             setup.cfg.vocab_size)}

    # sharded run
    with axis_rules(setup.rules, mesh):
        jf = jax.jit(setup.trainer._train_step_impl,
                     in_shardings=(setup.state_sharding, bshard),
                     out_shardings=(setup.state_sharding, None))
        js = jax.jit(setup.trainer._sync_step_impl,
                     in_shardings=(setup.state_sharding,),
                     out_shardings=setup.state_sharding)
        with mesh:
            st = jax.jit(setup.trainer.init_state,
                         out_shardings=setup.state_sharding)(jax.random.key(0))
            sharded_losses = []
            for step in range(4):
                st, m = jf(st, batches(step))
                sharded_losses.append(float(m["loss"]))
                if (step + 1) % 2 == 0:
                    st = js(st)

    # plain single-device emulation (same Trainer impl, no shardings)
    st2 = setup.trainer.init_state(jax.random.key(0))
    plain_losses = []
    for step in range(4):
        st2, m = setup.trainer._train_step_impl(st2, batches(step))
        plain_losses.append(float(m["loss"]))
        if (step + 1) % 2 == 0:
            st2 = setup.trainer._sync_step_impl(st2)

    import numpy as np
    print(json.dumps({
        "sharded": sharded_losses, "plain": plain_losses,
        "max_diff": float(np.max(np.abs(np.array(sharded_losses)
                                        - np.array(plain_losses)))),
    }))
    """)
    res = _run(code)
    assert res["max_diff"] < 5e-4, res
