"""Elastic scheduler tests (paper §III.B, Table I/II/IV) + hypothesis
property tests on Algorithm 1's invariants."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (CATALOG, CloudResources, load_power,
                                  optimal_matching, plan_batch_split,
                                  predict_times, waiting_fraction)

# ------------------------------------------------------------- Table I


def test_table1_normalizations():
    """TN / IN / ratio columns of paper Table I."""
    assert CATALOG["icelake"].tn == pytest.approx(1.0)
    assert CATALOG["cascade"].tn == pytest.approx(0.938, abs=0.01)
    assert CATALOG["skylake"].tn == pytest.approx(1.167, abs=0.01)
    assert CATALOG["t4"].tn == pytest.approx(57.854, abs=0.1)
    assert CATALOG["v100"].tn == pytest.approx(139.010, abs=0.1)
    assert CATALOG["cascade"].in_ == pytest.approx(0.666, abs=0.01)
    assert CATALOG["skylake"].in_ == pytest.approx(0.973, abs=0.01)
    assert CATALOG["t4"].in_ == pytest.approx(59.629, abs=0.3)
    assert CATALOG["v100"].in_ == pytest.approx(154.042, abs=0.5)
    assert CATALOG["v100"].in_tn_ratio == pytest.approx(1.108, abs=0.01)


def test_load_power_formula():
    # LP = (sum N*P) / S_data, measured (IN) powers preferred
    lp = load_power((("cascade", 6), ("t4", 1)), data_size=2.0)
    assert lp == pytest.approx((6 * 0.666 + 59.629) / 2.0, rel=1e-2)
    assert load_power((("cascade", 1),), 0.0) == math.inf


# --------------------------------------------------------- Algorithm 1


def _paper_case3():
    sh = CloudResources("sh", (("cascade", 6),), data_size=2.0)
    cq = CloudResources("cq", (("sky", 6),), data_size=1.0)
    return [sh, cq]


def test_optimal_matching_trims_fast_cloud():
    """Paper Table IV case 3 (data 2:1, Cascade vs Sky): the straggler keeps
    its full allocation; the fast region is trimmed."""
    plans = optimal_matching(_paper_case3())
    by = {p.region: p for p in plans}
    assert by["sh"].allocation == (("cascade", 6),)    # straggler untouched
    assert by["cq"].units < 6                          # fast region trimmed
    assert by["cq"].load_power >= by["sh"].load_power - 1e-9


def test_waiting_reduced_by_plan():
    clouds = _paper_case3()
    base = waiting_fraction(predict_times(clouds))
    plan = waiting_fraction(predict_times(clouds, optimal_matching(clouds)))
    assert max(plan.values()) < max(base.values())


def test_even_setup_keeps_everything():
    a = CloudResources("a", (("cascade", 4),), data_size=1.0)
    b = CloudResources("b", (("cascade", 4),), data_size=1.0)
    plans = optimal_matching([a, b])
    assert all(p.allocation == (("cascade", 4),) for p in plans)


# --------------------------------------------------- hypothesis properties

_dev = st.sampled_from(["icelake", "cascade", "skylake", "t4", "v100"])


@st.composite
def _clouds(draw):
    n = draw(st.integers(2, 4))
    out = []
    for i in range(n):
        dev = draw(_dev)
        units = draw(st.integers(1, 6))
        data = draw(st.floats(0.5, 4.0))
        out.append(CloudResources(f"c{i}", ((dev, units),), data_size=data))
    return out


@settings(max_examples=40, deadline=None)
@given(_clouds())
def test_plan_never_exceeds_available(clouds):
    plans = optimal_matching(clouds)
    for c, p in zip(clouds, plans):
        avail = dict(c.devices)
        for dev, n in p.allocation:
            assert 1 <= n <= avail[dev]


@settings(max_examples=40, deadline=None)
@given(_clouds())
def test_plan_lp_at_least_straggler(clouds):
    """No planned cloud becomes a worse straggler than the reference."""
    full = [load_power(c.devices, c.data_size) for c in clouds]
    ref = min(full)
    plans = optimal_matching(clouds)
    for p in plans:
        assert p.load_power >= ref - 1e-9


@settings(max_examples=40, deadline=None)
@given(_clouds())
def test_plan_weakly_reduces_units(clouds):
    plans = optimal_matching(clouds)
    for c, p in zip(clouds, plans):
        assert p.units <= sum(n for _, n in c.devices)


@settings(max_examples=40, deadline=None)
@given(_clouds())
def test_straggler_keeps_full_allocation(clouds):
    full = [load_power(c.devices, c.data_size) for c in clouds]
    i = full.index(min(full))
    plans = optimal_matching(clouds)
    assert plans[i].allocation == clouds[i].devices


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 512), st.lists(st.floats(0.1, 10.0), min_size=2,
                                     max_size=8))
def test_batch_split_sums_and_positive(batch, powers):
    if batch < len(powers):
        batch = len(powers)
    split = plan_batch_split(batch, powers)
    assert sum(split) == batch
    assert all(s >= 1 for s in split)


def test_batch_split_proportional():
    split = plan_batch_split(90, [2.0, 1.0])
    assert split == [60, 30]
