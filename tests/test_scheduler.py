"""Elastic scheduler tests (paper §III.B, Table I/II/IV).

The hypothesis property tests on Algorithm 1's invariants live in
test_property.py (optional dependency, guarded with importorskip).
"""
import math

import pytest

from repro.core.scheduler import (CATALOG, CloudResources, diff_plans,
                                  incremental_matching, load_power,
                                  optimal_matching, plan_batch_split,
                                  predict_times, waiting_fraction)

# ------------------------------------------------------------- Table I


def test_table1_normalizations():
    """TN / IN / ratio columns of paper Table I."""
    assert CATALOG["icelake"].tn == pytest.approx(1.0)
    assert CATALOG["cascade"].tn == pytest.approx(0.938, abs=0.01)
    assert CATALOG["skylake"].tn == pytest.approx(1.167, abs=0.01)
    assert CATALOG["t4"].tn == pytest.approx(57.854, abs=0.1)
    assert CATALOG["v100"].tn == pytest.approx(139.010, abs=0.1)
    assert CATALOG["cascade"].in_ == pytest.approx(0.666, abs=0.01)
    assert CATALOG["skylake"].in_ == pytest.approx(0.973, abs=0.01)
    assert CATALOG["t4"].in_ == pytest.approx(59.629, abs=0.3)
    assert CATALOG["v100"].in_ == pytest.approx(154.042, abs=0.5)
    assert CATALOG["v100"].in_tn_ratio == pytest.approx(1.108, abs=0.01)


def test_load_power_formula():
    # LP = (sum N*P) / S_data, measured (IN) powers preferred
    lp = load_power((("cascade", 6), ("t4", 1)), data_size=2.0)
    assert lp == pytest.approx((6 * 0.666 + 59.629) / 2.0, rel=1e-2)
    assert load_power((("cascade", 1),), 0.0) == math.inf


# --------------------------------------------------------- Algorithm 1


def _paper_case3():
    sh = CloudResources("sh", (("cascade", 6),), data_size=2.0)
    cq = CloudResources("cq", (("sky", 6),), data_size=1.0)
    return [sh, cq]


def test_optimal_matching_trims_fast_cloud():
    """Paper Table IV case 3 (data 2:1, Cascade vs Sky): the straggler keeps
    its full allocation; the fast region is trimmed."""
    plans = optimal_matching(_paper_case3())
    by = {p.region: p for p in plans}
    assert by["sh"].allocation == (("cascade", 6),)    # straggler untouched
    assert by["cq"].units < 6                          # fast region trimmed
    assert by["cq"].load_power >= by["sh"].load_power - 1e-9


def test_waiting_reduced_by_plan():
    clouds = _paper_case3()
    base = waiting_fraction(predict_times(clouds))
    plan = waiting_fraction(predict_times(clouds, optimal_matching(clouds)))
    assert max(plan.values()) < max(base.values())


def test_even_setup_keeps_everything():
    a = CloudResources("a", (("cascade", 4),), data_size=1.0)
    b = CloudResources("b", (("cascade", 4),), data_size=1.0)
    plans = optimal_matching([a, b])
    assert all(p.allocation == (("cascade", 4),) for p in plans)


def test_batch_split_proportional():
    split = plan_batch_split(90, [2.0, 1.0])
    assert split == [60, 30]


# ------------------------------------------- incremental re-matching + diff


def test_incremental_matching_reuses_unchanged_clouds():
    clouds = _paper_case3()
    fresh = optimal_matching(clouds)
    inc = incremental_matching(clouds, prev=fresh)
    assert [p.allocation for p in inc] == [p.allocation for p in fresh]
    assert diff_plans(fresh, inc).is_empty


def test_incremental_matching_after_departure():
    sh, cq = _paper_case3()
    bj = CloudResources("bj", (("sky", 3),), data_size=1.0)
    before = optimal_matching([sh, cq, bj])
    after = incremental_matching([sh, cq], prev=before)
    assert [p.allocation for p in after] == \
        [p.allocation for p in optimal_matching([sh, cq])]
    d = diff_plans(before, after)
    assert d.removed == ("bj",) and not d.added


def test_diff_plans_reports_resizes():
    a = optimal_matching(_paper_case3())
    sh2 = CloudResources("sh", (("cascade", 3),), data_size=2.0)
    b = incremental_matching([sh2, _paper_case3()[1]], prev=a)
    d = diff_plans(a, b)
    assert any(r[0] == "sh" for r in d.resized)
    assert "no-op" not in d.summary()
