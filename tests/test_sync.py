"""Synchronization-strategy semantics + convergence (paper §III.C, Figs 7/10).

Runs the real SPMD code path (stacked pod dim) as a faithful multi-cloud
emulation on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sync as S
from repro.core.sync import SyncConfig, apply_sync, init_sync_state, \
    is_sync_step, on_step_gradients
from repro.data.pipeline import GeoDataset, synthetic_classification
from repro.models.reference import PAPER_MODELS
from repro.training.trainer import Trainer, TrainerConfig, accuracy_eval, \
    stack_pod_batches


def _tree(n_pods, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n_pods, 4, 3)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n_pods, 3)), jnp.float32)}


# ---------------------------------------------------------------- unit-level


def test_asgd_baseline_is_cross_pod_mean():
    g = _tree(4)
    st = init_sync_state(SyncConfig("asgd"), g)
    out, _ = on_step_gradients(SyncConfig("asgd"), g, st)
    for k in g:
        np.testing.assert_allclose(
            np.asarray(out[k]),
            np.broadcast_to(np.mean(np.asarray(g[k]), 0, keepdims=True),
                            g[k].shape), rtol=1e-6)


def test_sma_is_global_average():
    p = _tree(3)
    cfg = SyncConfig("sma", 4)
    st = init_sync_state(cfg, p)
    out, _ = apply_sync(cfg, p, st)
    for k in p:
        np.testing.assert_allclose(
            np.asarray(out[k]),
            np.broadcast_to(np.mean(np.asarray(p[k]), 0, keepdims=True),
                            p[k].shape), rtol=1e-6)


def test_ama_is_pairwise_with_one_ring_peer():
    p = _tree(4)
    cfg = SyncConfig("ama", 4)
    out, _ = apply_sync(cfg, p, init_sync_state(cfg, p))
    for k in p:
        expect = 0.5 * (np.asarray(p[k]) + np.roll(np.asarray(p[k]), 1, 0))
        np.testing.assert_allclose(np.asarray(out[k]), expect, rtol=1e-6)


def test_asgd_ga_accumulates_and_ships_to_one_peer():
    cfg = SyncConfig("asgd_ga", interval=2)
    p = _tree(2, seed=1)
    st = init_sync_state(cfg, p)
    g1, g2 = _tree(2, seed=2), _tree(2, seed=3)
    _, st = on_step_gradients(cfg, g1, st)
    _, st = on_step_gradients(cfg, g2, st)
    assert int(st.steps_since_sync) == 2
    out, st2 = apply_sync(cfg, p, st, lr=0.1)
    for k in p:
        acc = (np.asarray(g1[k]) + np.asarray(g2[k])) / 2.0
        peer = np.roll(acc, 1, axis=0)       # receive from previous pod
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(p[k]) - 0.1 * peer, rtol=1e-5)
    # buffer reset
    assert all(float(jnp.abs(x).max()) == 0.0
               for x in jax.tree.leaves(st2.ga_buffer))
    assert int(st2.steps_since_sync) == 0


def test_single_pod_sync_is_identity():
    for strat in S.STRATEGIES:
        cfg = SyncConfig(strat, 4)
        p = _tree(1)
        out, _ = apply_sync(cfg, p, init_sync_state(cfg, p))
        for k in p:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(p[k]))


def test_is_sync_step_schedule():
    cfg = SyncConfig("ama", 4)
    assert [is_sync_step(cfg, s) for s in range(8)] == \
        [False, False, False, True] * 2
    assert not any(is_sync_step(SyncConfig("asgd"), s) for s in range(8))


def test_traffic_model():
    assert S.traffic_per_step_mb(SyncConfig("asgd"), 48.0) == 48.0
    assert S.traffic_per_step_mb(SyncConfig("ama", 8), 48.0) == 6.0
    c = SyncConfig("asgd_ga", 8, compress_topk=0.01)
    assert S.traffic_per_step_mb(c, 48.0) == pytest.approx(48 * 0.02 / 8)


def test_topk_compressed_shipping_approximates_dense():
    cfg_d = SyncConfig("asgd_ga", 1)
    cfg_c = SyncConfig("asgd_ga", 1, compress_topk=0.5)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(2, 64, 8)), jnp.float32)}
    p = jax.tree.map(jnp.zeros_like, g)
    std = init_sync_state(cfg_d, p)
    _, std = on_step_gradients(cfg_d, g, std)
    dense, _ = apply_sync(cfg_d, p, std, lr=1.0)
    stc = init_sync_state(cfg_c, p)
    _, stc = on_step_gradients(cfg_c, g, stc)
    comp, _ = apply_sync(cfg_c, p, stc, lr=1.0)
    # compressed update preserves the largest-magnitude half of the energy
    e_d = float(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(dense)))
    e_c = float(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(comp)))
    assert 0.5 < e_c / e_d <= 1.0


# ----------------------------------------------------------- convergence


@pytest.mark.parametrize("strat,interval", [
    ("asgd", 1), ("asgd_ga", 4), ("ama", 4), ("sma", 4)])
def test_convergence_parity_lenet(strat, interval):
    """Paper Fig 7/10(d-f): all strategies reach baseline-level accuracy with
    SGD (the paper's optimizer) on 2 uneven clouds."""
    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(1500, m["input_shape"], m["n_classes"],
                                    seed=0)
    test = synthetic_classification(400, m["input_shape"], m["n_classes"],
                                    seed=1)
    geo = GeoDataset.partition(data, ["sh", "cq"], [2, 1])
    loaders = [geo.loader("sh", 32, seed=0), geo.loader("cq", 32, seed=1)]

    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=SyncConfig(strat, interval)))
    st = tr.init_state(jax.random.key(0))
    st, hist = tr.fit(st, lambda s: stack_pod_batches([next(l) for l in loaders]),
                      120, eval_fn=accuracy_eval(m["apply"], test),
                      eval_every=120)
    acc = hist["eval"][-1][1]
    assert acc > 0.9, (strat, acc)
    assert hist["loss"][-1] < hist["loss"][0] * 0.5


def test_pods_stay_identical_under_asgd():
    """Baseline per-step all-reduce keeps pod replicas bit-identical."""
    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(256, m["input_shape"], m["n_classes"])
    geo = GeoDataset.partition(data, ["a", "b"], [1, 1])
    loaders = [geo.loader("a", 16, seed=0), geo.loader("b", 16, seed=1)]
    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=SyncConfig("asgd", 1)))
    st = tr.init_state(jax.random.key(0))
    for step in range(5):
        st, _ = tr.train_step(st, stack_pod_batches([next(l) for l in loaders]))
    for leaf in jax.tree.leaves(st.params):
        np.testing.assert_array_equal(np.asarray(leaf[0]), np.asarray(leaf[1]))


def test_pods_diverge_then_sma_reconverges():
    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(256, m["input_shape"], m["n_classes"])
    geo = GeoDataset.partition(data, ["a", "b"], [1, 1])
    loaders = [geo.loader("a", 16, seed=0), geo.loader("b", 16, seed=1)]
    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=SyncConfig("sma", 4)))
    st = tr.init_state(jax.random.key(0))
    for step in range(3):
        st, _ = tr.train_step(st, stack_pod_batches([next(l) for l in loaders]))
    # diverged between syncs
    w = jax.tree.leaves(st.params)[0]
    assert float(jnp.abs(w[0] - w[1]).max()) > 0
    st = tr._sync_step(st)
    for leaf in jax.tree.leaves(st.params):
        np.testing.assert_array_equal(np.asarray(leaf[0]), np.asarray(leaf[1]))


def test_asp_significance_gating_and_convergence():
    """Gaia-style ASP baseline: converges to parity while shipping only the
    significant fraction of parameter deltas."""
    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(1200, m["input_shape"], m["n_classes"],
                                    seed=0)
    test = synthetic_classification(400, m["input_shape"], m["n_classes"],
                                    seed=1)
    geo = GeoDataset.partition(data, ["a", "b"], [2, 1])
    loaders = [geo.loader("a", 32, seed=0), geo.loader("b", 32, seed=1)]
    tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=SyncConfig("asp", 4, asp_threshold=0.02)))
    st = tr.init_state(jax.random.key(0))
    fracs = []
    for step in range(120):
        st, _ = tr.train_step(
            st, stack_pod_batches([next(l) for l in loaders]))
        if is_sync_step(tr.cfg.sync, step):
            st = tr._sync_step(st)
            fracs.append(float(st.sync_state.significant_frac))
    acc = accuracy_eval(m["apply"], test)(st)
    assert acc > 0.9, acc
    # significance filter actually filters (and late-training deltas shrink)
    assert 0.0 < np.mean(fracs) < 1.0
    assert fracs[-1] <= fracs[0] + 1e-6
