"""Required per-architecture smoke tests: a REDUCED variant of each assigned
family runs one forward + one train step + one decode step on CPU, asserting
output shapes and the absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.core.sync import SyncConfig
from repro.models.registry import get_model_fns
from repro.training.trainer import Trainer, TrainerConfig

B, S = 2, 32


def _batch(arch, cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if arch.module == "encdec":
        batch["audio_emb"] = jax.random.normal(
            k2, (B, cfg.encoder_ctx, cfg.d_model)) * 0.1
    if cfg.vision_patches:
        batch["patch_emb"] = jax.random.normal(
            k3, (B, cfg.vision_patches, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", ARCH_IDS)
def test_smoke_forward_and_shapes(name):
    arch = get_arch(name)
    cfg = arch.smoke
    assert cfg.n_layers <= 2 * cfg.period and cfg.d_model <= 512
    if cfg.has_moe:
        assert cfg.moe.num_experts <= 4
    fns = get_model_fns(arch.module)
    params = fns.init_params(jax.random.key(0), cfg)

    # analytic parameter count must match the actual tree exactly
    if arch.module == "transformer":
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert actual == cfg.param_count()

    batch = _batch(arch, cfg, jax.random.key(1))
    if arch.module == "encdec":
        from repro.models import encdec
        logits, _ = encdec.forward(params, cfg, batch["tokens"],
                                   batch["audio_emb"])
    else:
        from repro.models import transformer
        logits, _ = transformer.forward(params, cfg, batch["tokens"],
                                        patch_emb=batch.get("patch_emb"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", ARCH_IDS)
def test_smoke_train_step(name):
    arch = get_arch(name)
    cfg = arch.smoke
    fns = get_model_fns(arch.module)
    tcfg = TrainerConfig(n_pods=1, optimizer="sgd", lr=0.01,
                         sync=SyncConfig("asgd", 1))
    trainer = Trainer(lambda p, b: fns.loss_fn(p, cfg, b),
                      lambda k: fns.init_params(k, cfg), tcfg)
    state = trainer.init_state(jax.random.key(0))
    batch = jax.tree.map(lambda x: x[None], _batch(arch, cfg, jax.random.key(1)))
    state, metrics = trainer.train_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 1
    # params actually changed and stayed finite
    leaves = jax.tree.leaves(state.params)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_smoke_decode_step(name):
    arch = get_arch(name)
    cfg = arch.smoke
    fns = get_model_fns(arch.module)
    params = fns.init_params(jax.random.key(0), cfg)
    cache = fns.init_cache(cfg, B, 24)
    tok = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)
    logits, new_cache = fns.decode_step(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_full_configs_match_assignment():
    """The full-scale configs carry the exact assigned dimensions."""
    expect = {
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "mamba2-1.3b": (48, 2048, None, None, 0, 50280),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
    }
    for name, (L, D, H, K, F, V) in expect.items():
        c = get_arch(name).config
        assert c.n_layers == L and c.d_model == D and c.d_ff == F \
            and c.vocab_size == V, name
        if H is not None:
            assert c.n_heads == H and c.n_kv_heads == K, name

    moe = get_arch("qwen3-moe-30b-a3b").config.moe
    assert moe.num_experts == 128 and moe.top_k == 8
    moe = get_arch("kimi-k2-1t-a32b").config.moe
    assert moe.num_experts == 384 and moe.top_k == 8
    moe = get_arch("jamba-1.5-large-398b").config.moe
    assert moe.num_experts == 16 and moe.top_k == 2
    assert get_arch("mamba2-1.3b").config.ssm.state_dim == 128


def test_param_scale_sanity():
    """Analytic parameter counts are in the advertised ballpark."""
    assert 25e9 < get_arch("qwen3-moe-30b-a3b").config.param_count() < 36e9
    assert 0.9e12 < get_arch("kimi-k2-1t-a32b").config.param_count() < 1.2e12
    assert 320e9 < get_arch("jamba-1.5-large-398b").config.param_count() < 480e9
    assert 1.0e9 < get_arch("mamba2-1.3b").config.param_count() < 1.8e9
    assert 9e9 < get_arch("gemma3-12b").config.param_count() < 15e9
    assert 22e9 < get_arch("gemma2-27b").config.param_count() < 33e9
    a3b = get_arch("qwen3-moe-30b-a3b").config.active_param_count()
    assert 2e9 < a3b < 5e9
    k2a = get_arch("kimi-k2-1t-a32b").config.active_param_count()
    assert 25e9 < k2a < 45e9


def test_jamba_pattern_ratio():
    cfg = get_arch("jamba-1.5-large-398b").config
    kinds = [s.kind for s in cfg.pattern]
    assert kinds.count("attn") == 1 and kinds.count("ssm") == 7
    assert sum(s.moe for s in cfg.pattern) == 4  # every other position


def test_gemma_patterns():
    g3 = get_arch("gemma3-12b").config
    assert [s.window for s in g3.pattern] == [1024] * 5 + [None]
    g2 = get_arch("gemma2-27b").config
    assert [s.window for s in g2.pattern] == [4096, None]
    assert g2.attn_softcap == 50.0 and g2.final_softcap == 30.0
