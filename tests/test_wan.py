"""WAN simulator invariants + control-plane tests."""
import pytest

from repro.core.control_plane import (AddressTable, CommunicatorFunction,
                                      FunctionRegistry, FunctionReplica,
                                      TrainingRequest, Workflow,
                                      WorkflowEngine, build_training_plan,
                                      training_workflow)
from repro.core.cost import cost_report
from repro.core.scheduler import CloudResources
from repro.core.sync import SyncConfig
from repro.core.wan import SimCloud, WANConfig, compare_strategies, simulate

CLOUDS = [SimCloud("sh", iter_time_s=0.12, units=12),
          SimCloud("cq", iter_time_s=0.08, units=12)]
WAN = WANConfig(seed=1)


def _run(strategy, interval, **kw):
    return simulate(CLOUDS, SyncConfig(strategy, interval), n_iters=200,
                    model_mb=0.6, wan=WAN, **kw)


def test_makespan_at_least_compute():
    r = _run("asgd_ga", 8)
    for c in r.clouds:
        assert c.total_s >= c.compute_s - 1e-9


def test_frequency_reduction_cuts_traffic_and_comm():
    base = _run("asgd", 1)
    ga4 = _run("asgd_ga", 4)
    ga8 = _run("asgd_ga", 8)
    assert ga4.total_traffic_mb < base.total_traffic_mb
    assert ga8.total_traffic_mb < ga4.total_traffic_mb
    assert ga8.clouds[0].comm_s < ga4.clouds[0].comm_s < base.clouds[0].comm_s
    assert base.makespan_s > ga4.makespan_s > 0


def test_sma_barrier_waits_more():
    sma = _run("sma", 4)
    ama = _run("ama", 4)
    assert sum(c.wait_s for c in sma.clouds) >= \
        sum(c.wait_s for c in ama.clouds) - 1e-9
    # sync barrier also makes SMA slower than async MA (paper Fig 11)
    assert sma.makespan_s >= ama.makespan_s


def test_traffic_accounting_exact():
    r = _run("ama", 4)
    n_syncs = 200 // 4
    assert r.clouds[0].traffic_mb == pytest.approx(n_syncs * 0.6)
    base = _run("asgd", 1)
    assert base.clouds[0].traffic_mb == pytest.approx(200 * 0.6 * 2)  # push+pull


def test_cost_report_reduction():
    base = _run("asgd", 1)
    fast = _run("asgd_ga", 8)
    units = {"sh": 12, "cq": 12}
    rates = {"sh": 1.0, "cq": 1.0}
    rb = cost_report(base, units, rates)
    rf = cost_report(fast, units, rates)
    assert rf.reduction_vs(rb) > 0
    # bytes-on-wire flow into the report: freq-8 ships 1/16 of per-step
    # push+pull, and the reduction helper reflects it
    assert rf.traffic_mb == pytest.approx(rb.traffic_mb / 16)
    assert rf.traffic_reduction_vs(rb) == pytest.approx(1 - 1 / 16)


def test_compare_strategies_keys():
    res = compare_strategies(CLOUDS, n_iters=50, model_mb=0.6, wan=WAN)
    assert set(res) == {"asgd", "asgd_ga@4", "ama@4", "sma@4",
                        "asgd_ga@8", "ama@8", "sma@8", "asp"}
    # ASP ships less than the dense per-step baseline but more than freq-8
    assert res["asp"].total_traffic_mb < res["asgd"].total_traffic_mb
    assert res["asp"].total_traffic_mb > res["ama@8"].total_traffic_mb


def test_deterministic_given_seed():
    a = _run("asgd_ga", 4)
    b = _run("asgd_ga", 4)
    assert a.makespan_s == b.makespan_s


# ------------------------------------------------------------ control plane


def test_address_table_dynamic_endpoints():
    t = AddressTable()
    t.register(FunctionReplica("sh/ps#0", "ps", "sh", "10.0.0.1:50051"))
    assert t.resolve("sh/ps#0") == "10.0.0.1:50051"
    t.update_endpoint("sh/ps#0", "10.0.0.9:50051")   # endpoint churn
    assert t.resolve("sh/ps#0") == "10.0.0.9:50051"
    t.terminate("sh/ps#0")
    with pytest.raises(LookupError):
        t.resolve("sh/ps#0")


def test_workflow_topology_and_scale_to_zero():
    reg = FunctionRegistry()
    calls = []
    for name in ("load_data", "workers", "ps_update", "ps_communicator"):
        reg.deploy("sh", name, lambda ctx, n=name: calls.append(n))
    wf = training_workflow("sh")
    eng = WorkflowEngine(reg)
    eng.run(wf)
    assert calls == ["load_data", "workers", "ps_update", "ps_communicator"]
    # workers terminated after completion (serverless scale-to-zero)
    workers = reg.addresses.lookup(name="workers", namespace="sh")
    assert all(r.state == "terminated" for r in workers)


def test_workflow_cycle_detection():
    wf = Workflow("x")
    wf.add("a", deps=["b"])
    wf.add("b", deps=["a"])
    with pytest.raises(ValueError):
        wf.topo_order()


def test_communicator_requires_all_ps():
    comm = CommunicatorFunction()
    comm.register_ps("sh", "sh/ps#0")
    with pytest.raises(RuntimeError):
        comm.assign(["sh", "cq"])
    comm.register_ps("cq", "cq/ps#0")
    ids, topo = comm.assign(["sh", "cq"])
    assert len(ids) == 2 and topo == ((0, 1), (1, 0))


def test_build_training_plan_end_to_end():
    req = TrainingRequest(
        model="lenet",
        clouds=(CloudResources("sh", (("cascade", 6),), 2.0),
                CloudResources("cq", (("sky", 6),), 1.0)),
        sync=SyncConfig("ama", 8), global_batch=96)
    plan = build_training_plan(req)
    assert sum(plan.batch_split) == 96
    assert plan.batch_split[0] > plan.batch_split[1]   # more data+power -> more batch
    assert plan.topology == ((0, 1), (1, 0))
    assert len(plan.ps_identities) == 2


def test_reschedule_replans_topology_and_split():
    from repro.core.control_plane import reschedule
    req = TrainingRequest(
        model="lenet",
        clouds=(CloudResources("sh", (("cascade", 6),), 2.0),
                CloudResources("cq", (("sky", 6),), 1.0)),
        sync=SyncConfig("ama", 8), global_batch=96)
    plan = build_training_plan(req)
    # a third region comes online mid-run
    new = req.clouds + (CloudResources("bj", (("sky", 3),), 1.0),)
    plan2 = reschedule(plan, new)
    assert len(plan2.ps_identities) == 3
    assert plan2.topology == ((0, 1), (1, 2), (2, 0))
    assert sum(plan2.batch_split) == 96
