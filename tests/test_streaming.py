"""Streaming chunk-granular WAN shipping (PR 10).

Property contracts locked here:

- **Zero-retune bit-exactness** — a streaming round in which the
  controller never retunes is bit-identical to the classic
  ship+on_sync path on every transport: params, SyncState telemetry,
  billed TransferRecords, the probe's folded belief AND the rng stream
  (sim/hierarchical draw the round's transfer at round-open with the
  same consumption order ``on_sync`` has).
- **EF carries the exact fidelity delta** — a round that retunes
  mid-round splices the sender-side reconstruction (cfg prefix +
  cfg_to tail) and the EF residual equals ``flat - spliced_local``
  bit for bit, independently recomputed here via the public
  ``reencode_unsent`` seam.
- **Chaos composes by exclusion** — a fault-armed round declines the
  streaming protocol (classic resolve_round path); clean rounds
  delegate to the wrapped transport.
- **Mesh chunk timings** — ``measure_overlap`` reports per-chunk
  transfer wall-clock for both schedules (validated sharded in the
  multi-device CI job, ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import StreamingShipController
from repro.core.faults import ChaosTransport, FaultEvent, FaultPlan
from repro.core.sync import (BucketOverride, SyncConfig, _chunk_widths,
                             bucket_layout, reencode_unsent)
from repro.core.topology import HierarchicalTransport, TopologySpec
from repro.core.transport import (MeasuredWanProbe, MeshTransport,
                                  SimTransport)
from repro.core.wan import (BandwidthTrace, WANConfig, stream_chunk_plan,
                            stream_chunk_time)
from repro.training.trainer import Trainer, TrainerConfig

SYNC = SyncConfig("asgd_ga", 2, compress_topk=0.2, quantize_int8=True,
                  error_feedback=True, codec_block=128, overlap_chunks=2,
                  bucket_policy="layer-class",
                  buckets=(BucketOverride("norm", compress_topk=0.5),))
TRACE = BandwidthTrace(times_s=(0.0, 3.0), mbps=(100.0, 2.0))
# zero latency + zero fluctuation: a chunk's billed seconds express the
# traced bandwidth exactly, so the cliff law sees the collapse undiluted
CLEAN_WAN = WANConfig(latency_s=0.0, fluctuation=0.0)


def _loss(params, batch):
    pred = batch["x"] @ params["w"] + params["bias"]
    reg = jnp.mean(params["embed"] ** 2)
    return jnp.mean((pred - batch["y"]) ** 2) + 0.01 * reg, {}


def _init(key):
    kw, ke = jax.random.split(key)
    return {"w": jax.random.normal(kw, (8, 4)) * 0.1,
            "bias": jnp.zeros((4,)),
            "embed": jax.random.normal(ke, (16, 4)) * 0.1}


def _never_retuning(probe_est=None):
    """A live controller that can never fire (no belief to compare
    against) — exercises the full streaming protocol with zero retunes."""
    return StreamingShipController(SYNC, 0.001, probe_est=probe_est)


def _run(transport, stream=None, n_steps=10, sync=SYNC):
    tr = Trainer(_loss, _init,
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=sync),
                 transport=transport, stream=stream)
    st = tr.init_state(jax.random.key(0))
    rng = np.random.default_rng(7)
    snaps = []
    for step in range(n_steps):
        x = rng.normal(size=(2, 16, 8)).astype(np.float32)
        y = (x[..., :4] * 0.5).astype(np.float32)
        st, _ = tr.train_step(st, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        st = tr.maybe_sync(st, step, model_mb=0.001)
        if transport is not None and hasattr(transport, "tick"):
            transport.tick(0.5)
        snaps.append((np.asarray(st.sync_state.msg_norm).copy(),
                      np.asarray(st.sync_state.ef_residual).copy()))
    return st, tr, snaps


def _assert_same_stream(a, b, label):
    st_a, _, snaps_a = a
    st_b, _, snaps_b = b
    for la, lb in zip(jax.tree.leaves(st_a.params),
                      jax.tree.leaves(st_b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{label}: params")
    for field in ("ef_residual", "msg_norm", "resid_norm", "tier"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_a.sync_state, field)),
            np.asarray(getattr(st_b.sync_state, field)),
            err_msg=f"{label}: {field}")
    for i, ((ma, ra), (mb, rb)) in enumerate(zip(snaps_a, snaps_b)):
        np.testing.assert_array_equal(ma, mb, err_msg=f"{label}: step {i}")
        np.testing.assert_array_equal(ra, rb, err_msg=f"{label}: step {i}")


def _records(t):
    return [(r.bucket, r.payload_mb, r.seconds, r.step) for r in t.records]


# -------------------------------------------- zero-retune bit-exactness


def test_streaming_zero_retune_bit_identical_sim():
    """The headline invariant: with the streaming protocol active but no
    retune fired, EVERYTHING is bit-identical to the classic path —
    params, telemetry, billed records, probe belief, rng stream."""
    wan = WANConfig(fluctuation=0.2, seed=3)
    sim_c = SimTransport(TRACE, wan, probe=MeasuredWanProbe())
    sim_s = SimTransport(TRACE, wan, probe=MeasuredWanProbe())
    classic = _run(sim_c)
    ctl = _never_retuning()
    streamed = _run(sim_s, stream=ctl)
    _assert_same_stream(classic, streamed, "sim streaming vs classic")
    _assert_same_stream(_run(None), streamed, "sim streaming vs inline")
    assert _records(sim_s) == _records(sim_c)
    assert (sim_s.probe.estimator.bandwidth_mbps
            == sim_c.probe.estimator.bandwidth_mbps)
    assert sim_s.probe.n_observations == sim_c.probe.n_observations
    # the rng stream is untouched by streaming: the next classic draw on
    # both transports produces the same time
    assert sim_s.on_sync({"all": 0.5}) == sim_c.on_sync({"all": 0.5})
    # the streaming run DID stream: per-chunk observations landed
    assert len(sim_s.stream_rounds) == 5
    assert not any(r["retuned"] for r in sim_s.stream_rounds)
    assert sim_s.probe.n_chunk_observations == len(ctl.decisions) > 0
    assert all(d["action"] == "ship" for d in ctl.decisions)


def test_streaming_zero_retune_bit_identical_hierarchical():
    def make():
        spec = TopologySpec.from_regions(["us", "eu"], kind="tree")
        return HierarchicalTransport(
            spec, TRACE, wan=WANConfig(fluctuation=0.2, seed=3),
            probe=MeasuredWanProbe())

    t_c, t_s = make(), make()
    classic = _run(t_c)
    streamed = _run(t_s, stream=_never_retuning())
    _assert_same_stream(classic, streamed, "hier streaming vs classic")
    assert _records(t_s) == _records(t_c)
    assert (t_s.probe.estimator.bandwidth_mbps
            == t_c.probe.estimator.bandwidth_mbps)
    # the per-link beliefs (and hence the recompiled schedule) are also
    # bit-identical — begin_stream_round observes exactly what on_sync does
    assert t_s.beliefs.snapshot() == t_c.beliefs.snapshot()
    assert t_s.schedule == t_c.schedule
    assert len(t_s.stream_rounds) == 5


def test_streaming_zero_retune_bit_identical_mesh():
    """Mesh billing is wall-clock (not reproducible to the bit), but the
    shipped bytes are: params + telemetry match the classic mesh run and
    the inline ring; records keep the per-bucket structure."""
    mesh_c = MeshTransport(probe=MeasuredWanProbe())
    mesh_s = MeshTransport(probe=MeasuredWanProbe())
    classic = _run(mesh_c)
    streamed = _run(mesh_s, stream=_never_retuning())
    _assert_same_stream(classic, streamed, "mesh streaming vs classic")
    _assert_same_stream(_run(None), streamed, "mesh streaming vs inline")
    assert len(mesh_s.stream_rounds) == 5
    by_bucket_c = {r.bucket for r in mesh_c.records}
    by_bucket_s = {r.bucket for r in mesh_s.records}
    assert by_bucket_s == by_bucket_c
    assert mesh_s.probe.n_observations == mesh_c.probe.n_observations == 5
    assert mesh_s.probe.n_chunk_observations > 0
    # billed per-bucket MB match exactly (wall-clock seconds won't)
    mb_c = sorted((r.bucket, round(r.payload_mb, 12)) for r in mesh_c.records)
    mb_s = sorted((r.bucket, round(r.payload_mb, 12)) for r in mesh_s.records)
    assert mb_s == mb_c


# ------------------------------------------------- the mid-round retune


def _forced_cliff_run(n_steps=10):
    """Sim transport over the collapsing trace with the belief wired in:
    the first post-collapse chunk reads 2 Mbps against a ~100 Mbps belief
    and the cliff law fires.  Returns everything the EF-delta check needs."""
    t = SimTransport(TRACE, CLEAN_WAN, probe=MeasuredWanProbe())
    ctl = StreamingShipController(SYNC, 0.001, cliff_ratio=2.0,
                                  ef_guard=0.999,
                                  probe_est=t.probe.estimator)
    tr = Trainer(_loss, _init,
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=SYNC),
                 transport=t, stream=ctl)
    st = tr.init_state(jax.random.key(0))

    ships, retune_marks = [], []
    orig_ship, orig_retune = t.stream_ship_chunk, t.retune_stream

    def spy_ship(name, chunk, shift, mb):
        ships.append(name)
        return orig_ship(name, chunk, shift, mb)

    def spy_retune(tail_mb):
        retune_marks.append(len(ships))
        return orig_retune(tail_mb)

    t.stream_ship_chunk, t.retune_stream = spy_ship, spy_retune

    rng = np.random.default_rng(7)
    pre_states = {}
    for step in range(n_steps):
        x = rng.normal(size=(2, 16, 8)).astype(np.float32)
        y = (x[..., :4] * 0.5).astype(np.float32)
        st, _ = tr.train_step(st, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        pre_states[step] = st
        n_before = len(ships)
        st = tr.maybe_sync(st, step, model_mb=0.001)
        if tr.stream_retunes and "retune_step" not in pre_states:
            # record which ships belonged to the retuned round
            pre_states["retune_step"] = step
            pre_states["round_ships"] = ships[n_before:]
            pre_states["cut"] = retune_marks[0] - n_before
        t.tick(0.5)
    return t, ctl, tr, st, pre_states


def test_streaming_retune_fires_on_mid_round_cliff():
    t, ctl, tr, st, info = _forced_cliff_run()
    assert tr.stream_retunes == 1 and ctl.n_retunes == 1
    k = info["retune_step"]
    rd = next(r for r in t.stream_rounds if r["step"] == k)
    assert rd["retuned"] and rd["tail_mb"] > 0.0 and rd["t_tail"] > 0.0
    retunes = [d for d in ctl.decisions if d["action"] == "retune"]
    assert len(retunes) == 1 and retunes[0]["step"] == k
    # the cliff: achieved collapsed well below the pre-round belief
    assert retunes[0]["achieved"] * ctl.cliff_ratio < retunes[0]["believed"]
    # the retuned round's aggregate cliff-snapped the shared belief —
    # the round-level controllers see the collapse at the next barrier
    assert t.probe.estimator.bandwidth_mbps == pytest.approx(2.0)
    # ONE retune per round, and later rounds (belief already snapped)
    # ship clean — consume-once
    assert sum(r["retuned"] for r in t.stream_rounds) == 1
    assert np.isfinite(np.asarray(st.sync_state.ef_residual)).all()


def test_streaming_retune_ef_residual_is_exact_fidelity_delta():
    """Independently recompute ``flat - spliced_local`` for the retuned
    round through the public ``reencode_unsent`` seam and require the
    trainer's EF residual to match it bit for bit."""
    t, ctl, tr, st_final, info = _forced_cliff_run()
    k, cut = info["retune_step"], info["cut"]
    st_pre = info[k]

    cfg = SYNC
    # prepare is a deterministic jitted function of the pre-round state
    payloads = tr._prepare_sync(st_pre)
    layout = bucket_layout(cfg, st_pre.sync_state.ga_buffer)
    # sent = how many cfg-schedule chunks each bucket shipped before the
    # retune aborted the schedule (the spy recorded the ship order)
    sent = {name: 0 for name in payloads.chunks}
    for name in info["round_ships"][:cut]:
        sent[name] += 1
    rung = next(d for d in ctl.decisions if d["action"] == "retune")["rung"]
    cheap = ctl.ladder[rung]
    cfg_to = dataclasses.replace(cfg, compress_topk=cheap.compress_topk,
                                 value_dtype=cheap.value_dtype)

    tails, tail_local = reencode_unsent(cfg, cfg_to, payloads.flat,
                                        layout, sent)
    assert tails, "the forced cliff must leave an unsent tail"
    spliced = np.asarray(payloads.local).copy()
    for g, name in enumerate(layout.names):
        if name not in tails:
            continue
        off, size = layout.offsets[g], layout.sizes[g]
        widths = _chunk_widths(cfg.for_bucket(name), size)
        sw = int(sum(widths[:sent[name]]))
        spliced[:, off + sw:off + size] = np.asarray(tail_local[name])
    expected = np.asarray(payloads.flat) - spliced

    # replay the stream AFTER the retuned round on a fresh run to recover
    # the residual as it stood right after round k (the final state has
    # synced more rounds since)
    resid_after = info.get("resid_after")
    if resid_after is None:
        # round k's residual is snapshotted in the streaming run itself:
        # re-run and capture at step k
        t2 = SimTransport(TRACE, CLEAN_WAN, probe=MeasuredWanProbe())
        ctl2 = StreamingShipController(SYNC, 0.001, cliff_ratio=2.0,
                                       ef_guard=0.999,
                                       probe_est=t2.probe.estimator)
        tr2 = Trainer(_loss, _init,
                      TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                                    sync=SYNC),
                      transport=t2, stream=ctl2)
        st2 = tr2.init_state(jax.random.key(0))
        rng = np.random.default_rng(7)
        for step in range(k + 1):
            x = rng.normal(size=(2, 16, 8)).astype(np.float32)
            y = (x[..., :4] * 0.5).astype(np.float32)
            st2, _ = tr2.train_step(st2, {"x": jnp.asarray(x),
                                          "y": jnp.asarray(y)})
            st2 = tr2.maybe_sync(st2, step, model_mb=0.001)
            t2.tick(0.5)
        assert tr2.stream_retunes == 1
        resid_after = np.asarray(st2.sync_state.ef_residual)

    np.testing.assert_array_equal(resid_after, expected,
                                  err_msg="EF residual != flat - "
                                          "spliced_local after the retune")
    # and the delta is REAL: the cheap tail dropped more fidelity than
    # the planned encoding would have (residual grew on the tail columns)
    no_retune = np.asarray(payloads.flat) - np.asarray(payloads.local)
    assert np.linalg.norm(expected) > np.linalg.norm(no_retune)


def test_streaming_retune_stays_bit_exact_before_the_cliff():
    """Divergence starts AT the retuned round, not before: the pre-cliff
    prefix of the streaming run matches the classic run bit for bit."""
    t, ctl, tr, st, info = _forced_cliff_run()
    k = info["retune_step"]
    sim = SimTransport(TRACE, CLEAN_WAN, probe=MeasuredWanProbe())
    classic = _run(sim, n_steps=10)
    _, _, snaps_classic = classic
    # recompute the streaming run's snapshots
    t3 = SimTransport(TRACE, CLEAN_WAN, probe=MeasuredWanProbe())
    ctl3 = StreamingShipController(SYNC, 0.001, cliff_ratio=2.0,
                                   ef_guard=0.999,
                                   probe_est=t3.probe.estimator)
    streamed = _run(t3, stream=ctl3, n_steps=10)
    _, _, snaps_stream = streamed
    for i in range(k):
        np.testing.assert_array_equal(snaps_stream[i][0],
                                      snaps_classic[i][0])
        np.testing.assert_array_equal(snaps_stream[i][1],
                                      snaps_classic[i][1])
    # at the retuned round the residual genuinely differs
    assert not np.array_equal(snaps_stream[k][1], snaps_classic[k][1])


# ------------------------------------------------ controller law (units)


def test_controller_hysteresis_and_guard_block():
    probe = MeasuredWanProbe()
    probe.observe_transfer(1.0, 0.08)          # belief 100 Mbps
    ctl = StreamingShipController(SYNC, 1.0, cliff_ratio=4.0, hysteresis=2,
                                  probe_est=probe.estimator)
    ctl.begin_round(0, SYNC)
    # first cliff chunk: held (hysteresis 2)
    assert ctl.observe_chunk("dense", 0.1, 0.8) is None
    assert ctl.decisions[-1]["action"] == "hold"
    # second consecutive cliff chunk: fires
    assert ctl.observe_chunk("dense", 0.1, 0.8) is not None
    assert ctl.decisions[-1]["action"] == "retune"
    assert ctl.end_round()
    # guard-block: a stressed EF residual blocks the retune
    from repro.core.autotune import BucketStats
    ctl2 = StreamingShipController(SYNC, 1.0, cliff_ratio=4.0,
                                   ef_guard=0.9,
                                   probe_est=probe.estimator)
    ctl2.note_stats(BucketStats(msg_norm=1.0, resid_norm=0.95))
    ctl2.begin_round(1, SYNC)
    assert ctl2.observe_chunk("dense", 0.1, 0.8) is None
    assert ctl2.decisions[-1]["action"] == "guard-block"
    assert ctl2.n_retunes == 0 and not ctl2.end_round()
    # a clean-speed chunk resets the streak
    ctl3 = StreamingShipController(SYNC, 1.0, cliff_ratio=4.0, hysteresis=2,
                                   probe_est=probe.estimator)
    ctl3.begin_round(2, SYNC)
    ctl3.observe_chunk("dense", 0.1, 0.8)      # cliff -> streak 1
    ctl3.observe_chunk("dense", 0.1, 0.008)    # full speed -> reset
    assert ctl3.observe_chunk("dense", 0.1, 0.8) is None   # streak 1 again
    assert ctl3.n_retunes == 0


def test_stream_chunk_billing_law():
    """The shared chunk-billing law the bench and replay gate re-run:
    chunks bill pro-rata slices of the round draw and sum back exactly."""
    plan = stream_chunk_plan(1.0, 4)
    assert plan == [0.25] * 4
    t_round = 3.7
    parts = [stream_chunk_time(t_round, mb, 1.0) for mb in plan]
    assert sum(parts) == pytest.approx(t_round)
    assert stream_chunk_time(t_round, 0.5, 0.0) == 0.0


# --------------------------------------------------- chaos composition


def test_chaos_declines_streaming_on_faulted_rounds():
    plan = FaultPlan(events=(FaultEvent(kind="timeout", step=5, pod=1,
                                        factor=6.0, attempts=1),), seed=0)
    inner = SimTransport(TRACE, WANConfig(fluctuation=0.0),
                         probe=MeasuredWanProbe())
    chaos = ChaosTransport(inner, plan)
    assert chaos.supports_streaming            # delegates to the sim
    # the armed round declines; a clean round delegates
    assert chaos.begin_stream_round({"all": 0.5}, step=5) is False
    assert chaos.begin_stream_round({"all": 0.5}, step=4) is True
    inner.end_stream_round()

    inner2 = SimTransport(TRACE, WANConfig(fluctuation=0.0),
                          probe=MeasuredWanProbe())
    chaos2 = ChaosTransport(inner2, plan)
    st, tr, _ = _run(chaos2, stream=_never_retuning(), n_steps=12)
    # interval 2 over 12 steps -> 6 sync rounds; the step-5 fault round
    # went down the classic resolve_round path, the rest streamed
    assert len(inner2.stream_rounds) == 5
    assert [o["step"] for o in chaos2.outcomes] == [5]
    assert np.isfinite(np.asarray(st.sync_state.ef_residual)).all()


def test_chaos_clean_plan_streaming_still_bit_exact():
    """An empty chaos plan is a bit-exact passthrough for streaming too."""
    empty = FaultPlan(events=(), seed=0)
    wan = WANConfig(fluctuation=0.2, seed=3)
    sim = SimTransport(TRACE, wan, probe=MeasuredWanProbe())
    inner = SimTransport(TRACE, wan, probe=MeasuredWanProbe())
    chaos = ChaosTransport(inner, empty)
    classic = _run(sim)
    streamed = _run(chaos, stream=_never_retuning())
    _assert_same_stream(classic, streamed, "chaos streaming vs classic")
    assert _records(inner) == _records(sim)


# ------------------------------------------- mesh per-chunk observation


def test_mesh_measure_overlap_reports_per_chunk_timings():
    """Satellite: measure_overlap reports each chunk's transfer wall-clock
    for both schedules — the chunk-granular observation stream the
    streaming seam consumes.  Sharded assertions engage on the >= 4
    virtual-device CI job."""
    cfg = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                     error_feedback=True, codec_block=1024,
                     overlap_chunks=4)
    mesh = MeshTransport(emulate_mbps=2.0)
    rep = mesh.measure_overlap(cfg, n_pods=4, n_elems=1 << 16, reps=1)
    assert rep["chunks"] == 4
    assert len(rep["chunk_mb"]) == 4
    hops = rep["chunk_transfer_s"]
    assert set(hops) == {"serialized", "pipelined"}
    assert len(hops["serialized"]) == len(hops["pipelined"]) == 4
    # every chunk's transfer was measured (the emulated hop guarantees a
    # visible wall-clock on every schedule)
    assert all(h > 0.0 for h in hops["serialized"])
    assert all(h > 0.0 for h in hops["pipelined"])
    # the serialized schedule's total transfer is consistent with its
    # end-to-end time (transfers are a subset of the round)
    assert sum(hops["serialized"]) <= rep["t_serialized_s"] + 1e-6
    if jax.device_count() >= 4:
        assert rep["sharded"] and rep["n_devices"] >= 4


def test_mesh_streaming_chunk_observations_feed_probe():
    mesh = MeshTransport(probe=MeasuredWanProbe(), emulate_mbps=50.0)
    st, tr, _ = _run(mesh, stream=_never_retuning(), n_steps=4)
    assert len(mesh.stream_rounds) == 2
    assert mesh.probe.n_chunk_observations > 0
    assert mesh.probe.last_chunk_mbps is not None
    # chunk log carries (mb, s, mbps) triples for the whole stream
    mb, s, mbps = mesh.probe.chunk_log[-1]
    assert mb > 0 and s > 0 and mbps == pytest.approx(mb * 8.0 / s)
