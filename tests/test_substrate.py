"""Substrate tests: optimizers, data pipeline, checkpointing, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import (GeoDataset, TokenStream,
                                 synthetic_classification)
from repro.optim.optimizers import (adamw, clip_by_global_norm, global_norm,
                                    momentum, sgd, warmup_cosine_schedule)
from repro.sharding.rules import LA, logical_to_spec, spec_tree_for_params

# ------------------------------------------------------------------ optim


def _quadratic_opt(opt, steps=200, lr=0.1):
    params = {"x": jnp.asarray([5.0, -3.0]), "y": jnp.asarray([[2.0]])}
    target = jax.tree.map(jnp.zeros_like, params)
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.tree.map(lambda p, t: p - t, params, target)
        params, state = opt.update(grads, state, params, jnp.float32(lr))
    return float(global_norm(params))


@pytest.mark.parametrize("opt,lr", [(sgd(), 0.1), (momentum(0.9), 0.05),
                                    (adamw(), 0.05)])
def test_optimizers_minimize_quadratic(opt, lr):
    assert _quadratic_opt(opt, lr=lr) < 1e-2


def test_momentum_bf16_state_dtype():
    opt = momentum(state_dtype="bfloat16")
    params = {"x": jnp.ones((4,), jnp.float32)}
    st = opt.init(params)
    assert st["x"].dtype == jnp.bfloat16
    _, st2 = opt.update(params, st, params, jnp.float32(0.1))
    assert st2["x"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((4,), 0.01)}
    np.testing.assert_allclose(np.asarray(clip_by_global_norm(small, 1.0)["a"]),
                               np.asarray(small["a"]))


def test_warmup_cosine():
    sched = warmup_cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0)
    assert float(sched(100)) == pytest.approx(0.0, abs=1e-6)
    assert float(sched(5)) == pytest.approx(0.5)


# ------------------------------------------------------------------- data


def test_token_stream_deterministic_and_sharded():
    s0 = TokenStream(vocab_size=128, seq_len=16, batch_size=4, seed=1, shard=0)
    s0b = TokenStream(vocab_size=128, seq_len=16, batch_size=4, seed=1, shard=0)
    s1 = TokenStream(vocab_size=128, seq_len=16, batch_size=4, seed=1, shard=1)
    b0, b0b, b1 = s0.batch(3), s0b.batch(3), s1.batch(3)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels are the shifted tokens
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])
    assert b0["tokens"].max() < 128


def test_token_stream_structured_learnable():
    s = TokenStream(vocab_size=64, seq_len=64, batch_size=8, structured=True)
    b = s.batch(0)
    # ~90% of transitions follow next = (3 tok + 1) % V
    match = np.mean((3 * b["tokens"][:, :-1] + 1) % 64 == b["tokens"][:, 1:])
    assert match > 0.8


def test_geo_partition_ratio_and_coverage():
    data = synthetic_classification(1000, (4,), 3, feature_vocab=50)
    geo = GeoDataset.partition(data, ["a", "b", "c"], [2, 1, 1], seed=0)
    sizes = geo.sizes()
    assert sum(sizes.values()) == 1000
    assert sizes["a"] == 500 and sizes["b"] == 250
    # shards are disjoint and cover everything (check by multiset of labels)
    ys = np.concatenate([s.data["y"] for s in geo.shards])
    np.testing.assert_array_equal(np.sort(ys), np.sort(data["y"]))


def test_geo_loader_draws_only_own_shard():
    data = {"x": np.arange(100)[:, None].astype(np.float32),
            "y": np.arange(100).astype(np.int32)}
    geo = GeoDataset.partition(data, ["a", "b"], [1, 1], seed=0)
    own = set(geo.shards[0].data["y"].tolist())
    loader = geo.loader("a", 16, seed=3)
    for _ in range(5):
        batch = next(loader)
        assert set(batch["y"].tolist()) <= own


# -------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), tree, step=7, metadata={"note": "x"})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones((3,))})


def test_checkpoint_missing_leaf_raises(tmp_path):
    ckpt.save(str(tmp_path), {"a": jnp.ones((2,))})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), {"zz": jnp.ones((2,))})


# ---------------------------------------------------------------- sharding


class _FakeMesh:
    axis_names = ("pod", "data", "model")
    class devices:  # noqa: D401
        shape = (2, 16, 16)
        size = 512


def test_logical_to_spec_divisibility_fallback():
    rules = {"heads": "model", "batch": ("pod", "data"), "kv": "model"}
    spec = logical_to_spec((6, 32), ("heads", "batch"), rules, _FakeMesh())
    # 6 heads don't divide 16 -> replicated; 32 batch over pod*data
    assert spec == P(None, ("pod", "data"))
    spec = logical_to_spec((64, 31), ("heads", "batch"), rules, _FakeMesh())
    assert spec == P("model", None)   # 31 indivisible -> dropped


def test_logical_to_spec_no_duplicate_axis():
    rules = {"cache_seq": "model", "kv_heads": "model"}
    spec = logical_to_spec((32768, 16), ("cache_seq", "kv_heads"),
                           rules, _FakeMesh())
    assert spec == P("model", None)   # first dim wins the axis


def test_spec_tree_for_params():
    tree = {"w": LA(("heads", None)), "b": LA((None,))}
    ab = {"w": jax.ShapeDtypeStruct((32, 8), jnp.float32),
          "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
    specs = spec_tree_for_params(tree, ab, {"heads": "model"}, _FakeMesh())
    assert specs["w"] == P("model", None)
    assert specs["b"] == P(None)
