"""End-to-end system tests: the full training driver (control plane ->
elastic plan -> geo data -> sync strategies -> checkpoints) and the serving
driver, exercised through their CLIs."""
import json
import os

import jax
import numpy as np
import pytest


def test_end_to_end_training_loss_decreases(tmp_path):
    from repro.launch.train import main
    summary = main([
        "--preset", "tiny", "--pods", "2", "--steps", "30",
        "--batch", "8", "--seq", "64", "--sync", "asgd_ga", "--interval", "4",
        "--lr", "0.1", "--data-ratio", "2:1", "--log-every", "0",
        "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "30",
    ])
    # loss must move on the structured bigram stream
    assert summary["loss_last"] < summary["loss_first"]
    assert summary["wan_traffic_mb"] > 0
    assert os.path.exists(tmp_path / "ck" / "manifest.json")


def test_end_to_end_uneven_split_masks(tmp_path):
    from repro.launch.train import main
    s = main(["--preset", "tiny", "--pods", "2", "--steps", "6",
              "--batch", "6", "--seq", "32", "--sync", "sma",
              "--interval", "2", "--data-ratio", "3:1", "--log-every", "0"])
    assert np.isfinite(s["loss_last"])


def test_end_to_end_serving():
    from repro.launch.serve import main
    results = main(["--arch", "granite-8b", "--smoke", "--batch", "2",
                    "--prompt-len", "8", "--new-tokens", "4",
                    "--requests", "3"])
    assert len(results) == 3
