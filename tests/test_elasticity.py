"""Elasticity engine tests: event bus, controller re-planning, pod-resize
state transforms, reconfig-at-barrier semantics, WAN event injection, and
resharding-aware checkpoint restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core.control_plane import (CloudEvent, ElasticityController,
                                      EventBus, TrainingRequest,
                                      adapt_interval, build_training_plan)
from repro.core.scheduler import CloudResources, load_power
from repro.core.sync import (SyncConfig, grow_pods, init_sync_state,
                             resize_sync_state, shrink_pods)
from repro.core.wan import SimCloud, SimEvent, WANConfig, simulate
from repro.training.trainer import (Trainer, TrainerConfig, apply_reconfig,
                                    resize_train_state)

CLOUDS = (CloudResources("sh", (("cascade", 6),), data_size=2.0),
          CloudResources("cq", (("sky", 6),), data_size=1.0),
          CloudResources("bj", (("sky", 3),), data_size=1.0))


def _plan(sync=SyncConfig("asgd_ga", 8), clouds=CLOUDS, batch=96):
    return build_training_plan(TrainingRequest(
        model="m", clouds=clouds, sync=sync, global_batch=batch))


# ------------------------------------------------------- state transforms


def test_grow_pods_preserves_parameter_mean():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(3, 4, 2)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32)}
    grown = grow_pods(tree, 5, how="mean")
    for k in tree:
        assert grown[k].shape == (5,) + tree[k].shape[1:]
        np.testing.assert_allclose(np.mean(np.asarray(grown[k]), 0),
                                   np.mean(np.asarray(tree[k]), 0), atol=1e-6)


def test_shrink_pods_preserves_parameter_mean():
    rng = np.random.default_rng(1)
    tree = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    shrunk = shrink_pods(tree, (0, 2), how="mean")
    assert shrunk["w"].shape == (2, 3)
    np.testing.assert_allclose(np.mean(np.asarray(shrunk["w"]), 0),
                               np.mean(np.asarray(tree["w"]), 0), atol=1e-6)


def test_shrink_pods_sum_mode_replay_accumulates():
    rng = np.random.default_rng(2)
    buf = {"g": jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)}
    shrunk = shrink_pods(buf, (1, 3), how="sum")
    np.testing.assert_allclose(np.sum(np.asarray(shrunk["g"]), 0),
                               np.sum(np.asarray(buf["g"]), 0), atol=1e-5)


def test_pod_transform_validation():
    tree = {"w": jnp.zeros((3, 2))}
    with pytest.raises(ValueError):
        grow_pods(tree, 2)
    with pytest.raises(ValueError):
        shrink_pods(tree, ())
    with pytest.raises(ValueError):
        shrink_pods(tree, (0, 0))
    with pytest.raises(ValueError):
        shrink_pods(tree, (5,))


def test_resize_sync_state_ga_buffer_total_preserved():
    cfg = SyncConfig("asgd_ga", 4)
    rng = np.random.default_rng(3)
    params3 = {"w": jnp.asarray(rng.normal(size=(3, 2)), jnp.float32)}
    state = init_sync_state(cfg, params3)
    state = state._replace(ga_buffer=jax.tree.map(
        lambda b: b + 1.0, state.ga_buffer))
    params2 = shrink_pods(params3, (0, 2))
    out = resize_sync_state(cfg, state, params2, keep=(0, 2))
    np.testing.assert_allclose(
        np.sum(np.asarray(out.ga_buffer["w"]), 0),
        np.sum(np.asarray(state.ga_buffer["w"]), 0), atol=1e-5)
    # growing seeds joiners with zero accumulation
    params5 = grow_pods(params3, 5)
    grown = resize_sync_state(cfg, state, params5)
    np.testing.assert_allclose(np.asarray(grown.ga_buffer["w"][3:]), 0.0)


# --------------------------------------------------- controller re-planning


def test_cloud_left_replans_to_match_straggler():
    plan = _plan()
    ctl = ElasticityController(plan)
    rc = ctl.handle(CloudEvent("cloud_left", region="cq", time_s=10.0))
    plans = rc.new.resource_plans
    assert [p.region for p in plans] == ["sh", "bj"]
    ref = min(load_power(c.devices, c.data_size)
              for c in CLOUDS if c.region != "cq")
    for p in plans:
        # within tolerance of the straggler: at or above the reference, and
        # trimming one more unit anywhere would fall below it
        assert p.load_power >= ref - 1e-9
        cloud = next(c for c in CLOUDS if c.region == p.region)
        for i, (dev, n) in enumerate(p.allocation):
            if n == 1 and len(p.allocation) == 1:
                continue   # cannot trim the last unit
            trimmed = tuple((d, m - 1 if j == i else m)
                            for j, (d, m) in enumerate(p.allocation) if
                            (m - 1 if j == i else m) > 0)
            assert load_power(trimmed, cloud.data_size) < ref - 1e-12


def test_cloud_joined_extends_ring_and_split():
    plan = _plan(clouds=CLOUDS[:2])
    ctl = ElasticityController(plan)
    rc = ctl.handle(CloudEvent(
        "cloud_joined", time_s=5.0,
        resources=CloudResources("bj", (("sky", 3),), data_size=1.0)))
    assert rc.diff.added == ("bj",)
    assert len(rc.new.ps_identities) == 3
    assert rc.new.topology == ((0, 1), (1, 2), (2, 0))
    assert sum(rc.new.batch_split) == 96
    keep, n_new = rc.pod_transition()
    assert keep == (0, 1) and n_new == 3


def test_bandwidth_change_adapts_interval_not_plan():
    plan = _plan()
    ctl = ElasticityController(plan, ref_bandwidth_mbps=100.0)
    rc = ctl.handle(CloudEvent("bandwidth_changed", bandwidth_mbps=25.0))
    assert rc.diff.is_empty            # resource plans untouched
    assert rc.new.request.sync.interval == 32
    assert not rc.is_noop              # but the sync schedule changed
    # recovery restores the base interval
    rc2 = ctl.handle(CloudEvent("bandwidth_changed", bandwidth_mbps=100.0))
    assert rc2.new.request.sync.interval == 8


def test_straggler_event_rebalances_split():
    plan = _plan()
    ctl = ElasticityController(plan)
    rc = ctl.handle(CloudEvent("straggler_detected", region="sh",
                               slowdown=2.0))
    sh_i = [p.region for p in rc.new.resource_plans].index("sh")
    assert rc.new.batch_split[sh_i] < rc.old.batch_split[sh_i]


def test_identical_event_is_noop():
    plan = _plan()
    ctl = ElasticityController(plan)
    rc = ctl.handle(CloudEvent("bandwidth_changed", bandwidth_mbps=100.0))
    assert rc.is_noop and rc.diff.is_empty


def test_event_bus_routes_to_controller():
    plan = _plan()
    bus = EventBus()
    ctl = ElasticityController(plan, bus=bus)
    out = bus.publish(CloudEvent("cloud_left", region="bj", time_s=1.0))
    assert len(out) == 1 and out[0].diff.removed == ("bj",)
    assert ctl.plan is out[0].new
    assert bus.history[0].kind == "cloud_left"
    with pytest.raises(ValueError):
        bus.subscribe("nope", lambda e: e)
    with pytest.raises(ValueError):
        CloudEvent("not_a_kind")


def test_controller_refuses_removing_last_cloud():
    plan = _plan(clouds=CLOUDS[:1])
    ctl = ElasticityController(plan)
    with pytest.raises(ValueError):
        ctl.handle(CloudEvent("cloud_left", region="sh"))


def test_adapt_interval_clamps():
    sync = SyncConfig("asgd_ga", 8)
    assert adapt_interval(sync, 8, 100.0, 1.0, max_interval=64).interval == 64
    assert adapt_interval(sync, 8, 100.0, 1e6).interval == 1
    assert adapt_interval(SyncConfig("asgd", 1), 1, 100.0, 25.0).interval == 1


# ------------------------------------------------ trainer re-stacking


def _toy_trainer(n_pods, sync, optimizer="momentum"):
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {}

    def init_fn(key):
        return {"w": jax.random.normal(key, (4, 1)) * 0.1}

    cfg = TrainerConfig(n_pods=n_pods, optimizer=optimizer, lr=0.05,
                        sync=sync)
    return Trainer(loss_fn, init_fn, cfg)


def _toy_batch(n_pods, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n_pods, 8, 4)).astype(np.float32)
    w = rng.normal(size=(4, 1)).astype(np.float32)
    y = x @ w + 0.01 * rng.normal(size=(n_pods, 8, 1)).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def test_trainer_shrink_preserves_param_mean_and_trains():
    sync = SyncConfig("asgd_ga", 4)
    trainer = _toy_trainer(3, sync)
    state = trainer.init_state(jax.random.key(0), same_init=False)
    for step in range(4):
        state, _ = trainer.train_step(state, _toy_batch(3, step))
        state = trainer.maybe_sync(state, step)
    mean_before = np.mean(np.asarray(state.params["w"]), 0)

    trainer2, state2 = trainer.reconfigure(state, 2, keep=(0, 2))
    assert trainer2.cfg.n_pods == 2
    assert state2.params["w"].shape[0] == 2
    np.testing.assert_allclose(np.mean(np.asarray(state2.params["w"]), 0),
                               mean_before, atol=1e-6)
    # momentum state resized consistently with params
    assert all(x.shape[0] == 2 for x in jax.tree.leaves(state2.opt_state))
    # training continues and the loss stays finite
    for step in range(4, 8):
        state2, m = trainer2.train_step(state2, _toy_batch(2, step))
        state2 = trainer2.maybe_sync(state2, step)
    assert np.isfinite(float(m["loss"]))


def test_trainer_grow_preserves_param_mean():
    sync = SyncConfig("ama", 2)
    trainer = _toy_trainer(2, sync)
    state = trainer.init_state(jax.random.key(1), same_init=False)
    mean_before = np.mean(np.asarray(state.params["w"]), 0)
    trainer2, state2 = trainer.reconfigure(state, 4)
    assert state2.params["w"].shape[0] == 4
    np.testing.assert_allclose(np.mean(np.asarray(state2.params["w"]), 0),
                               mean_before, atol=1e-6)
    state2, m = trainer2.train_step(state2, _toy_batch(4))
    assert np.isfinite(float(m["loss"]))


def test_apply_reconfig_noop_on_empty_diff():
    plan = _plan()
    ctl = ElasticityController(plan)
    rc = ctl.handle(CloudEvent("bandwidth_changed", bandwidth_mbps=100.0))
    assert rc.is_noop
    trainer = _toy_trainer(3, SyncConfig("asgd_ga", 8))
    state = trainer.init_state(jax.random.key(2))
    out_trainer, out_state, applied = apply_reconfig(trainer, state, rc)
    assert not applied
    assert out_trainer is trainer and out_state is state


def test_apply_reconfig_cloud_left_restacks():
    plan = _plan()
    ctl = ElasticityController(plan)
    rc = ctl.handle(CloudEvent("cloud_left", region="cq", time_s=3.0))
    trainer = _toy_trainer(3, SyncConfig("asgd_ga", 8))
    state = trainer.init_state(jax.random.key(3), same_init=False)
    mean_before = np.mean(np.asarray(state.params["w"]), 0)
    out_trainer, out_state, applied = apply_reconfig(trainer, state, rc)
    assert applied and out_trainer.cfg.n_pods == 2
    np.testing.assert_allclose(np.mean(np.asarray(out_state.params["w"]), 0),
                               mean_before, atol=1e-6)


def test_resize_train_state_rejects_bad_keep():
    trainer = _toy_trainer(3, SyncConfig("sma", 4))
    state = trainer.init_state(jax.random.key(4))
    with pytest.raises(ValueError):
        resize_train_state(trainer.cfg.sync, state, 1, keep=(0, 1))


# -------------------------------------------------- WAN event injection


def _sim(events=(), sync=SyncConfig("asgd_ga", 8)):
    clouds = [SimCloud("sh", iter_time_s=0.12, units=12),
              SimCloud("cq", iter_time_s=0.08, units=12)]
    return simulate(clouds, sync, n_iters=200, model_mb=0.6,
                    wan=WANConfig(seed=1), events=events)


def test_simulate_no_events_unchanged():
    assert _sim().makespan_s == _sim(events=()).makespan_s


def test_bandwidth_collapse_slows_run():
    slow = _sim([SimEvent(5.0, "bandwidth_changed", bandwidth_mbps=5.0)])
    assert slow.makespan_s > _sim().makespan_s


def test_cloud_left_releases_resources():
    left = _sim([SimEvent(5.0, "cloud_left", region="cq")])
    base = _sim()
    assert left.total_cost < base.total_cost
    cq = next(c for c in left.clouds if c.region == "cq")
    sh = next(c for c in left.clouds if c.region == "sh")
    assert cq.total_s < sh.total_s       # departed early, billing stopped

def test_reconfig_event_pays_pause_and_swaps_schedule():
    rec = _sim([SimEvent(5.0, "reconfig",
                         clouds=[SimCloud("sh", 0.06, units=24)],
                         sync=SyncConfig("asgd_ga", 16), pause_s=3.0)])
    assert rec.n_reconfigs == 1
    assert rec.sync_cfg.interval == 16
    assert all(c.reconfig_s == 3.0 for c in rec.clouds
               if c.region == "sh")


def test_cloud_joined_mid_simulation():
    joined = _sim([SimEvent(5.0, "cloud_joined",
                            cloud=SimCloud("bj", 0.1, units=6))])
    assert sorted(c.region for c in joined.clouds) == ["bj", "cq", "sh"]
    bj = next(c for c in joined.clouds if c.region == "bj")
    assert bj.total_s < joined.makespan_s  # born late: billed a shorter life


# ------------------------------------------- resharding-aware checkpoints


def test_checkpoint_restore_pod_grow_and_shrink(tmp_path):
    rng = np.random.default_rng(5)
    tree3 = {"w": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)}
    ckpt.save(str(tmp_path), tree3, step=11)

    like5 = {"w": jnp.zeros((5, 4), jnp.float32)}
    out5, step = ckpt.restore(str(tmp_path), like5, pod_resize="mean")
    assert step == 11 and out5["w"].shape == (5, 4)
    np.testing.assert_allclose(np.mean(np.asarray(out5["w"]), 0),
                               np.mean(np.asarray(tree3["w"]), 0), atol=1e-6)

    like2 = {"w": jnp.zeros((2, 4), jnp.float32)}
    out2, _ = ckpt.restore(str(tmp_path), like2, pod_resize="mean")
    np.testing.assert_allclose(np.mean(np.asarray(out2["w"]), 0),
                               np.mean(np.asarray(tree3["w"]), 0), atol=1e-6)

    # without pod_resize the mismatch still raises (original contract)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), like5)

    # trailing-dim mismatches are never silently resized
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((3, 7), jnp.float32)},
                     pod_resize="mean")


def test_trainer_shrink_keeps_adam_second_moment_nonnegative():
    """Survivors' optimizer moments are kept, not mean-shifted: a shift could
    push Adam's second moment negative -> NaN via sqrt on the next update."""
    trainer = _toy_trainer(3, SyncConfig("asgd_ga", 4), optimizer="adamw")
    state = trainer.init_state(jax.random.key(5), same_init=False)
    for step in range(3):
        state, _ = trainer.train_step(state, _toy_batch(3, step))
    trainer2, state2 = trainer.reconfigure(state, 2, keep=(0, 2))
    nu_leaves = [np.asarray(x) for x in jax.tree.leaves(state2.opt_state)]
    assert all(np.all(np.isfinite(x)) for x in nu_leaves)
    # adamw state is (mu, nu, count); nu (second moment) must stay >= 0
    mu, nu, _ = state2.opt_state
    assert all(np.all(np.asarray(x) >= 0.0) for x in jax.tree.leaves(nu))
    state2, m = trainer2.train_step(state2, _toy_batch(2, 9))
    assert np.isfinite(float(m["loss"]))


def test_launcher_composes_events_between_barriers():
    """Two events between two barriers apply as ONE reconfiguration diffed
    against the plan live on the trainer, so the pod transition is computed
    from the right base (a cloud_left followed by a straggler event must
    still shrink the pod dimension)."""
    from repro.launch.train import main
    summary = main(["--preset", "tiny", "--pods", "3", "--steps", "20",
                    "--batch", "6", "--seq", "16", "--sync", "asgd_ga",
                    "--interval", "16", "--log-every", "0",
                    "--events", "cloud_left:pod1@3,straggler:pod0x2.0@5"])
    assert summary["final_pods"] == 2
    assert summary["reconfigs"] == 1          # composed, applied once
    assert np.isfinite(summary["loss_last"])


def test_wan_leave_then_rejoin_bills_both_lives():
    clouds = [SimCloud("sh", iter_time_s=0.1, units=10),
              SimCloud("cq", iter_time_s=0.1, units=10)]
    base = simulate(clouds, SyncConfig("ama", 4), n_iters=400, model_mb=0.5,
                    wan=WANConfig(seed=2, fluctuation=0.0))
    rejoin = simulate(
        clouds, SyncConfig("ama", 4), n_iters=400, model_mb=0.5,
        wan=WANConfig(seed=2, fluctuation=0.0),
        events=[SimEvent(10.0, "cloud_left", region="cq"),
                SimEvent(30.0, "cloud_joined",
                         cloud=SimCloud("cq", iter_time_s=0.1, units=10))])
    cq = next(c for c in rejoin.clouds if c.region == "cq")
    cq_base = next(c for c in base.clouds if c.region == "cq")
    # offline gap is not billed: cheaper and shorter-lived than the base run,
    # but both lives count (first life's ~10s of compute is not erased)
    assert cq.total_s < cq_base.total_s
    assert cq.cost < cq_base.cost
    assert cq.total_s > rejoin.makespan_s - 30.0 - 1e-6
    assert cq.compute_s > 10.0


# ------------------------------------------------------ benchmark scenario


def test_elasticity_benchmark_elastic_beats_static(tmp_path, monkeypatch):
    import benchmarks.elasticity as E
    monkeypatch.setattr(E, "OUT_DIR", str(tmp_path))
    monkeypatch.setattr(E, "OUT_PATH", str(tmp_path / "BENCH_elasticity.json"))
    r = E.bench_elasticity(seed=0)
    assert r["speedup"] > 1.0
    assert r["cost_reduction"] > 0.0
    assert (tmp_path / "BENCH_elasticity.json").exists()
