"""Per-bucket WAN sync partitioning: layer-class classification, per-bucket
codec semantics, EF-residual carry-over across retune + elasticity in one
run, the growth-trend guard, and the BucketedSyncController control law.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import (AdaptiveSyncController, BucketStats,
                                 BucketedSyncController,
                                 bucket_stats_from_sync_state)
from repro.core.sync import (BUCKET_CLASSES, BucketOverride, SyncConfig,
                             apply_sync, bucket_layout, bucket_weights_of,
                             init_sync_state, on_step_gradients,
                             resize_sync_state, retune_sync_state,
                             _pack_stacked)

# a stacked tree with one leaf per layer class (2 pods)
def _tree(n_pods=2, seed=0):
    rng = np.random.default_rng(seed)
    f32 = lambda *s: jnp.asarray(rng.normal(size=(n_pods,) + s), jnp.float32)
    return {"embed": {"tokens": f32(40, 8)},
            "final_norm": {"scale": f32(16)},
            "mlp": {"w": f32(64, 32)},
            "moe": {"wg": f32(4, 16, 8)}}


MULTI = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                   error_feedback=True, codec_block=256,
                   bucket_policy="layer-class")


# ----------------------------------------------------------- classification


def test_layer_class_classification():
    t = _tree()
    lay = bucket_layout(MULTI, t)
    assert lay.names == BUCKET_CLASSES
    # leaves flatten in dict-key order: embed, final_norm, mlp, moe
    assert lay.leaf_bucket == (BUCKET_CLASSES.index("embed"),
                               BUCKET_CLASSES.index("norm"),
                               BUCKET_CLASSES.index("dense"),
                               BUCKET_CLASSES.index("moe"))
    # contiguous segments covering the whole buffer, in name order
    assert lay.offsets == (0, 320, 336, 2384)
    assert sum(lay.sizes) == 320 + 16 + 2048 + 512


def test_vector_fallback_and_pattern_precedence():
    t = {"moe": {"bias": jnp.zeros((2, 8))},       # moe pattern beats bias
         "w1": jnp.zeros((2, 4, 4)),               # no pattern, rank 2 -> dense
         "b1": jnp.zeros((2, 4))}                  # no pattern, rank 1 -> norm
    lay = bucket_layout(MULTI, t)
    # dict keys flatten sorted: b1, moe/bias, w1
    by_name = dict(zip(["b1", "bias", "w1"], lay.leaf_bucket))
    assert BUCKET_CLASSES[by_name["bias"]] == "moe"
    assert BUCKET_CLASSES[by_name["w1"]] == "dense"
    assert BUCKET_CLASSES[by_name["b1"]] == "norm"


def test_single_policy_layout_is_identity():
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                     error_feedback=True)
    t = _tree()
    lay = bucket_layout(cfg, t)
    assert lay.names == ("all",)
    assert lay.order == tuple(range(4))
    legacy = np.asarray(_pack_stacked(t))
    grouped = np.asarray(_pack_stacked(t, lay))
    np.testing.assert_array_equal(legacy, grouped)


def test_bucket_weights_sum_to_one():
    w = bucket_weights_of(MULTI, _tree())
    assert w.keys() == set(BUCKET_CLASSES)
    assert sum(w.values()) == pytest.approx(1.0)
    assert all(v >= 0 for v in w.values())


# -------------------------------------------------------- config semantics


def test_bucket_override_knobs_and_payload():
    cfg = SyncConfig(
        "asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
        error_feedback=True, bucket_policy="layer-class",
        buckets=(BucketOverride("moe", compress_topk=0.01,
                                value_dtype="int4"),
                 BucketOverride("norm", compress_topk=0.5)))
    assert cfg.bucket_knobs("moe") == (0.01, "int4", 4096)
    assert cfg.bucket_knobs("norm") == (0.5, "int8", 4096)
    assert cfg.bucket_knobs("dense") == (0.05, "int8", 4096)  # inherits global
    assert cfg.for_bucket("moe").uses_codec
    assert cfg.bucket_tiers == (1, 1, 1, 3)
    # weighted payload equals the sum of per-bucket payloads
    w = {"embed": 0.2, "norm": 0.05, "dense": 0.55, "moe": 0.2}
    expect = sum(cfg.for_bucket(n).payload_mb(100.0 * w[n])
                 for n in cfg.bucket_names)
    assert cfg.payload_mb(100.0, bucket_weights=w) == pytest.approx(expect)


def test_validation_errors_name_the_bucket():
    base = dict(compress_topk=0.1, quantize_int8=True, error_feedback=True,
                bucket_policy="layer-class")
    with pytest.raises(ValueError, match="bucket 'moe'"):
        SyncConfig("asgd_ga", 1, **base,
                   buckets=(BucketOverride("moe", value_dtype="fp16"),))
    with pytest.raises(ValueError, match="bucket 'embed'"):
        SyncConfig("asgd_ga", 1, **base,
                   buckets=(BucketOverride("embed", compress_topk=1.5),))
    with pytest.raises(ValueError, match="bucket 'attn'"):
        SyncConfig("asgd_ga", 1, **base,
                   buckets=(BucketOverride("attn", compress_topk=0.1),))
    with pytest.raises(ValueError, match="bucket 'norm'.*duplicate"):
        SyncConfig("asgd_ga", 1, **base,
                   buckets=(BucketOverride("norm", compress_topk=0.1),
                            BucketOverride("norm", compress_topk=0.2)))
    # overrides without the layer-class policy name the offenders
    with pytest.raises(ValueError, match="moe.*layer-class"):
        SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                   error_feedback=True,
                   buckets=(BucketOverride("moe", compress_topk=0.1),))
    # the policy itself is inert without the codec
    with pytest.raises(ValueError, match="inert without the fused codec"):
        SyncConfig("asgd_ga", 1, bucket_policy="layer-class")


# ------------------------------------------------------- sync-round physics


def test_per_bucket_ef_residual_is_exact_per_segment():
    g = _tree(seed=3)
    cfg = SyncConfig(
        "asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
        error_feedback=True, codec_block=256, bucket_policy="layer-class",
        buckets=(BucketOverride("norm", compress_topk=0.5),
                 BucketOverride("moe", value_dtype="int4")))
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    _, st = on_step_gradients(cfg, g, st)
    out, st2 = apply_sync(cfg, p, st, lr=1.0)
    lay = bucket_layout(cfg, p)
    msg = np.asarray(_pack_stacked(st.ga_buffer, lay))
    received = -np.asarray(_pack_stacked(out, lay))
    local = np.roll(received, -cfg.peer_shift, axis=0)
    # the residual is exactly message - decode(encode(message)), per bucket
    np.testing.assert_allclose(np.asarray(st2.ef_residual), msg - local,
                               atol=1e-6)
    # telemetry matches the segment norms
    for gidx in range(len(lay.names)):
        off, size = lay.offsets[gidx], lay.sizes[gidx]
        np.testing.assert_allclose(
            np.asarray(st2.msg_norm)[:, gidx],
            np.linalg.norm(msg[:, off:off + size], axis=1), rtol=1e-5)
    assert tuple(np.asarray(st2.tier)) == cfg.bucket_tiers
    # per-bucket stats expose differentiated ratios (norm@0.5 captures more
    # energy than dense@0.1)
    stats = bucket_stats_from_sync_state(st2, cfg.bucket_names)
    assert stats["norm"].ef_ratio < stats["dense"].ef_ratio


def test_bucketed_run_converges_like_single():
    """Same knobs everywhere: the layer-class partition only re-orders the
    packing, so training converges the same as single-bucket (not
    bit-identical — block boundaries shift — but to the same quality)."""
    rng = np.random.default_rng(0)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["bias"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def init_fn(key):
        return {"w": jax.random.normal(key, (8, 4)) * 0.1,
                "bias": jnp.zeros((4,))}

    from repro.training.trainer import Trainer, TrainerConfig

    def run(policy):
        sync = SyncConfig("asgd_ga", 2, compress_topk=0.2,
                          quantize_int8=True, error_feedback=True,
                          codec_block=128, bucket_policy=policy)
        tr = Trainer(loss_fn, init_fn,
                     TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                                   sync=sync))
        st = tr.init_state(jax.random.key(0))
        losses = []
        data_rng = np.random.default_rng(7)
        for step in range(30):
            x = data_rng.normal(size=(2, 16, 8)).astype(np.float32)
            y = (x[..., :4] * 0.5).astype(np.float32)
            st, m = tr.train_step(st, {"x": jnp.asarray(x),
                                       "y": jnp.asarray(y)})
            st = tr.maybe_sync(st, step)
            losses.append(float(m["loss"]))
        return losses

    single, multi = run("single"), run("layer-class")
    assert multi[-1] < multi[0] * 0.5
    assert multi[-1] == pytest.approx(single[-1], rel=0.25)


# ----------------------- EF carry-over: retune + grow/shrink in one run


def test_ef_residual_carries_across_retune_and_resize_same_run():
    """The satellite guarantee: a bucket's EF residual survives BOTH a
    codec retune and a pod grow/shrink in the same run — sum-preserving
    through the resize, byte-identical through the retune."""
    g = _tree(n_pods=3, seed=5)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(MULTI, p)
    _, st = on_step_gradients(MULTI, g, st)
    _, st = apply_sync(MULTI, p, st, lr=1.0)
    assert float(jnp.linalg.norm(st.ef_residual)) > 0

    # 1. retune: move only the moe bucket's tier — every bucket's residual
    # segment is untouched (dense bucket coordinates are tier-free)
    retuned = SyncConfig(
        "asgd_ga", 2, compress_topk=0.1, quantize_int8=True,
        error_feedback=True, codec_block=256, bucket_policy="layer-class",
        buckets=(BucketOverride("moe", compress_topk=0.02,
                                value_dtype="int4"),))
    st2 = retune_sync_state(retuned, MULTI, st, p)
    np.testing.assert_array_equal(np.asarray(st2.ef_residual),
                                  np.asarray(st.ef_residual))
    assert tuple(np.asarray(st2.tier)) == retuned.bucket_tiers

    # 2. shrink 3 -> 2 pods: per-bucket residual totals are preserved
    # (replay-distribution is sum-preserving on every segment)
    lay = bucket_layout(retuned, p)
    totals_before = [np.asarray(st2.ef_residual)[:, off:off + size].sum()
                     for off, size in zip(lay.offsets, lay.sizes)]
    p2 = jax.tree.map(lambda x: x[:2], p)
    st3 = resize_sync_state(retuned, st2, p2, keep=(0, 1))
    assert st3.ef_residual.shape[0] == 2
    for (off, size), before in zip(zip(lay.offsets, lay.sizes),
                                   totals_before):
        after = np.asarray(st3.ef_residual)[:, off:off + size].sum()
        assert after == pytest.approx(before, abs=1e-4)
    # telemetry re-armed, per-bucket tiers survive
    assert np.asarray(st3.msg_norm).shape == (2, len(BUCKET_CLASSES))
    assert float(np.abs(np.asarray(st3.msg_norm)).max()) == 0.0
    assert tuple(np.asarray(st3.tier)) == retuned.bucket_tiers

    # 3. grow back to 3: joiner starts with zero residual on every bucket
    p3 = jax.tree.map(
        lambda x: jnp.concatenate([x, x[:1]], axis=0), p2)
    st4 = resize_sync_state(retuned, st3, p3)
    assert st4.ef_residual.shape[0] == 3
    np.testing.assert_allclose(np.asarray(st4.ef_residual)[2], 0.0)

    # 4. and a second retune after the resize still carries it
    st5 = retune_sync_state(MULTI, retuned, st4, p3)
    np.testing.assert_array_equal(np.asarray(st5.ef_residual),
                                  np.asarray(st4.ef_residual))


def test_policy_change_retune_remaps_residual():
    g = _tree(seed=9)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(MULTI, p)
    _, st = on_step_gradients(MULTI, g, st)
    _, st = apply_sync(MULTI, p, st, lr=1.0)
    single = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                        error_feedback=True, codec_block=256)
    st_single = retune_sync_state(single, MULTI, st, p)
    st_back = retune_sync_state(MULTI, single, st_single, p)
    # round trip through the single layout is the identity permutation
    np.testing.assert_array_equal(np.asarray(st_back.ef_residual),
                                  np.asarray(st.ef_residual))
    # and no residual mass is lost either way
    assert float(jnp.linalg.norm(st_single.ef_residual)) == pytest.approx(
        float(jnp.linalg.norm(st.ef_residual)), rel=1e-6)
    # telemetry re-armed on the policy change (bucket columns re-labeled)
    assert st_single.msg_norm.shape[1] == 1
    assert float(np.abs(np.asarray(st_single.msg_norm)).max()) == 0.0


def test_trainer_retune_cache_skips_rejit():
    from repro.training.trainer import Trainer, TrainerConfig

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"]) ** 2), {}

    def init_fn(key):
        return {"w": jax.random.normal(key, (4, 2)) * 0.1}

    base = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                      error_feedback=True)
    tr = Trainer(loss_fn, init_fn,
                 TrainerConfig(n_pods=2, optimizer="sgd", sync=base))
    st = tr.init_state(jax.random.key(0))
    # interval-only retune: the compiled sync step is reused as-is
    import dataclasses
    tr2, st = tr.retune(st, dataclasses.replace(base, interval=8))
    assert tr2._sync_step is tr._sync_step
    # tier change: new sync step...
    tier2 = dataclasses.replace(base, interval=8, value_dtype="int4")
    tr3, st = tr2.retune(st, tier2)
    assert tr3._sync_step is not tr2._sync_step
    # ...but returning to a previously compiled rung reuses its executable
    tr4, st = tr3.retune(st, dataclasses.replace(base, interval=2))
    assert tr4._sync_step is tr._sync_step


# ------------------------------------------------------ growth-trend guard


def test_trend_guard_fires_before_absolute_bound():
    """Property (satellite): on a monotone-rising EF-ratio trace the
    growth-trend guard de-escalates BEFORE the ratio reaches the absolute
    bound."""
    base = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                      error_feedback=True)
    for slope in (0.03, 0.05, 0.08):
        c = AdaptiveSyncController(base, 44.6, 0.5, ef_guard=0.9,
                                   hysteresis=1000)  # isolate the guard
        c.rung = 5
        c.current = c.ladder[5]
        ratio, step, fired = 0.05, 0, None
        while ratio < 0.9:
            u = c.update(step, BucketStats(1.0, ratio))
            if u is not None and u.reason == "ef-trend":
                fired = ratio
                break
            assert c.rung == 5, "no other rule may move the rung here"
            ratio, step = ratio + slope, step + 1
        assert fired is not None and fired < 0.9, f"slope {slope}"


def test_trend_guard_ignores_noise_and_benign_drift():
    base = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                      error_feedback=True)
    c = AdaptiveSyncController(base, 44.6, 0.5, ef_guard=0.9,
                               hysteresis=1000)
    c.rung = 5
    c.current = c.ladder[5]
    # non-monotone wiggle far below the guard: never fires
    for step, r in enumerate([0.3, 0.32, 0.31, 0.33, 0.32, 0.34, 0.33]):
        u = c.update(step, BucketStats(1.0, r))
        assert u is None or u.reason != "ef-trend"
    assert c.rung == 5
    # slow drift whose extrapolation stays below the guard: never fires
    c2 = AdaptiveSyncController(base, 44.6, 0.5, ef_guard=0.9,
                                hysteresis=1000, trend_rise=0.02)
    c2.rung = 5
    c2.current = c2.ladder[5]
    for step in range(8):
        u = c2.update(step, BucketStats(1.0, 0.10 + 0.021 * step))
        assert u is None or u.reason != "ef-trend"
    assert c2.rung == 5


# --------------------------------------------- BucketedSyncController law


BMULTI = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                    error_feedback=True, bucket_policy="layer-class")
BMB = {"embed": 10.0, "norm": 0.5, "dense": 30.0, "moe": 0.0}


def _bctrl(**kw):
    kw.setdefault("interval_budget", 8)
    kw.setdefault("max_interval", 12)
    return BucketedSyncController(BMULTI, BMB, 0.5, **kw)


def test_bucketed_controller_requires_layer_class():
    single = SyncConfig("asgd_ga", 4, compress_topk=0.05,
                        quantize_int8=True, error_feedback=True)
    with pytest.raises(ValueError, match="layer-class"):
        BucketedSyncController(single, BMB, 0.5)
    with pytest.raises(ValueError, match="positive-size"):
        BucketedSyncController(BMULTI, {"moe": 0.0}, 0.5)


def test_guard_trip_moves_only_the_tripped_bucket():
    c = _bctrl()
    for b in c.buckets.values():
        b.rung = 4
    u = c.update(0, {"embed": BucketStats(1.0, 0.95),
                     "norm": BucketStats(1.0, 0.2),
                     "dense": BucketStats(1.0, 0.2)})
    assert u is not None and "ef-guard[embed]" in u.reasons
    assert c.buckets["embed"].rung == 3
    assert c.buckets["norm"].rung == 4
    assert c.buckets["dense"].rung == 4


def test_pressure_escalates_biggest_bucket_first():
    c = _bctrl(hysteresis=2)
    for _ in range(6):
        c.observe_wan(5.0)
    calm = {n: BucketStats(1.0, 0.3) for n in c.buckets}
    c.update(0, calm)
    u = c.update(1, calm)
    assert u is not None and any("wan-pressure[dense]" in r
                                 for r in u.reasons)
    # dense (30 MB) sheds bytes; embed/norm keep full fidelity
    assert c.buckets["dense"].rung > 0
    assert c.buckets["embed"].rung == 0
    assert c.buckets["norm"].rung == 0


def test_pressure_never_escalates_guard_stressed_bucket():
    c = _bctrl(hysteresis=1, ef_guard=0.9, escalate_margin=0.8)
    for _ in range(8):
        c.observe_wan(0.5)      # catastrophic link
    stressed = {"embed": BucketStats(1.0, 0.85),   # above 0.72 margin
                "norm": BucketStats(1.0, 0.2),
                "dense": BucketStats(1.0, 0.85)}
    for step in range(6):
        c.update(step, stressed)
    assert c.buckets["embed"].rung == 0
    assert c.buckets["dense"].rung == 0
    # only the calm (tiny) bucket was allowed to trade fidelity
    assert c.buckets["norm"].rung > 0


def test_rearmed_telemetry_blocks_escalation():
    """After a pod resize re-arms telemetry (msg_norm == 0), stale
    pre-resize calm must not license an escalation — same rule as the
    single-bucket controller."""
    c = _bctrl(hysteresis=1)
    calm = {n: BucketStats(1.0, 0.2) for n in c.buckets}
    c.update(0, calm)                       # readings arrive once
    for _ in range(8):
        c.observe_wan(0.5)                  # heavy pressure
    rearmed = {n: BucketStats(0.0, 0.0) for n in c.buckets}
    for step in range(1, 6):
        c.update(step, rearmed)
    assert all(b.rung == 0 for b in c.buckets.values())
    # and the interval stays within the budget (no escape valve on
    # ignorance either)
    assert c.interval <= c.interval_budget


def test_headroom_returns_fidelity_to_most_hurt_bucket():
    c = _bctrl(hysteresis=2)
    for b in c.buckets.values():
        b.rung = 4
    for _ in range(10):
        c.observe_wan(10_000.0)
    stats = {"embed": BucketStats(1.0, 0.7),
             "norm": BucketStats(1.0, 0.2),
             "dense": BucketStats(1.0, 0.4)}
    for step in range(20):
        u = c.update(step, stats)
        if u is not None and any("wan-headroom" in r for r in u.reasons):
            break
    assert c.buckets["embed"].rung == 3       # highest ratio de-escalates
    assert c.buckets["norm"].rung == 4
    assert c.buckets["dense"].rung == 4


def test_combined_config_is_valid_and_applies():
    c = _bctrl()
    c.buckets["dense"].rung = 5
    cfg = c.current
    assert cfg.bucket_policy == "layer-class"
    assert cfg.uses_codec and cfg.error_feedback    # validation ran
    knobs = {o.name for o in cfg.buckets}
    assert knobs == {"embed", "norm", "dense"}
    # resync re-anchors from an externally applied config
    c2 = _bctrl()
    c2.resync(cfg)
    assert c2.buckets["dense"].rung == 5
    assert c2.interval == cfg.interval


def test_bucketed_guard_never_violated_on_random_streams():
    """Safety invariant on random stats streams: a guard trip always
    de-escalates that bucket (or clamps at 0), and no bucket escalates
    while its ratio is at/above the escalation margin."""
    for seed in range(300):
        rng = np.random.default_rng(seed)
        c = _bctrl(hysteresis=int(rng.integers(1, 4)),
                   ef_guard=float(rng.uniform(0.5, 0.95)))
        for i in range(40):
            c.observe_wan(float(rng.uniform(0.5, 200.0)))
            stats, before = {}, {n: b.rung for n, b in c.buckets.items()}
            for n in c.buckets:
                stats[n] = BucketStats(1.0, float(rng.uniform(0.0, 1.0)))
            c.update(i, stats)
            for n, b in c.buckets.items():
                r = stats[n].ef_ratio
                if r >= c.ef_guard:
                    assert b.rung == max(0, before[n] - 1), (seed, i, n)
                elif r >= c.escalate_margin * c.ef_guard:
                    assert b.rung <= before[n], (seed, i, n)
                assert 0 <= b.rung < len(b.ladder)
            assert c.min_interval <= c.interval <= c.max_interval


# -------------------------------------------- user-defined pattern tables


def test_bucket_spec_parse_presets_and_custom():
    from repro.core.sync import (DEFAULT_BUCKET_SPEC, MOE_ROUTER_BUCKET_SPEC,
                                 BucketSpec)

    assert BucketSpec.parse("default") is DEFAULT_BUCKET_SPEC
    assert BucketSpec.parse("") is DEFAULT_BUCKET_SPEC
    assert BucketSpec.parse("moe-router") is MOE_ROUTER_BUCKET_SPEC
    spec = BucketSpec.parse(
        "router=router;moe=moe|expert;embed=embed|vocab;norm=norm|bias;"
        "dense=;vector=norm;fallback=dense")
    assert spec.names == ("router", "moe", "embed", "norm", "dense")
    assert spec.patterns[0] == ("router", ("router",))
    assert spec.vector_bucket == "norm" and spec.fallback == "dense"
    # precedence: first entry wins
    assert spec.classify("moe/router", 2) == "router"
    assert spec.classify("moe/wg", 3) == "moe"
    with pytest.raises(ValueError, match="no bucket groups"):
        BucketSpec.parse("vector=norm")
    with pytest.raises(ValueError, match="name=sub1"):
        BucketSpec.parse("router")
    # a typoed directive target is refused, not silently created as a
    # phantom group that would swallow every fallthrough leaf
    with pytest.raises(ValueError, match="undeclared bucket group"):
        BucketSpec.parse("embed=embed;dense=;fallback=dens")
    # fallback default prefers the declared pattern-less catch-all —
    # never the most-specific FIRST group
    moe = BucketSpec.parse("router=router;moe=moe|expert;norm=norm;rest=")
    assert moe.fallback == "rest"
    assert moe.classify("mlp/w_up", 2) == "rest"
    # spec-level validation: pattern groups must be declared names
    with pytest.raises(ValueError, match="not one of its names"):
        from repro.core.sync import BucketSpec as BS
        BS(names=("a",), patterns=(("b", ("x",)),), vector_bucket="a",
           fallback="a")


def test_moe_router_preset_splits_routers_from_experts():
    """The ROADMAP item: under the moe-router table the router matrix gets
    its OWN group (own knobs, own EF telemetry) instead of riding the
    expert group — while the default table keeps today's behaviour."""
    from repro.core.sync import MOE_ROUTER_BUCKET_SPEC

    t = {"moe": {"router": jnp.zeros((2, 16, 4)),
                 "wg": jnp.zeros((2, 4, 16, 8))},
         "mlp": {"w": jnp.zeros((2, 16, 16))}}
    # default: router rides the expert group (leaves flatten in sorted
    # key order: mlp/w, moe/router, moe/wg)
    lay = bucket_layout(MULTI, t)
    names = [lay.names[b] for b in lay.leaf_bucket]
    assert names == ["dense", "moe", "moe"]
    # moe-router: routers split out
    routed = SyncConfig(
        "asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
        error_feedback=True, codec_block=256, bucket_policy="layer-class",
        bucket_spec=MOE_ROUTER_BUCKET_SPEC)
    lay2 = bucket_layout(routed, t)
    names2 = [lay2.names[b] for b in lay2.leaf_bucket]
    assert names2 == ["dense", "router", "moe"]
    # ...and the split group takes its own override, validated by name
    cfg = SyncConfig(
        "asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
        error_feedback=True, codec_block=256, bucket_policy="layer-class",
        bucket_spec=MOE_ROUTER_BUCKET_SPEC,
        buckets=(BucketOverride("router", compress_topk=0.5),))
    assert cfg.bucket_knobs("router")[0] == 0.5
    assert cfg.bucket_knobs("moe")[0] == 0.1
    with pytest.raises(ValueError, match="bucket 'router'"):
        SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                   error_feedback=True, bucket_policy="layer-class",
                   buckets=(BucketOverride("router", compress_topk=0.5),))


def test_custom_spec_runs_a_sync_round_end_to_end():
    """A custom table flows through layout, telemetry widths, knobs and an
    actual codec sync round (per-group EF segments)."""
    from repro.core.sync import BucketSpec

    spec = BucketSpec.parse("emb=embed;rest=")
    assert spec.fallback == "rest"      # the pattern-less catch-all
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.2, quantize_int8=True,
                     error_feedback=True, codec_block=128,
                     bucket_policy="layer-class", bucket_spec=spec,
                     buckets=(BucketOverride("emb", compress_topk=0.5),))
    assert cfg.bucket_names == ("emb", "rest")
    g = _tree(seed=11)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    assert st.msg_norm.shape == (2, 2)
    _, st = on_step_gradients(cfg, g, st)
    out, st2 = apply_sync(cfg, p, st, lr=1.0)
    assert float(jnp.linalg.norm(st2.ef_residual)) > 0
    assert np.all(np.asarray(st2.msg_norm) > 0)
    stats = bucket_stats_from_sync_state(st2, cfg.bucket_names)
    # emb@0.5 captures more energy than the 0.2-topk rest bucket
    assert stats["emb"].ef_ratio < stats["rest"].ef_ratio


# ------------------------------------------- per-bucket codec_block override


def test_per_bucket_codec_block_is_billed_and_validated():
    base = dict(compress_topk=0.05, quantize_int8=True, error_feedback=True,
                bucket_policy="layer-class")
    cfg = SyncConfig("asgd_ga", 4, **base,
                     buckets=(BucketOverride("embed", codec_block=256),))
    assert cfg.bucket_knobs("embed") == (0.05, "int8", 256)
    assert cfg.bucket_knobs("dense") == (0.05, "int8", 4096)
    # the 1/block scale term is billed per bucket: smaller block, more
    # scales, strictly more wire bytes for the overridden group
    w = {"embed": 0.25, "norm": 0.05, "dense": 0.5, "moe": 0.2}
    plain = SyncConfig("asgd_ga", 4, **base)
    assert cfg.payload_mb(100.0, bucket_weights=w) > \
        plain.payload_mb(100.0, bucket_weights=w)
    expect = sum(cfg.for_bucket(n).payload_mb(100.0 * w[n])
                 for n in cfg.bucket_names)
    assert cfg.payload_mb(100.0, bucket_weights=w) == pytest.approx(expect)
    # the cost table shows the block next to the payload it produced
    from repro.core.cost import bucket_payload_table
    table = bucket_payload_table(cfg, {n: 100.0 * w[n]
                                       for n in cfg.bucket_names})
    assert table["embed"]["codec_block"] == 256
    assert table["dense"]["codec_block"] == 4096
    # validation names the offending group
    with pytest.raises(ValueError, match="bucket 'embed'.*codec_block"):
        SyncConfig("asgd_ga", 4, **base,
                   buckets=(BucketOverride("embed", codec_block=64),))


def test_per_bucket_codec_block_sync_round_is_exact():
    """A per-bucket block override changes the selection granularity but
    the EF residual is still exactly message - decode(encode(message))
    per segment."""
    g = _tree(seed=13)
    cfg = SyncConfig(
        "asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
        error_feedback=True, codec_block=256, bucket_policy="layer-class",
        buckets=(BucketOverride("dense", codec_block=512),
                 BucketOverride("embed", codec_block=128)))
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    _, st = on_step_gradients(cfg, g, st)
    out, st2 = apply_sync(cfg, p, st, lr=1.0)
    lay = bucket_layout(cfg, p)
    msg = np.asarray(_pack_stacked(st.ga_buffer, lay))
    received = -np.asarray(_pack_stacked(out, lay))
    local = np.roll(received, -cfg.peer_shift, axis=0)
    np.testing.assert_allclose(np.asarray(st2.ef_residual), msg - local,
                               atol=1e-6)


# ------------------------------------------------------------ launcher glue


def test_parse_bucket_overrides():
    from repro.launch.train import parse_bucket_overrides

    got = parse_bucket_overrides("embed:topk=0.02:dtype=int4,norm:dtype=int8")
    assert got == (BucketOverride("embed", compress_topk=0.02,
                                  value_dtype="int4"),
                   BucketOverride("norm", value_dtype="int8"))
    assert parse_bucket_overrides("") == ()
    # per-bucket codec_block override rides the same syntax
    assert parse_bucket_overrides("embed:block=1024") == (
        BucketOverride("embed", codec_block=1024),)
    with pytest.raises(ValueError, match="unknown override key"):
        parse_bucket_overrides("embed:threshold=0.5")
