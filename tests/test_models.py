"""Model-layer correctness: RoPE/M-RoPE, GQA, masks, MoE dispatch,
decode-vs-forward consistency, prefill-cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import layers as L
from repro.models import moe as M
from repro.models import transformer as T
from repro.models.config import LayerSpec, ModelConfig, MoEConfig

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ------------------------------------------------------------------- RoPE


def test_rope_relative_shift_invariance():
    """<RoPE(q,i), RoPE(k,j)> depends only on i-j."""
    Dh = 64
    q = _rand((1, 1, 1, Dh))
    k = _rand((1, 1, 1, Dh))
    def dot(i, j):
        qi = L.apply_rope(q, jnp.array([[i]]), 10000.0)
        kj = L.apply_rope(k, jnp.array([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert dot(5, 3) == pytest.approx(dot(105, 103), rel=1e-4)
    assert dot(7, 0) == pytest.approx(dot(57, 50), rel=1e-4)


def test_rope_preserves_norm():
    x = _rand((2, 8, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_mrope_equals_rope_when_positions_equal():
    """With t=h=w positions, M-RoPE == standard RoPE."""
    x = _rand((2, 8, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    mpos = jnp.broadcast_to(pos[None], (3, 2, 8))
    y1 = L.apply_rope(x, pos, 10000.0)
    y2 = L.apply_mrope(x, mpos, 10000.0, (16, 8, 8))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_mrope_sections_rotate_independently():
    x = jnp.ones((1, 1, 1, 64))
    t_only = jnp.asarray([[[3]], [[0]], [[0]]])
    h_only = jnp.asarray([[[0]], [[3]], [[0]]])
    yt = L.apply_mrope(x, t_only, 10000.0, (16, 8, 8))
    yh = L.apply_mrope(x, h_only, 10000.0, (16, 8, 8))
    # the t-section (first 16 freq slots) differs, the h-section matches ones
    assert float(jnp.abs(yt[..., :16] - yh[..., :16]).max()) > 1e-3
    np.testing.assert_allclose(np.asarray(yt[..., 16:24]),
                               np.asarray(x[..., 16:24]), atol=1e-6)


# ------------------------------------------------------------------- masks


def test_attn_bias_causal_window():
    qp = jnp.arange(6)[None]
    kp = jnp.arange(6)[None]
    bias = L.attn_bias(qp, kp, None, causal=True, window=3)[0, 0]
    vis = np.asarray(bias) == 0.0
    for i in range(6):
        for j in range(6):
            assert vis[i, j] == (j <= i and j > i - 3)


def test_softcap_bounds_logits():
    x = jnp.asarray([-1e4, -10.0, 0.0, 10.0, 1e4])
    y = L._softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(np.asarray(L._softcap(x, 0.0)), np.asarray(x))


# --------------------------------------------------------------------- GQA


def test_gqa_equals_repeated_kv():
    B, S, H, K, Dh = 1, 16, 8, 2, 32
    q, k, v = _rand((B, S, H, Dh)), _rand((B, S, K, Dh)), _rand((B, S, K, Dh))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    bias = L.attn_bias(pos, pos, None, True, None)
    out = L.sdpa_reference(q, k, v, bias)
    kr = jnp.repeat(k, H // K, axis=2)
    vr = jnp.repeat(v, H // K, axis=2)
    out2 = L.sdpa_reference(q, kr, vr, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-5)


# --------------------------------------------------------------------- MoE


def _moe_cfg(E=4, k=2):
    return ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                       pattern=(LayerSpec(moe=True),),
                       moe=MoEConfig(num_experts=E, top_k=k,
                                     capacity_factor=4.0),
                       param_dtype="float32", compute_dtype="float32")


def test_moe_matches_dense_computation():
    """With capacity high enough that nothing drops, the sort-based dispatch
    must equal the naive per-token expert evaluation."""
    cfg = _moe_cfg()
    params = M.moe_init(jax.random.key(0), cfg)
    x = _rand((2, 8, 32))
    y, aux = M.moe_apply(params, cfg, x)

    # naive: evaluate every expert densely, combine by router weights
    logits = (x.reshape(-1, 32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    xt = x.reshape(-1, 32)
    dense = []
    for e in range(cfg.moe.num_experts):
        g = jax.nn.silu(xt @ params["wg"][e])
        u = xt @ params["wu"][e]
        dense.append((g * u) @ params["wd"][e])
    dense = jnp.stack(dense, 1)                     # (T, E, D)
    expect = jnp.zeros_like(xt)
    for slot in range(cfg.moe.top_k):
        sel = jnp.take_along_axis(dense, top_e[:, slot][:, None, None]
                                  .repeat(32, -1), axis=1)[:, 0]
        expect = expect + sel * top_p[:, slot][:, None]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 32)),
                               np.asarray(expect), atol=2e-5)
    assert float(aux["lb_loss"]) >= 1.0 - 1e-6      # >= 1 by Cauchy-Schwarz


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg().replace(moe=MoEConfig(num_experts=4, top_k=2,
                                           capacity_factor=0.1))
    params = M.moe_init(jax.random.key(0), cfg)
    x = _rand((2, 32, 32))
    y, _ = M.moe_apply(params, cfg, x)
    assert bool(jnp.isfinite(y).all())
    # with tiny capacity most tokens drop -> output mostly zeros
    frac_zero = float(jnp.mean((jnp.abs(y) < 1e-9).all(-1).astype(jnp.float32)))
    assert frac_zero > 0.3


def test_moe_grad_flows_to_router():
    cfg = _moe_cfg()
    params = M.moe_init(jax.random.key(0), cfg)
    x = _rand((1, 8, 32))

    def f(p):
        y, aux = M.moe_apply(p, cfg, x)
        return jnp.sum(y ** 2) + M.moe_loss(aux, cfg)

    g = jax.grad(f)(params)
    assert float(jnp.abs(g["router"]).max()) > 0


# ------------------------------------------- decode vs forward consistency


def _dropless(cfg: ModelConfig) -> ModelConfig:
    """Remove MoE capacity dropping: capacity covers worst-case routing.

    Capacity-based token-choice MoE makes forward logits depend on the
    *other* tokens in the batch: when an expert overflows its capacity
    ``C = ceil(T*K*cf/E)``, the overflow tokens are dropped (their expert
    output is zero).  Single-token decode (T=1) never overflows, so
    teacher-forced decode cannot reproduce dropped positions — with the
    stock qwen3 smoke config, layer 0 drops 2/48 slots at S=24, which was
    the root cause of the historical ``test_decode_matches_forward`` parity
    failure.  ``cf = E/K`` makes C >= T for any routing, isolating what the
    test is about: cache/decode correctness, not capacity semantics."""
    if not cfg.has_moe:
        return cfg
    import dataclasses
    m = cfg.moe
    return cfg.replace(moe=dataclasses.replace(
        m, capacity_factor=float(m.num_experts) / m.top_k))


@pytest.mark.parametrize("name", ["granite-8b", "gemma2-27b", "mamba2-1.3b",
                                  "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(name):
    """Teacher forcing: stepping token-by-token through the decode cache must
    reproduce the full-sequence forward logits (exercises ring buffers for
    gemma2, SSM state for mamba2, MoE routing under batch=decode)."""
    arch = get_arch(name)
    cfg = _dropless(arch.smoke)
    S = 24
    params = T.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    full_logits, _ = T.forward(params, cfg, tokens)

    cache = T.init_cache(cfg, 1, S)
    outs = []
    for t in range(S):
        logits, cache = T.decode_step(params, cfg, tokens[:, t:t + 1], cache,
                                      jnp.int32(t))
        outs.append(logits[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), atol=2e-3, rtol=2e-2)


def test_prefill_then_decode_matches_forward():
    arch = get_arch("granite-8b")
    cfg = arch.smoke
    S, extra = 16, 4
    params = T.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (1, S + extra), 0,
                                cfg.vocab_size)
    full_logits, _ = T.forward(params, cfg, tokens)

    logits, cache = T.prefill(params, cfg, tokens[:, :S], S + extra)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, S - 1]),
                               atol=2e-3, rtol=2e-2)
    pos = S
    for t in range(extra):
        step, cache = T.decode_step(params, cfg, tokens[:, S + t:S + t + 1],
                                    cache, jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(step[:, 0]),
                                   np.asarray(full_logits[:, S + t]),
                                   atol=2e-3, rtol=2e-2)
        pos += 1


def test_sliding_window_ring_cache_long_decode():
    """Decode far past the window: ring cache must equal a fresh forward over
    the visible window."""
    arch = get_arch("gemma3-12b")
    cfg = arch.smoke          # all windows = 16 in smoke; pattern 5 local + 1 global
    W = 16
    S = 40                     # > 2x window
    params = T.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    full_logits, _ = T.forward(params, cfg, tokens)
    cache = T.init_cache(cfg, 1, S)
    for t in range(S):
        logits, cache = T.decode_step(params, cfg, tokens[:, t:t + 1], cache,
                                      jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-3, rtol=2e-2)


def test_whisper_decode_matches_forward():
    arch = get_arch("whisper-tiny")
    cfg = arch.smoke
    from repro.models import encdec
    params = encdec.init_params(jax.random.key(0), cfg)
    S = 12
    tokens = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    audio = _rand((1, cfg.encoder_ctx, cfg.d_model), scale=0.1)
    full_logits, _ = encdec.forward(params, cfg, tokens, audio)
    enc = encdec.encode(params, cfg, audio)
    cache = encdec.init_cache(cfg, 1, S, enc=enc, params=params)
    for t in range(S):
        logits, cache = encdec.decode_step(params, cfg, tokens[:, t:t + 1],
                                           cache, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   atol=2e-3, rtol=2e-2)


def test_moe_grouped_dispatch_matches_global():
    """Group-local dispatch (the collective-term optimization) is numerically
    identical to global dispatch when capacity doesn't bind."""
    cfg = _moe_cfg().replace(moe_dispatch="grouped")
    params = M.moe_init(jax.random.key(0), cfg)
    x = _rand((3, 16, 32))
    y1, a1 = M.moe_apply(params, cfg.replace(moe_dispatch="global"), x)
    y2, a2 = M.moe_apply(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1["lb_loss"]), float(a2["lb_loss"]),
                               rtol=1e-5)
