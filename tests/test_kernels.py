"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.topk_compress import topk_compress_pallas

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize("B,S,H,K,Dh", [
    (2, 128, 4, 2, 64),
    (1, 256, 4, 4, 64),
    (2, 96, 6, 2, 32),     # non-multiple of block
    (1, 64, 8, 1, 128),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(B, S, H, K, Dh, dtype):
    q, k, v = (_rand((B, S, H, Dh), dtype), _rand((B, S, K, Dh), dtype),
               _rand((B, S, K, Dh), dtype))
    out = flash_attention(q, k, v, causal=True, interpret=True,
                          block_q=64, block_k=64)
    expect = ref.sdpa(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_flash_window_softcap(window, softcap):
    q, k, v = (_rand((1, 128, 4, 64), jnp.float32),
               _rand((1, 128, 2, 64), jnp.float32),
               _rand((1, 128, 2, 64), jnp.float32))
    out = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, interpret=True,
                          block_q=32, block_k=32)
    expect = ref.sdpa(q, k, v, causal=True, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    q, k, v = (_rand((2, 64, 2, 32), jnp.float32),
               _rand((2, 64, 2, 32), jnp.float32),
               _rand((2, 64, 2, 32), jnp.float32))
    out = flash_attention(q, k, v, causal=False, interpret=True,
                          block_q=32, block_k=32)
    expect = ref.sdpa(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------ SSD scan


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 16, 32, 32),
    (1, 256, 2, 64, 128, 64),
    (2, 64, 8, 8, 16, 64),
])
def test_ssd_kernel_matches_chunked_ref(B, S, H, P, N, chunk):
    x = _rand((B, S, H, P), jnp.float32)
    a = -jnp.abs(_rand((B, S, H), jnp.float32)) * 0.1
    Bm, Cm = _rand((B, S, H, N), jnp.float32), _rand((B, S, H, N), jnp.float32)
    y1, f1 = ssd_scan(x, a, Bm, Cm, chunk=chunk, interpret=True)
    y2, f2 = ref.ssd(x, a, Bm, Cm, chunk=chunk)
    scale = float(jnp.max(jnp.abs(y2)))
    np.testing.assert_allclose(np.asarray(y1) / scale, np.asarray(y2) / scale,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-3)


def test_ssd_chunked_matches_naive_recurrence():
    """Anchor: the chunked SSD algorithm == the literal per-step recurrence."""
    B, S, H, P, N = 1, 64, 2, 8, 16
    x = _rand((B, S, H, P), jnp.float32)
    a = -jnp.abs(_rand((B, S, H), jnp.float32)) * 0.2
    Bm, Cm = _rand((B, S, H, N), jnp.float32), _rand((B, S, H, N), jnp.float32)
    s0 = _rand((B, H, P, N), jnp.float32)
    y1, f1 = ref.ssd(x, a, Bm, Cm, chunk=16, init_state=s0)
    y2, f2 = ref.ssd_naive(x, a, Bm, Cm, init_state=s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               atol=1e-4, rtol=1e-4)


def test_ssd_with_initial_state_continues_stream():
    """Splitting a sequence in two with state carry == one full pass."""
    B, S, H, P, N = 1, 128, 2, 8, 16
    x = _rand((B, S, H, P), jnp.float32)
    a = -jnp.abs(_rand((B, S, H), jnp.float32)) * 0.1
    Bm, Cm = _rand((B, S, H, N), jnp.float32), _rand((B, S, H, N), jnp.float32)
    y_full, f_full = ref.ssd(x, a, Bm, Cm, chunk=32)
    y1, f1 = ref.ssd(x[:, :64], a[:, :64], Bm[:, :64], Cm[:, :64], chunk=32)
    y2, f2 = ref.ssd(x[:, 64:], a[:, 64:], Bm[:, 64:], Cm[:, 64:], chunk=32,
                     init_state=f1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f_full), atol=1e-4)


# --------------------------------------------------------------------- top-k


@pytest.mark.parametrize("n,k,block", [(4096, 64, 512), (1000, 16, 256),
                                       (8192, 128, 1024), (256, 8, 256)])
def test_topk_kernel_matches_ref(n, k, block):
    x = _rand((n,), jnp.float32)
    v1, i1 = topk_compress_pallas(x, k, block=block, interpret=True)
    v2, i2 = ref.topk_block(x, k, block=block)
    d1 = ref.topk_decompress(v1, i1, n)
    d2 = ref.topk_decompress(v2, i2, n)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_topk_block_energy_close_to_exact():
    x = _rand((8192,), jnp.float32)
    k = 256
    db = ref.topk_decompress(*ref.topk_block(x, k, block=1024), 8192)
    de = ref.topk_decompress(*ref.topk_exact(x, k), 8192)
    assert float(jnp.sum(db ** 2)) >= 0.9 * float(jnp.sum(de ** 2))


def test_topk_roundtrip_preserves_selected():
    x = _rand((512,), jnp.float32)
    v, i = ref.topk_block(x, 32, block=128)
    d = ref.topk_decompress(v, i, 512)
    np.testing.assert_allclose(np.asarray(d[np.asarray(i)]), np.asarray(v))
