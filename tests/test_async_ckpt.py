"""Async snapshot engine + live pod migration: the checkpoint-equivalence
suite that locks the elastic-reconfig path down.

Covers the engine's durability contract (``last_durable`` only advances
after the atomic rename; partial commits are never visible; retention
prunes to ``keep``), its failure surface (background errors re-raised by
``wait``; externally-corrupted snapshots skipped on restore), and — the
acceptance bar — that a live migration through ``LiveMigrator`` is
step-for-step loss-identical to a pause-and-restore reconfiguration on the
same event trace.
"""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.checkpoint.async_engine import (AsyncCheckpointEngine,
                                           SnapshotError,
                                           blocking_equivalent, list_steps,
                                           step_dir)
from repro.core.control_plane import (CloudEvent, ElasticityController,
                                      TrainingRequest, build_training_plan)
from repro.core.scheduler import CloudResources
from repro.core.sync import SyncConfig, is_sync_step
from repro.training.trainer import (LiveMigrator, Trainer, TrainerConfig,
                                    apply_reconfig)

CLOUDS = (CloudResources("sh", (("cascade", 6),), data_size=2.0),
          CloudResources("cq", (("sky", 6),), data_size=1.0),
          CloudResources("bj", (("sky", 3),), data_size=1.0))


def _tree(n_pods, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(n_pods, 6, 3)), jnp.float32),
        "opt": {"m": jnp.asarray(rng.normal(size=(n_pods, 6, 3)),
                                 jnp.float32)},
        "bias": jnp.asarray(rng.normal(size=(n_pods, 3)), jnp.float32),
    }


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------ commit & retention


def test_engine_commits_and_prunes_to_keep(tmp_path):
    eng = AsyncCheckpointEngine(str(tmp_path), keep=2)
    for s in range(5):
        eng.snapshot(_tree(2, seed=s), s)
    eng.wait()
    assert eng.committed == 5
    assert list_steps(str(tmp_path)) == [3, 4]
    step, path = eng.last_durable()
    assert step == 4 and path == step_dir(str(tmp_path), 4)
    eng.close()


def test_engine_rejects_keepless_retention(tmp_path):
    with pytest.raises(ValueError, match="keep"):
        AsyncCheckpointEngine(str(tmp_path), keep=0)


def test_engine_reseeds_durable_steps_from_disk(tmp_path):
    eng = AsyncCheckpointEngine(str(tmp_path), keep=3)
    eng.snapshot(_tree(2), 7)
    eng.close()
    eng2 = AsyncCheckpointEngine(str(tmp_path), keep=3)
    assert eng2.last_durable()[0] == 7
    eng2.close()


def test_async_snapshot_matches_blocking_save(tmp_path):
    """The engine's commit is byte-for-byte the checkpoint layer's writer:
    restored trees and manifest structure match a blocking ``save`` of the
    same tree at the same step (file bytes differ only by zip mtimes)."""
    tree = _tree(3, seed=11)
    eng = AsyncCheckpointEngine(str(tmp_path / "async"), keep=1)
    eng.snapshot(tree, 42, metadata={"pods": 3})
    eng.wait()
    _, apath = eng.last_durable()
    bpath = blocking_equivalent(tree, 42, str(tmp_path / "block"),
                                metadata={"pods": 3})
    like = jax.tree.map(jnp.zeros_like, tree)
    a, astep = ckpt.restore(apath, like)
    b, bstep = ckpt.restore(bpath, like)
    assert astep == bstep == 42
    _assert_trees_equal(a, b)
    ma, mb = ckpt.load_manifest(apath), ckpt.load_manifest(bpath)
    for k in ("keys", "dtypes", "shapes", "step", "metadata"):
        assert ma[k] == mb[k]
    eng.close()


def test_donated_buffers_are_reused_across_snapshots(tmp_path):
    eng = AsyncCheckpointEngine(str(tmp_path), keep=1)
    eng.snapshot(_tree(2, seed=0), 0)
    eng.wait()
    bufs0 = dict(eng._host_bufs)
    eng.snapshot(_tree(2, seed=1), 1)
    eng.wait()
    assert all(eng._host_bufs[i] is bufs0[i] for i in bufs0)
    out, _ = ckpt.restore(eng.last_durable()[1],
                          jax.tree.map(jnp.zeros_like, _tree(2)))
    _assert_trees_equal(out, _tree(2, seed=1))
    eng.close()


# --------------------------------------------------- durability under race


def _gated_engine(root, keep=2):
    """Engine whose commit blocks on an event — lets a test observe the
    window between enqueue and the atomic rename."""
    eng = AsyncCheckpointEngine(root, keep=keep)
    gate = threading.Event()
    orig = eng._commit_snapshot

    def gated(*item):
        assert gate.wait(timeout=30)
        orig(*item)

    eng._commit_snapshot = gated
    return eng, gate


def test_last_durable_advances_only_after_commit(tmp_path):
    eng, gate = _gated_engine(str(tmp_path))
    eng.snapshot(_tree(2), 5)
    # in flight: not durable, and no partial step dir is visible on disk
    assert eng.last_durable() is None
    assert list_steps(str(tmp_path)) == []
    gate.set()
    eng.wait()
    assert eng.last_durable()[0] == 5
    assert list_steps(str(tmp_path)) == [5]
    eng.close()


def test_restore_last_drains_inflight_snapshots(tmp_path):
    eng, gate = _gated_engine(str(tmp_path))
    tree = _tree(2, seed=9)
    eng.snapshot(tree, 3)
    gate.set()
    out, step = eng.restore_last(like=jax.tree.map(jnp.zeros_like, tree))
    assert step == 3
    _assert_trees_equal(out, tree)
    eng.close()


def test_wait_surfaces_background_failure_as_snapshot_error(tmp_path):
    eng = AsyncCheckpointEngine(str(tmp_path), keep=1)

    def boom(*item):
        raise OSError("disk detached")

    eng._commit_snapshot = boom
    eng.snapshot(_tree(2), 1)
    with pytest.raises(SnapshotError, match="disk detached"):
        eng.wait()
    eng.close()


def test_restore_last_falls_back_past_corrupted_newest(tmp_path):
    """An externally-damaged newest snapshot (truncated arrays.npz) is
    skipped and the previous durable snapshot restores instead."""
    eng = AsyncCheckpointEngine(str(tmp_path), keep=3)
    older = _tree(2, seed=1)
    eng.snapshot(older, 1)
    eng.snapshot(_tree(2, seed=2), 2)
    eng.wait()
    apath = os.path.join(step_dir(str(tmp_path), 2), "arrays.npz")
    with open(apath, "rb") as f:
        blob = f.read()
    with open(apath, "wb") as f:
        f.write(blob[: len(blob) // 2])
    out, step = eng.restore_last(like=jax.tree.map(jnp.zeros_like, older))
    assert step == 1
    _assert_trees_equal(out, older)
    eng.close()


def test_restore_last_with_nothing_durable_raises(tmp_path):
    eng = AsyncCheckpointEngine(str(tmp_path), keep=1)
    with pytest.raises(FileNotFoundError):
        eng.restore_last(like=_tree(2))
    eng.close()


# ------------------------------------- the checkpoint-equivalence contract


def _loss(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _init(key):
    return {"w": jax.random.normal(key, (4, 1)) * 0.1}


def _batch(n_pods, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n_pods, 8, 4)).astype(np.float32)
    w = rng.normal(size=(4, 1)).astype(np.float32)
    y = x @ w + 0.01 * rng.normal(size=(n_pods, 8, 1)).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def _run_trace(root, live, n_steps=16, event_step=5):
    """One elastic run over a fixed event trace: ``cloud_left`` fires at
    ``event_step``, the reconfig lands at the next sync barrier.

    ``live=False`` is the reference arm — pause at the barrier, blocking
    checkpoint save + restore, re-stack.  ``live=True`` is the migration
    arm — async barrier snapshots, ``stage`` at event time off the step
    path, ``reconcile`` at the barrier.  Returns the per-step loss stream.
    """
    sync = SyncConfig("asgd_ga", 4, compress_topk=0.25, quantize_int8=True,
                      error_feedback=True, codec_block=128)
    plan = build_training_plan(TrainingRequest(
        model="m", clouds=CLOUDS, sync=sync, global_batch=96))
    ctl = ElasticityController(plan)
    trainer = Trainer(_loss, _init,
                      TrainerConfig(n_pods=3, optimizer="momentum", lr=0.05,
                                    sync=sync))
    state = trainer.init_state(jax.random.key(0), same_init=False)
    engine = AsyncCheckpointEngine(os.path.join(root, "snaps"),
                                   keep=2) if live else None
    migrator = LiveMigrator(engine) if live else None
    if live:
        engine.snapshot(state, 0)
    losses, pending = [], None
    for step in range(n_steps):
        state, m = trainer.train_step(state,
                                      _batch(trainer.cfg.n_pods, step))
        state = trainer.maybe_sync(state, step)
        losses.append(float(m["loss"]))
        at_barrier = is_sync_step(trainer.cfg.sync, step)
        if live and at_barrier:
            engine.snapshot(state, step + 1)
        if step == event_step:
            pending = ctl.handle(CloudEvent("cloud_left", region="cq",
                                            time_s=float(step)))
            if live:
                keep, n_new = pending.pod_transition()
                migrator.stage(state, n_new, keep=keep)
        if pending is not None and at_barrier:
            if live:
                trainer, state, applied = migrator.reconcile(
                    trainer, state, pending)
            else:
                d = os.path.join(root, f"pause_{step + 1}")
                ckpt.save(d, state, step=step + 1)
                state, _ = ckpt.restore(d, like=state)
                trainer, state, applied = apply_reconfig(
                    trainer, state, pending)
            assert applied
            pending = None
    if live:
        assert migrator.migrations == 1
        assert not migrator.errors
        assert migrator.last_staged is not None
        assert migrator.last_staged["n_new"] == trainer.cfg.n_pods
        engine.close()
    return np.asarray(losses)


def test_live_migration_loss_identical_to_pause_and_restore(tmp_path):
    """The acceptance bar: a migrated run is step-for-step loss-identical
    to a pause-and-restore run on the same event trace — the staged
    snapshot pre-moves bytes but never perturbs the numerics, and the fp32
    checkpoint round-trip of the pause arm is exact."""
    ref = _run_trace(str(tmp_path / "pause"), live=False)
    mig = _run_trace(str(tmp_path / "live"), live=True)
    np.testing.assert_array_equal(ref, mig)


def test_stage_supersedes_and_stale_stage_degrades(tmp_path):
    """Two events between barriers: the second stage supersedes the first
    (counted, not reconciled), and reconcile still re-stacks correctly."""
    sync = SyncConfig("asgd_ga", 8)
    plan = build_training_plan(TrainingRequest(
        model="m", clouds=CLOUDS, sync=sync, global_batch=96))
    ctl = ElasticityController(plan)
    trainer = Trainer(_loss, _init,
                      TrainerConfig(n_pods=3, optimizer="sgd", lr=0.05,
                                    sync=sync))
    state = trainer.init_state(jax.random.key(1), same_init=False)
    engine = AsyncCheckpointEngine(str(tmp_path), keep=2)
    migrator = LiveMigrator(engine)
    engine.snapshot(state, 0)
    rc = ctl.handle(CloudEvent("cloud_left", region="cq", time_s=1.0))
    migrator.stage(state, rc.pod_transition()[1])
    migrator.stage(state, rc.pod_transition()[1])   # supersedes the first
    trainer, state, applied = migrator.reconcile(trainer, state, rc)
    assert applied and trainer.cfg.n_pods == 2
    assert migrator.restaged == 1 and migrator.migrations == 1
    engine.close()


def test_stage_without_durable_snapshot_degrades_cleanly(tmp_path):
    """No durable snapshot yet: stage is a no-op and reconcile falls back
    to the plain barrier re-stack (nothing staged, nothing raised)."""
    sync = SyncConfig("asgd_ga", 8)
    plan = build_training_plan(TrainingRequest(
        model="m", clouds=CLOUDS, sync=sync, global_batch=96))
    ctl = ElasticityController(plan)
    trainer = Trainer(_loss, _init,
                      TrainerConfig(n_pods=3, optimizer="sgd", lr=0.05,
                                    sync=sync))
    state = trainer.init_state(jax.random.key(2), same_init=False)
    engine = AsyncCheckpointEngine(str(tmp_path), keep=2)
    migrator = LiveMigrator(engine)
    rc = ctl.handle(CloudEvent("cloud_left", region="cq", time_s=1.0))
    migrator.stage(state, rc.pod_transition()[1])
    trainer, state, applied = migrator.reconcile(trainer, state, rc)
    assert applied and trainer.cfg.n_pods == 2
    assert migrator.last_staged is None and not migrator.errors
    engine.close()
