"""Network-aware hierarchical aggregation topology (PR 6): parity of the
hierarchical transport with the legacy inline ring (bit-exact params +
telemetry at every sync, across random pod counts / region groupings /
bucket policies / seeds), EF carry across a mid-run topology retune,
schedule compilation (ring ordering, tree rooting, auxiliary-route
fallback on cliff-snapped links), the link-collapse reroute-within-one-
round + EF-guard-never-violated invariants (seeded-random stream style,
as in test_buckets), the topology planner's switch law, the third-actuator
wiring in AdaptiveSyncController, and exact traffic accounting against
the DES billing in core.wan.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import AdaptiveSyncController, BucketStats
from repro.core.cost import adaptive_traffic_mb, bucket_payload_table
from repro.core.sync import (BucketOverride, SyncConfig,
                             hierarchical_average)
from repro.core.topology import (HierarchicalTransport, LinkBeliefs,
                                 TopologyPlanner, TopologySpec, link_key)
from repro.core.transport import MeasuredWanProbe
from repro.core.wan import (BandwidthTrace, SimCloud, WANConfig, simulate,
                            transfer_time)
from repro.training.trainer import Trainer, TrainerConfig

SYNC = SyncConfig("asgd_ga", 2, compress_topk=0.2, quantize_int8=True,
                  error_feedback=True, codec_block=128, overlap_chunks=2,
                  bucket_policy="layer-class",
                  buckets=(BucketOverride("norm", compress_topk=0.5),))
TRACE = BandwidthTrace(times_s=(0.0, 3.0), mbps=(100.0, 2.0))


def _loss(params, batch):
    pred = batch["x"] @ params["w"] + params["bias"]
    reg = jnp.mean(params["embed"] ** 2)
    return jnp.mean((pred - batch["y"]) ** 2) + 0.01 * reg, {}


def _init(key):
    kw, ke = jax.random.split(key)
    return {"w": jax.random.normal(kw, (8, 4)) * 0.1,
            "bias": jnp.zeros((4,)),
            "embed": jax.random.normal(ke, (16, 4)) * 0.1}


def _run(transport, n_pods=2, n_steps=10, sync=SYNC, seed=7,
         set_kind_at=None, set_kind_to=None):
    """Drive the production trainer path; returns (state, trainer,
    per-step (msg_norm, ef_residual) snapshots)."""
    tr = Trainer(_loss, _init,
                 TrainerConfig(n_pods=n_pods, optimizer="sgd", lr=0.05,
                               sync=sync),
                 transport=transport)
    st = tr.init_state(jax.random.key(0))
    rng = np.random.default_rng(seed)
    snaps = []
    for step in range(n_steps):
        if set_kind_at is not None and step == set_kind_at:
            transport.set_kind(set_kind_to, step=step)
        x = rng.normal(size=(n_pods, 16, 8)).astype(np.float32)
        y = (x[..., :4] * 0.5).astype(np.float32)
        st, _ = tr.train_step(st, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        st = tr.maybe_sync(st, step, model_mb=0.001)
        if transport is not None and hasattr(transport, "tick"):
            transport.tick(0.5)
        snaps.append((np.asarray(st.sync_state.msg_norm).copy(),
                      np.asarray(st.sync_state.ef_residual).copy()))
    return st, tr, snaps


def _assert_same_stream(a, b, label):
    """Bit-identical params + SyncState telemetry after the same stream."""
    st_a, _, snaps_a = a
    st_b, _, snaps_b = b
    for la, lb in zip(jax.tree.leaves(st_a.params),
                      jax.tree.leaves(st_b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{label}: params")
    for field in ("ef_residual", "msg_norm", "resid_norm", "tier"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_a.sync_state, field)),
            np.asarray(getattr(st_b.sync_state, field)),
            err_msg=f"{label}: {field}")
    for i, ((ma, ra), (mb, rb)) in enumerate(zip(snaps_a, snaps_b)):
        np.testing.assert_array_equal(ma, mb, err_msg=f"{label}: step {i}")
        np.testing.assert_array_equal(ra, rb, err_msg=f"{label}: step {i}")


def _random_grouping(rng, n_pods):
    """Random partition of pods 0..n-1 into named region groups."""
    n_groups = int(rng.integers(1, n_pods + 1))
    assign = np.concatenate([np.arange(n_groups),
                             rng.integers(0, n_groups, n_pods - n_groups)])
    rng.shuffle(assign)
    return [f"r{assign[i]}" for i in range(n_pods)]


# ------------------------------------------------------------------ parity


def test_hierarchical_bit_identical_to_inline_random_streams():
    """The tentpole property: shipping through a hierarchical transport —
    any shape, any region grouping, any bucket policy — produces params
    and per-bucket telemetry bit-identical to the flat inline ring at
    every sync.  Topology is billing, never bytes."""
    rng = np.random.default_rng(0)
    for case in range(6):
        n_pods = int(rng.integers(2, 6))
        regions = _random_grouping(rng, n_pods)
        kind = ("ring", "tree")[case % 2]
        policy = ("single", "layer-class")[int(rng.integers(0, 2))]
        sync = dataclasses.replace(
            SYNC, bucket_policy=policy,
            buckets=SYNC.buckets if policy == "layer-class" else ())
        seed = int(rng.integers(0, 1_000))
        spec = TopologySpec.from_regions(regions, kind=kind)
        hier = HierarchicalTransport(spec, TRACE,
                                     wan=WANConfig(fluctuation=0.2, seed=3),
                                     probe=MeasuredWanProbe())
        label = (f"case {case}: pods={n_pods} regions={regions} "
                 f"kind={kind} policy={policy} seed={seed}")
        _assert_same_stream(
            _run(None, n_pods=n_pods, sync=sync, seed=seed),
            _run(hier, n_pods=n_pods, sync=sync, seed=seed), label)
        assert len(hier.records) > 0, label


def test_ef_residual_carries_across_topology_retune():
    """Switching topology mid-run (the actuator's set_kind at a live
    transport) is invisible to the numerics: the EF residual carries and
    the whole stream stays bit-identical to the inline path."""
    spec = TopologySpec.from_regions(["sh", "sh", "cq"], kind="ring")
    hier = HierarchicalTransport(spec, TRACE, wan=WANConfig(seed=0),
                                 probe=MeasuredWanProbe())
    pre = _run(HierarchicalTransport(spec, TRACE, wan=WANConfig(seed=0)),
               n_pods=3, n_steps=6)
    assert np.linalg.norm(np.asarray(pre[0].sync_state.ef_residual)) > 0
    full = _run(hier, n_pods=3, n_steps=12, set_kind_at=6,
                set_kind_to="tree")
    inline = _run(None, n_pods=3, n_steps=12)
    _assert_same_stream(inline, full, "topology retune stream")
    assert hier.spec.kind == "tree"
    assert hier.switches == [(6, "ring", "tree")]


# -------------------------------------------------------- schedule compile


def test_tree_schedule_structure_and_counts():
    spec = TopologySpec.from_regions(["sh", "sh", "cq", "gz"], kind="tree")
    sched = spec.compile(LinkBeliefs(default_mbps=100.0))
    kinds = [p.kind for p in sched.phases]
    assert kinds == ["intra-reduce", "gather", "broadcast", "intra-bcast"]
    assert sched.root in ("sh", "cq", "gz")
    # tree over R regions: 2(R-1) WAN transfers, intra phases are not WAN
    assert sched.wan_transfers == 4
    assert not sched.uses_aux_route
    assert all(not p.wan for p in sched.phases
               if p.kind.startswith("intra"))


def test_singleton_ring_matches_flat_pod_count():
    """Flat-ring back-compat: a ring over all-singleton regions makes
    exactly n_pods WAN transfers — the historical n_pods multiplier."""
    for n in (2, 3, 5):
        spec = TopologySpec.from_regions([f"p{i}" for i in range(n)],
                                         kind="ring")
        assert spec.compile(LinkBeliefs()).wan_transfers == n


def test_ring_order_maximizes_bottleneck_link():
    """With >= 4 regions the ring reorders to keep the worst link out of
    the cycle when the triangle inequality allows it."""
    regions = ["a", "b", "c", "d"]
    spec = TopologySpec.from_regions(regions, kind="ring")
    b = LinkBeliefs(default_mbps=100.0)
    # make a-b terrible; a ring a-c-b-d avoids the a-b edge entirely
    for x, y in (("a", "c"), ("c", "b"), ("b", "d"), ("d", "a")):
        b.observe(x, y, 100.0)
    b.observe("a", "b", 1.0)
    b.observe("c", "d", 1.0)
    sched = spec.compile(b)
    crossed = {hop for leg in sched.wan_legs for hop in leg.hops}
    assert link_key("a", "b") not in crossed
    assert link_key("c", "d") not in crossed
    assert sched.wan_transfers == 4


def test_aux_route_fires_only_past_collapse_ratio():
    """The auxiliary two-hop route routes around a cliff-snapped link but
    not around ordinary noise (collapse_ratio is the dividing line) — and
    fires when re-rooting alone cannot dodge the collapsed link (the root
    is pinned by its other links)."""
    regions = ["root", "hub1", "hub2", "leaf"]
    spec = TopologySpec.from_regions(regions, kind="tree")
    b = LinkBeliefs(default_mbps=100.0)
    # pin the root: overwhelming total belief via the hubs
    b.observe("root", "hub1", 1000.0)
    b.observe("root", "hub2", 1000.0)
    b.observe("leaf", "hub1", 100.0)       # the future relay path
    b.observe("leaf", "hub2", 10.0)
    b.observe("root", "leaf", 50.0)        # degraded but above the line:
    #   best relay bottleneck is 100 < collapse_ratio * 50, so no reroute
    sched = spec.compile(b)
    assert sched.root == "root"
    assert not sched.uses_aux_route
    assert sched.wan_transfers == 2 * 3
    b.observe("root", "leaf", 5.0)         # 10x collapse -> cliff-snap
    sched = spec.compile(b)
    assert sched.root == "root"            # still pinned; reroute instead
    (leg,) = [l for l in sched.wan_legs
              if l.src == "leaf" and l.dst == "root"]
    assert leg.via == "hub1"
    assert leg.hops == (link_key("leaf", "hub1"),
                        link_key("hub1", "root"))
    # aux legs pay both hops in the transfer count
    assert sched.wan_transfers == 2 * (2 + 1 + 1)


def test_compile_is_deterministic():
    rng = np.random.default_rng(3)
    for _ in range(20):
        n = int(rng.integers(2, 6))
        regions = _random_grouping(rng, n)
        b = LinkBeliefs(default_mbps=100.0)
        spec = TopologySpec.from_regions(regions, kind="tree")
        names = sorted(set(regions))
        for i, a_ in enumerate(names):
            for b_ in names[i + 1:]:
                b.observe(a_, b_, float(rng.uniform(1.0, 200.0)))
        assert spec.compile(b) == spec.compile(b)
        ring = spec.with_kind("ring")
        assert ring.compile(b) == ring.compile(b)


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown topology kind"):
        TopologySpec(kind="mesh", groups=(("a", (0,)),))
    with pytest.raises(ValueError, match="partition"):
        TopologySpec(kind="ring", groups=(("a", (0, 2)),))
    with pytest.raises(ValueError, match="duplicate region"):
        TopologySpec(kind="ring", groups=(("a", (0,)), ("a", (1,))))
    with pytest.raises(ValueError, match="itself"):
        link_key("a", "a")
    assert link_key("b", "a") == ("a", "b")


# --------------------------------------------- hierarchical_average mapping


def test_hierarchical_average_singletons_is_flat_ama():
    """All-singleton groups + inter='ama' == flat ama, bit-for-bit: a
    size-one region mean is the identity and the region ring is the pod
    ring."""
    rng = np.random.default_rng(0)
    for n, shift in ((2, 1), (4, 1), (5, 2)):
        tree = {"w": jnp.asarray(rng.normal(size=(n, 6, 3)),
                                 jnp.float32),
                "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float16)}
        flat = jax.tree.map(
            lambda p: ((p.astype(jnp.float32)
                        + jnp.roll(p, shift, axis=0).astype(jnp.float32))
                       * 0.5).astype(p.dtype), tree)
        hier = hierarchical_average(tree, [(i,) for i in range(n)],
                                    inter="ama", shift=shift)
        for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(hier)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hierarchical_average_one_group_is_flat_sma():
    rng = np.random.default_rng(1)
    for n in (2, 3, 5):
        tree = {"w": jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)}
        flat = jax.tree.map(
            lambda p: jnp.broadcast_to(
                jnp.mean(p.astype(jnp.float32), axis=0, keepdims=True),
                p.shape).astype(p.dtype), tree)
        hier = hierarchical_average(tree, [tuple(range(n))], inter="sma")
        np.testing.assert_array_equal(np.asarray(flat["w"]),
                                      np.asarray(hier["w"]))


def test_hierarchical_average_two_level_semantics():
    """Members of a region share their aggregate, and inter='sma' over
    equal-size regions preserves the global mean."""
    rng = np.random.default_rng(2)
    tree = {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)}
    groups = [(0, 1), (2, 3)]
    out = hierarchical_average(tree, groups, inter="sma")["w"]
    for g in groups:
        np.testing.assert_array_equal(np.asarray(out[g[0]]),
                                      np.asarray(out[g[1]]))
    np.testing.assert_allclose(np.asarray(out).mean(axis=0),
                               np.asarray(tree["w"]).mean(axis=0),
                               rtol=1e-6, atol=1e-6)
    # inter='ama' gossips region means one ring step
    out2 = hierarchical_average(tree, groups, inter="ama")["w"]
    m = np.asarray(tree["w"], np.float32).reshape(2, 2, 8).mean(axis=1)
    want = (m + np.roll(m, 1, axis=0)) * 0.5
    np.testing.assert_allclose(np.asarray(out2[0]), want[0], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out2[2]), want[1], rtol=1e-6, atol=1e-6)


def test_hierarchical_average_validation():
    tree = {"w": jnp.zeros((4, 2))}
    with pytest.raises(ValueError, match="partition"):
        hierarchical_average(tree, [(0, 1), (1, 2, 3)])
    with pytest.raises(ValueError, match="coprime"):
        hierarchical_average(tree, [(0,), (1,), (2,), (3,)], shift=2)
    with pytest.raises(ValueError, match="'ama' or 'sma'"):
        hierarchical_average(tree, [(0, 1, 2, 3)], inter="asgd")


# ------------------------------------- link collapse: reroute + EF guard


def test_collapse_reroutes_within_one_sync_round_stream():
    """The satellite invariant, seeded-random style (as the 300-stream
    controller tests in test_buckets): random networks with an injected
    10x collapse on a random link — the round that bills the collapsed
    link feeds its belief, and the very next schedule no longer crosses
    that link directly (re-root or auxiliary route — within one sync
    round of observing it)."""
    rng = np.random.default_rng(42)
    n_rerouted = 0
    for stream in range(120):
        n_regions = int(rng.integers(3, 6))
        regions = [f"r{i}" for i in range(n_regions)]
        kind = ("tree", "ring")[int(rng.integers(0, 2))]
        spec = TopologySpec.from_regions(regions, kind=kind)
        base = float(rng.uniform(50.0, 200.0))
        collapse_at = float(rng.uniform(2.0, 6.0))
        links = sorted({link_key(a, b) for a in regions for b in regions
                       if a != b})
        bad = links[int(rng.integers(0, len(links)))]
        traces = {l: BandwidthTrace((0.0,), (base,)) for l in links}
        traces[bad] = BandwidthTrace((0.0, collapse_at),
                                     (base, base / 10.0))
        tr = HierarchicalTransport(
            spec, BandwidthTrace((0.0,), (base,)), link_traces=traces,
            wan=WANConfig(fluctuation=0.0, latency_s=0.0,
                          seed=int(rng.integers(0, 99))))
        collapsed_seen_at = None
        for step in range(16):
            crossed = {h for leg in tr.schedule.wan_legs
                       for h in leg.hops}
            if collapsed_seen_at is not None:
                # reroute within one round: once the collapse was billed,
                # the recompiled schedule avoids the direct link (a tree
                # re-roots or relays; a >= 4-region ring reorders; the
                # 3-region ring swaps to the tree's cost model only via
                # the planner, so it is exempt below)
                if not (kind == "ring" and n_regions == 3):
                    assert bad not in crossed, (
                        f"stream {stream}: step {step} still crosses "
                        f"{bad} after collapse billed at "
                        f"{collapsed_seen_at}")
                    n_rerouted += 1
            tr.on_sync({"all": 1.0}, step=step)
            if (collapsed_seen_at is None and tr.clock_s >= collapse_at
                    and bad in crossed):
                collapsed_seen_at = step
            tr.tick(1.0)
    assert n_rerouted > 100   # the property actually fired, broadly


def test_ef_guard_never_violated_with_topology_actuator():
    """test_buckets' controller invariants survive the third actuator:
    across random streams with a planner wired in, a fresh guard trip
    always de-escalates (reason ef-guard, rung strictly down) and no
    topology decision ever rides on a guard-trip update."""
    base = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                      error_feedback=True)
    rng = np.random.default_rng(7)
    n_guard_trips = 0
    n_topo_moves = 0
    for stream in range(200):
        regions = [f"r{i}" for i in range(int(rng.integers(2, 5)))]
        spec = TopologySpec.from_regions(regions, kind="ring")
        beliefs = LinkBeliefs(default_mbps=float(rng.uniform(20.0, 200.0)))
        planner = TopologyPlanner(spec, beliefs,
                                  hysteresis=int(rng.integers(1, 3)))
        tuner = AdaptiveSyncController(
            base, model_mb=44.6, compute_step_s=0.3,
            ef_guard=float(rng.uniform(0.5, 0.98)),
            hysteresis=int(rng.integers(1, 4)),
            interval_budget=int(rng.integers(4, 16)),
            topology=planner)
        for step in range(30):
            if rng.random() < 0.7:
                tuner.observe_wan(float(rng.uniform(0.5, 200.0)))
            if rng.random() < 0.3:
                a, b = rng.choice(len(regions), 2, replace=False)
                beliefs.observe(regions[a], regions[b],
                                float(rng.uniform(0.5, 200.0)))
            ratio = float(rng.uniform(0.0, 1.2))
            stats = BucketStats(msg_norm=1.0 + step + stream,
                                resid_norm=ratio * (1.0 + step + stream))
            rung_before = tuner.rung
            n_decisions_before = len(planner.decisions)
            upd = tuner.update(step, stats)
            if stats.ef_ratio >= tuner.ef_guard:
                n_guard_trips += 1
                # the guard always wins: de-escalate, and the planner was
                # not even consulted this update
                if rung_before > 0:
                    assert upd is not None and upd.reason == "ef-guard"
                    assert upd.rung == rung_before - 1
                assert len(planner.decisions) == n_decisions_before
            if upd is not None:
                assert upd.topology == planner.kind
                if upd.reason.startswith("topo-"):
                    n_topo_moves += 1
                    assert upd.sync == dataclasses.replace(
                        tuner.current, interval=upd.sync.interval)
        assert tuner.max_ef_ratio <= 1.2 + 1e-9
    assert n_guard_trips > 100          # streams actually exercised the guard
    assert n_topo_moves > 0             # and the actuator actually moved


def test_topology_only_update_keeps_codec_knobs():
    """A planner switch with no codec pressure emits a topo-only update:
    same rung, same interval, reason topo-<kind>."""
    spec = TopologySpec.from_regions(["a", "b", "c"], kind="ring")
    beliefs = LinkBeliefs(default_mbps=100.0)
    planner = TopologyPlanner(spec, beliefs, hysteresis=1)
    base = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                      error_feedback=True)
    tuner = AdaptiveSyncController(base, 44.6, 0.3, topology=planner,
                                   interval_budget=8)
    tuner.observe_wan(100.0)
    calm = BucketStats(1.0, 0.1)
    first = tuner.update(0, calm)       # settle the interval fit
    rung0, interval0 = tuner.rung, tuner.interval
    # collapse one link: tree (which can avoid it) now beats the ring
    beliefs.observe("a", "b", 100.0)
    beliefs.observe("a", "b", 2.0)
    upd = tuner.update(1, BucketStats(2.0, 0.2))
    assert upd is not None and upd.reason == "topo-tree"
    assert upd.topology == "tree"
    assert upd.rung == rung0 and upd.sync.interval == interval0
    assert planner.decisions and planner.decisions[0][2] == "tree"
    assert first is None or first.topology == "ring"


# ------------------------------------------------------ planner switch law


def test_planner_hysteresis_and_margin():
    spec = TopologySpec.from_regions(["a", "b", "c"], kind="ring")
    beliefs = LinkBeliefs(default_mbps=100.0)
    applied = []
    planner = TopologyPlanner(spec, beliefs, hysteresis=2,
                              switch_margin=0.85,
                              apply=lambda k, s: applied.append((k, s)))
    # symmetric network: ring and tree are close -> no switch, ever
    for step in range(5):
        assert planner.decide(step, 10.0) is None
    assert planner.kind == "ring" and not applied
    # collapse a-b: tree avoids it, ring (3 regions) cannot
    beliefs.observe("a", "b", 100.0)
    beliefs.observe("a", "b", 2.0)
    assert planner.decide(5, 10.0) is None      # streak 1 of 2
    assert planner.decide(6, 10.0) == "tree"    # streak 2 -> switch
    assert planner.kind == "tree"
    assert applied == [("tree", 6)]
    assert len(planner.decisions) == 1
    step_, old, new, reason = planner.decisions[0]
    assert (step_, old, new) == (6, "ring", "tree")
    assert reason.startswith("topo-cost:ring->tree")
    # healed link: a symmetric ring is one phase vs the tree's two, so
    # ring is cheaper again — but the return still waits out hysteresis
    beliefs.observe("a", "b", 100.0)
    beliefs.observe("a", "b", 100.0)
    assert planner.decide(7, 10.0) is None      # streak 1 of 2
    assert planner.decide(8, 10.0) == "ring"
    assert planner.kind == "ring"
    assert applied == [("tree", 6), ("ring", 8)]


def test_planner_is_deterministic_replay():
    """Same belief stream -> same decisions, estimate for estimate (the
    check_regression replay contract)."""
    def drive(planner, beliefs):
        out = []
        obs = [("a", "b", 100.0), ("a", "c", 80.0), ("b", "c", 90.0),
               ("a", "b", 3.0), ("a", "b", 3.0), ("b", "c", 85.0)]
        for step, (x, y, mbps) in enumerate(obs):
            beliefs.observe(x, y, mbps)
            planner.decide(step, 12.5)
            out.append((planner.kind, planner.estimates(12.5)))
        return out, list(planner.decisions)

    def fresh():
        spec = TopologySpec.from_regions(["a", "b", "c"], kind="ring")
        beliefs = LinkBeliefs(default_mbps=100.0)
        return TopologyPlanner(spec, beliefs, hysteresis=2), beliefs

    assert drive(*fresh()) == drive(*fresh())


# ------------------------------------------- exact accounting: cost vs DES


def test_des_topology_traffic_matches_cost_accounting():
    """wan.simulate under a topology bills exactly payload x wan_transfers
    per sync round — and cost.adaptive_traffic_mb(wan_legs=...) reproduces
    it to the float."""
    cfg = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                     error_feedback=True)
    clouds = [SimCloud(region=r, iter_time_s=0.3, units=4,
                       cost_per_unit_hour=1.0)
              for r in ("sh", "cq", "gz")]
    n_iters, model_mb = 40, 44.6
    n_syncs = n_iters // cfg.interval
    for kind in ("ring", "tree"):
        spec = TopologySpec.from_regions(["sh", "cq", "gz"], kind=kind)
        legs = spec.compile(LinkBeliefs()).wan_transfers
        res = simulate(clouds, cfg, n_iters=n_iters, model_mb=model_mb,
                       wan=WANConfig(bandwidth_mbps=100.0), topology=spec)
        want = cfg.payload_mb(model_mb) * legs * n_syncs
        assert res.total_traffic_mb == pytest.approx(want)
        # the same number through the decision-stream accounting
        fake = type("U", (), {"sync": cfg})
        got = adaptive_traffic_mb([fake], [n_syncs], model_mb,
                                  n_pods=len(clouds), wan_legs=legs)
        assert got == pytest.approx(res.total_traffic_mb)


def test_des_flat_ring_backcompat_traffic():
    """A singleton-region ring topology bills the same traffic as the
    historical flat path (n_pods transfers per round)."""
    cfg = SyncConfig("asgd_ga", 4)
    clouds = [SimCloud(region=f"p{i}", iter_time_s=0.3, units=4,
                       cost_per_unit_hour=1.0) for i in range(3)]
    spec = TopologySpec.from_regions(["p0", "p1", "p2"], kind="ring")
    flat = simulate(clouds, cfg, n_iters=24, model_mb=10.0,
                    wan=WANConfig(bandwidth_mbps=100.0))
    topo = simulate(clouds, cfg, n_iters=24, model_mb=10.0,
                    wan=WANConfig(bandwidth_mbps=100.0), topology=spec)
    assert topo.total_traffic_mb == pytest.approx(flat.total_traffic_mb)
    # and each cloud originates exactly one payload per round either way
    for a, b in zip(sorted(flat.clouds, key=lambda c: c.region),
                    sorted(topo.clouds, key=lambda c: c.region)):
        assert a.traffic_mb == pytest.approx(b.traffic_mb)


def test_des_asymmetric_tree_beats_ring_on_makespan():
    """On an asymmetric network (one collapsed inter-region link) the DES
    agrees with the planner: the tree schedule's makespan beats the flat
    ring's, because the ring must cross the slow link every round."""
    cfg = SyncConfig("asgd_ga", 4)
    clouds = [SimCloud(region=r, iter_time_s=0.3, units=4,
                       cost_per_unit_hour=1.0)
              for r in ("sh", "cq", "gz")]
    links = {("gz", "sh"): 0.05}     # sh<->gz collapsed 20x
    kw = dict(n_iters=60, model_mb=44.6,
              wan=WANConfig(bandwidth_mbps=100.0, fluctuation=0.0))
    ring = simulate(clouds, cfg, topology=TopologySpec.from_regions(
        ["sh", "cq", "gz"], kind="ring"), topology_links=links, **kw)
    tree = simulate(clouds, cfg, topology=TopologySpec.from_regions(
        ["sh", "cq", "gz"], kind="tree"), topology_links=links, **kw)
    assert tree.makespan_s < ring.makespan_s


def test_hierarchical_billing_matches_schedule_law():
    """on_sync's billed round is reproducible from the schedule + the
    seeded rng: per WAN hop one transfer_time draw at that link's traced
    bandwidth, phases summing the slowest leg (the SimTransport billing
    law, generalized per link)."""
    spec = TopologySpec.from_regions(["a", "a", "b", "c"], kind="tree")
    wan = WANConfig(fluctuation=0.3, latency_s=0.05, seed=11)
    traces = {link_key("a", "b"): BandwidthTrace((0.0,), (50.0,)),
              link_key("a", "c"): BandwidthTrace((0.0,), (10.0,))}
    tr = HierarchicalTransport(spec, BandwidthTrace((0.0,), (100.0,)),
                               wan=wan, link_traces=traces,
                               probe=MeasuredWanProbe())
    sched = tr.schedule
    wire = {"dense": 0.8, "norm": 0.2}
    t = tr.on_sync(wire, step=0)
    rng = np.random.default_rng(11)
    want = 0.0
    for phase in sched.phases:
        if not phase.wan:
            want += 1.0 * 8.0 / spec.intra_mbps
            continue
        want += max(
            sum(transfer_time(
                1.0, traces.get(h, BandwidthTrace((0.0,), (100.0,))).at(0.0),
                wan, rng) for h in leg.hops)
            for leg in phase.legs)
    assert t == pytest.approx(want)
    # per-bucket records split the round proportionally and sum back
    assert sum(r.seconds for r in tr.records) == pytest.approx(t)
    assert tr.probe.n_observations == 1
    assert tr.probe.last_mbps == pytest.approx(1.0 * 8.0 / t)


def test_bucket_payload_table_wire_column():
    cfg = SyncConfig("asgd_ga", 4, compress_topk=0.1, quantize_int8=True,
                     error_feedback=True, bucket_policy="layer-class")
    mb = {"embed": 4.0, "norm": 0.1, "dense": 30.0, "moe": 0.0}
    plain = bucket_payload_table(cfg, mb)
    assert "wire_mb" not in plain["total"]
    table = bucket_payload_table(cfg, mb, wan_legs=4)
    for name, row in table.items():
        assert row["wire_mb"] == pytest.approx(row["payload_mb"] * 4,
                                               abs=1e-6)


def test_trainer_traffic_uses_schedule_legs():
    """Trainer.maybe_sync bills wan_transfers_per_round when the transport
    exposes one: a 2-region tree over 3 pods makes 2 transfers per round,
    not 3."""
    spec = TopologySpec.from_regions(["sh", "sh", "cq"], kind="tree")
    hier = HierarchicalTransport(spec, TRACE, wan=WANConfig(seed=0))
    assert hier.wan_transfers_per_round == 2
    _, tr_hier, _ = _run(hier, n_pods=3, n_steps=4)
    _, tr_flat, _ = _run(None, n_pods=3, n_steps=4)
    assert tr_hier.traffic_mb == pytest.approx(tr_flat.traffic_mb * 2 / 3)
