"""Fused WAN payload codec: kernel-vs-oracle exactness, bucketed sync-layer
round trip, error-feedback semantics + convergence parity, chunked-overlap
equivalence, payload accounting.

Kernel tests run the Pallas kernels in interpret mode and assert EXACT
equality against the ``ref.py`` oracles — the codec's selection key,
tie-breaking and quantizer are specified to the bit (see
``repro.kernels.wan_codec``), so allclose would hide real drift.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sync import (SyncConfig, apply_sync, init_sync_state,
                             on_step_gradients, resize_sync_state)
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.wan_codec import (k_per_block, wan_decode_pallas,
                                     wan_encode_pallas)

RNG = np.random.default_rng(0)


def _rand(n):
    return jnp.asarray(RNG.normal(size=(n,)), jnp.float32)


# ------------------------------------------------------- kernel vs oracle


@pytest.mark.parametrize("n,k_block,block", [
    (4096, 41, 1024),
    (8192, 82, 4096),
    (1000, 16, 256),      # non-multiple of block
    (300, 8, 512),        # single short block
    (5000, 12, 1024),     # padded tail block
    (9000, 50, 4096),     # padded tail + partial row group
])
def test_encode_kernel_matches_oracle_exactly(n, k_block, block):
    x = _rand(n)
    q1, i1, s1 = wan_encode_pallas(x, k_block, block=block, interpret=True)
    q2, i2, s2 = ref.wan_encode(x, k_block, block=block)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    d1 = wan_decode_pallas(q1, i1, s1, n, block=block, interpret=True)
    d2 = ref.wan_decode(q2, i2, s2, n, block=block)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_encode_handles_ties_and_zero_blocks():
    x = _rand(777).at[:64].set(0.25).at[400:].set(0.0)
    q1, i1, s1 = wan_encode_pallas(x, 16, block=128, interpret=True)
    q2, i2, s2 = ref.wan_encode(x, 16, block=128)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # all-zero input: scale must fall back to 1, payload to exact zeros
    z = jnp.zeros((512,), jnp.float32)
    q, i, s = wan_encode_pallas(z, 8, block=256, interpret=True)
    assert float(jnp.max(jnp.abs(q))) == 0.0
    np.testing.assert_array_equal(np.asarray(s), np.ones(2, np.float32))
    d = wan_decode_pallas(q, i, s, 512, block=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(d), np.zeros(512, np.float32))


def test_quantization_error_bounded_by_half_scale():
    """Every reconstructed winner is within scale/2 of its fp32 value."""
    n, block, k_block = 4096, 1024, 64
    x = _rand(n)
    q, idx, scales = ref.wan_encode(x, k_block, block=block)
    dense = np.asarray(ref.wan_decode(q, idx, scales, n, block=block))
    xb = np.asarray(x).reshape(-1, block)
    db = dense.reshape(-1, block)
    il = np.asarray(idx).reshape(-1, k_block)
    for b in range(xb.shape[0]):
        err = np.abs(db[b, il[b]] - xb[b, il[b]])
        assert err.max() <= float(scales[b]) * 0.5 + 1e-7


def test_selection_energy_close_to_exact_topk():
    """The 16-bit truncated sort key costs (almost) no selection quality."""
    n, k = 8192, 256
    x = _rand(n)
    q, idx, scales = ref.wan_encode(x, k // 8, block=1024)
    d_codec = np.asarray(ref.wan_decode(q, idx, scales, n, block=1024))
    d_exact = np.asarray(
        ref.topk_decompress(*ref.topk_exact(x, k), n))
    assert np.sum(d_codec ** 2) >= 0.9 * np.sum(d_exact ** 2)


def test_high_k_auto_caps_onehot_tile_and_stays_exact():
    """At aggressive fractions the (rows, block, k_block) one-hot tile is
    the VMEM high-water mark; rows must degrade to keep the compiled TPU
    path under budget, without changing results (tiling is semantics-free).
    """
    from repro.kernels.wan_codec import _ONEHOT_BUDGET_BYTES, _cap_rows

    block = 4096
    kb = k_per_block(block, 0.05)            # 205 winners/block
    rows = _cap_rows(8, block, kb)
    assert rows * block * kb * 4 <= _ONEHOT_BUDGET_BYTES
    assert rows < 8                           # the cap actually engaged
    x = _rand(1 << 16)
    q1, i1, s1 = wan_encode_pallas(x, kb, block=block, interpret=True)
    q2, i2, s2 = ref.wan_encode(x, kb, block=block)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    d1 = wan_decode_pallas(q1, i1, s1, 1 << 16, block=block, interpret=True)
    d2 = ref.wan_decode(q2, i2, s2, 1 << 16, block=block)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_ops_dispatch_oracle_equals_kernel():
    x = _rand(6000)
    kb = k_per_block(1024, 0.05)
    out_k = kops.wan_encode(x, kb, block=1024, interpret=True)
    out_o = kops.wan_encode(x, kb, block=1024, use_kernel=False)
    for a, b in zip(out_k, out_o):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    d_k = kops.wan_decode(*out_k, 6000, block=1024, interpret=True)
    d_o = kops.wan_decode(*out_o, 6000, block=1024, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_o))


# --------------------------------------------- precision tiers (int4 / fp8)


@pytest.mark.parametrize("value_dtype", ["int8", "fp8", "int4"])
@pytest.mark.parametrize("n,k_block,block", [
    (4096, 41, 1024),     # odd k_block: int4 pads one zero nibble per block
    (5000, 12, 1024),     # padded tail block
    (300, 8, 512),        # single short block
])
def test_tier_kernel_matches_oracle_exactly(value_dtype, n, k_block, block):
    x = _rand(n)
    q1, i1, s1 = wan_encode_pallas(x, k_block, block=block,
                                   value_dtype=value_dtype, interpret=True)
    q2, i2, s2 = ref.wan_encode(x, k_block, block=block,
                                value_dtype=value_dtype)
    assert q1.dtype == q2.dtype
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    d1 = wan_decode_pallas(q1, i1, s1, n, block=block,
                           value_dtype=value_dtype, interpret=True)
    d2 = ref.wan_decode(q2, i2, s2, n, block=block, value_dtype=value_dtype)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_int4_payload_is_nibble_packed():
    """int4 wire bytes: uint8, ceil(k_block/2) per block — half of int8."""
    n, block = 4096, 1024
    for kb in (16, 17):                       # even and odd winner counts
        q8, _, _ = ref.wan_encode(_rand(n), kb, block=block,
                                  value_dtype="int8")
        q4, _, _ = ref.wan_encode(_rand(n), kb, block=block,
                                  value_dtype="int4")
        nb = n // block
        assert q8.shape[0] == nb * kb and q8.dtype == jnp.int8
        assert q4.shape[0] == nb * ((kb + 1) // 2) and q4.dtype == jnp.uint8


def test_pack_unpack_nibbles_round_trip():
    from repro.kernels.wan_codec import pack_nibbles, unpack_nibbles

    for k in (6, 7):                          # even / odd
        q = jnp.asarray(RNG.integers(-7, 8, size=(5, k)), jnp.int8)
        p = pack_nibbles(q)
        assert p.shape == (5, (k + 1) // 2) and p.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(unpack_nibbles(p, k)),
                                      np.asarray(q))


@pytest.mark.parametrize("value_dtype", ["fp8", "int4"])
def test_tier_ties_and_zero_blocks(value_dtype):
    x = _rand(777).at[:64].set(0.25).at[400:].set(0.0)
    q1, i1, s1 = wan_encode_pallas(x, 16, block=128,
                                   value_dtype=value_dtype, interpret=True)
    q2, i2, s2 = ref.wan_encode(x, 16, block=128, value_dtype=value_dtype)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # all-zero input: scale falls back to 1, payload decodes to exact zeros
    z = jnp.zeros((512,), jnp.float32)
    q, i, s = wan_encode_pallas(z, 8, block=256, value_dtype=value_dtype,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(s), np.ones(2, np.float32))
    d = wan_decode_pallas(q, i, s, 512, block=256, value_dtype=value_dtype,
                          interpret=True)
    np.testing.assert_array_equal(np.asarray(d), np.zeros(512, np.float32))


def test_int4_round_trip_error_bounded_by_half_scale():
    """Every reconstructed winner is within scale/2 = max|x|/14 of its
    fp32 value — the int4 analogue of the int8 half-step bound."""
    n, block, k_block = 4096, 1024, 64
    x = _rand(n)
    q, idx, scales = ref.wan_encode(x, k_block, block=block,
                                    value_dtype="int4")
    dense = np.asarray(ref.wan_decode(q, idx, scales, n, block=block,
                                      value_dtype="int4"))
    xb = np.asarray(x).reshape(-1, block)
    db = dense.reshape(-1, block)
    il = np.asarray(idx).reshape(-1, k_block)
    for b in range(xb.shape[0]):
        err = np.abs(db[b, il[b]] - xb[b, il[b]])
        assert err.max() <= float(scales[b]) * 0.5 + 1e-7


def test_fp8_round_trip_error_is_relative():
    """fp8-e4m3 rounds to 3 mantissa bits: every reconstructed winner is
    within half an ulp — 2^-4 relative — of its fp32 value (plus the
    subnormal floor scale * 2^-10)."""
    n, block, k_block = 4096, 1024, 64
    x = _rand(n)
    q, idx, scales = ref.wan_encode(x, k_block, block=block,
                                    value_dtype="fp8")
    dense = np.asarray(ref.wan_decode(q, idx, scales, n, block=block,
                                      value_dtype="fp8"))
    xs = np.asarray(x)
    sel = dense != 0
    err = np.abs(dense[sel] - xs[sel])
    bound = np.abs(xs[sel]) * 2.0 ** -4 + float(scales.max()) * 2.0 ** -10
    assert (err <= bound).all()


def test_fp8_beats_int8_on_heavy_tailed_blocks():
    """The fp8 tier's reason to exist: int8's uniform step is set by the
    block max, so one huge outlier crushes every small value to zero; fp8's
    relative rounding keeps them.  Reconstruction error (on the selected
    entries) must be strictly better for fp8 here."""
    block = 256
    x = np.asarray(RNG.normal(size=(1024,)) * 1e-3, np.float32)
    x[::block] = 50.0                          # one outlier per block
    xj = jnp.asarray(x)
    errs = {}
    for dt in ("int8", "fp8"):
        q, idx, s = ref.wan_encode(xj, 32, block=block, value_dtype=dt)
        d = np.asarray(ref.wan_decode(q, idx, s, 1024, block=block,
                                      value_dtype=dt))
        sel = np.zeros_like(x, bool)
        il = np.asarray(idx).reshape(-1, 32)
        for b in range(il.shape[0]):
            sel[b * block + il[b]] = True
        errs[dt] = np.abs(d - x)[sel].sum()
    assert errs["fp8"] < errs["int8"]


# ------------------------------------------------- sync-layer integration


def _grads(n_pods=2, seed=1):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n_pods, 300, 40)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n_pods, 77)), jnp.float32)}


def _one_sync(cfg, g):
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    _, st = on_step_gradients(cfg, g, st)
    return apply_sync(cfg, p, st, lr=1.0)


def test_codec_ship_round_trips_bucketed_pytree():
    """Bucket -> encode -> ring -> decode reproduces the legacy per-leaf
    ring semantics up to the codec's lossiness: what arrives is the ring
    peer's compressed message (energy bounded, correct peer)."""
    from repro.core.sync import _pack_stacked

    g = _grads()
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.25, quantize_int8=True,
                     codec_block=512)
    dense, _ = _one_sync(SyncConfig("asgd_ga", 1), g)
    comp, _ = _one_sync(cfg, g)
    # params went DOWN by the (rolled) peer message: recover it, in the
    # same bucket order the codec compressed (blocks span leaf boundaries)
    m_dense = -np.asarray(_pack_stacked(dense))
    m_comp = -np.asarray(_pack_stacked(comp))
    # compressed message keeps the top-magnitude mass of the dense one
    e = np.sum(m_comp ** 2) / np.sum(m_dense ** 2)
    assert 0.4 < e <= 1.0
    # and every shipped entry matches the dense message to within the int8
    # step of its 512-element block (scale = blockmax/127)
    for pod in range(m_dense.shape[0]):
        db = np.pad(m_dense[pod], (0, (-m_dense.shape[1]) % 512)
                    ).reshape(-1, 512)
        cb = np.pad(m_comp[pod], (0, (-m_comp.shape[1]) % 512)
                    ).reshape(-1, 512)
        step = np.abs(db).max(axis=1, keepdims=True) / 127.0
        nz = cb != 0
        assert (np.abs(cb - db)[nz] <=
                (np.broadcast_to(step * 0.5 + 1e-7, cb.shape))[nz]).all()


@pytest.mark.parametrize("chunks", [2, 3, 8])
def test_chunked_overlap_equals_unchunked(chunks):
    g = _grads()
    base = dict(compress_topk=0.25, quantize_int8=True, error_feedback=True,
                codec_block=512)
    p1, s1 = _one_sync(SyncConfig("asgd_ga", 1, overlap_chunks=1, **base), g)
    pc, sc = _one_sync(
        SyncConfig("asgd_ga", 1, overlap_chunks=chunks, **base), g)
    for k in g:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(pc[k]))
    np.testing.assert_array_equal(np.asarray(s1.ef_residual),
                                  np.asarray(sc.ef_residual))


def test_ef_residual_is_exact_compression_error():
    """residual == message - decode(encode(message)), and re-injection
    makes two syncs ship more mass than two independent ones."""
    g = _grads()
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                     error_feedback=True, codec_block=512)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    _, st = on_step_gradients(cfg, g, st)
    out, st2 = apply_sync(cfg, p, st, lr=1.0)
    # reconstruct: message (bucket order) minus what the peer received
    from repro.core.sync import _pack_stacked
    msg = np.asarray(_pack_stacked(jax.tree.map(
        lambda b: b, st.ga_buffer)))
    received = -np.asarray(_pack_stacked(out))   # rolled peer message
    local = np.roll(received, -cfg.peer_shift, axis=0)   # undo the ring
    np.testing.assert_allclose(np.asarray(st2.ef_residual), msg - local,
                               atol=1e-6)
    # EF off -> residual stays empty
    cfg0 = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True)
    _, st0 = _one_sync(cfg0, g)
    assert st0.ef_residual.shape[1] == 0


def test_ef_residual_reinjected_next_sync():
    """A second sync with zero fresh gradient still ships the residual."""
    g = _grads()
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                     error_feedback=True, codec_block=512)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    _, st = on_step_gradients(cfg, g, st)
    p1, st = apply_sync(cfg, p, st, lr=1.0)
    assert float(jnp.linalg.norm(st.ef_residual)) > 0
    # no new gradients: the next sync ships purely from the residual
    zero_g = jax.tree.map(jnp.zeros_like, g)
    _, st = on_step_gradients(cfg, zero_g, st)
    p2, st = apply_sync(cfg, p1, st, lr=1.0)
    moved = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p1)))
    assert moved > 0, "EF residual was not re-injected"


def test_resize_preserves_ef_residual_total():
    g = _grads(n_pods=3)
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                     error_feedback=True, codec_block=512)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    _, st = on_step_gradients(cfg, g, st)
    _, st = apply_sync(cfg, p, st, lr=1.0)
    total = np.asarray(jnp.sum(st.ef_residual, axis=0))
    p2 = jax.tree.map(lambda x: x[:2], p)
    shrunk = resize_sync_state(cfg, st, p2, keep=(0, 1))
    assert shrunk.ef_residual.shape[0] == 2
    np.testing.assert_allclose(
        np.asarray(jnp.sum(shrunk.ef_residual, axis=0)), total, atol=1e-5)
    grown = resize_sync_state(cfg, shrunk._replace(), g, keep=None)
    assert grown.ef_residual.shape[0] == 3
    np.testing.assert_allclose(
        np.asarray(grown.ef_residual[2]), 0.0, atol=0.0)


# --------------------------------------------------------- payload math


def test_payload_math_int8():
    dense = SyncConfig("asgd_ga", 8)
    sparse = SyncConfig("asgd_ga", 8, compress_topk=0.01)
    codec = SyncConfig("asgd_ga", 8, compress_topk=0.01, quantize_int8=True,
                       codec_block=4096)
    assert dense.payload_mb(100.0) == 100.0
    assert sparse.payload_mb(100.0) == pytest.approx(2.0)
    # int8 value + u16 index per kept element + fp32 scale per block
    assert codec.payload_mb(100.0) == pytest.approx(
        100.0 * (0.01 * 0.75 + 1.0 / 4096))
    # >= 8x below dense fp32 at equal sync interval
    assert dense.payload_mb(100.0) / codec.payload_mb(100.0) >= 8.0


def test_payload_math_tiers():
    """fp8 costs int8 bytes (1 B + u16 idx); int4 nibble-packs to 0.5 B."""
    base = dict(compress_topk=0.01, quantize_int8=True, codec_block=4096)
    int8 = SyncConfig("asgd_ga", 8, **base)
    fp8 = SyncConfig("asgd_ga", 8, value_dtype="fp8", **base)
    int4 = SyncConfig("asgd_ga", 8, value_dtype="int4", **base)
    assert fp8.payload_mb(100.0) == int8.payload_mb(100.0)
    assert int4.payload_mb(100.0) == pytest.approx(
        100.0 * (0.01 * 0.625 + 1.0 / 4096))
    assert int4.payload_mb(100.0) < int8.payload_mb(100.0)
    # tier indices follow the CODEC_TIERS ladder; codec-off is tier 0
    from repro.core.sync import CODEC_TIERS
    assert CODEC_TIERS == ("fp32", "int8", "fp8", "int4")
    assert SyncConfig("asgd_ga", 8).tier == 0
    assert (int8.tier, fp8.tier, int4.tier) == (1, 2, 3)


def test_config_validation():
    with pytest.raises(ValueError):
        SyncConfig("asgd_ga", 1, error_feedback=True)   # EF needs the codec
    with pytest.raises(ValueError):
        SyncConfig("asgd_ga", 1, overlap_chunks=0)
    with pytest.raises(ValueError):
        SyncConfig("asgd_ga", 1, codec_block=1 << 20)   # idx must fit u16
    # silently-inert codec flags are refused: int8 without a top-k
    # fraction (or on a non-gradient strategy) would train dense while the
    # run summary claims the codec was on
    with pytest.raises(ValueError):
        SyncConfig("asgd_ga", 1, quantize_int8=True)
    with pytest.raises(ValueError):
        SyncConfig("ama", 1, compress_topk=0.1, quantize_int8=True)


def test_config_validation_precise_errors():
    """Each mis-coupling gets its own actionable message (not one blanket
    error), and the new tiers validate their own knob."""
    with pytest.raises(ValueError, match="value_dtype"):
        SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                   value_dtype="int2")
    with pytest.raises(ValueError, match="strategy='asgd_ga'"):
        SyncConfig("sma", 1, compress_topk=0.1, quantize_int8=True)
    with pytest.raises(ValueError, match="compress_topk"):
        SyncConfig("asgd_ga", 1, quantize_int8=True, value_dtype="int4")
    with pytest.raises(ValueError, match="error_feedback"):
        SyncConfig("asgd_ga", 1, error_feedback=True)
    with pytest.raises(ValueError, match="overlap_chunks"):
        SyncConfig("asgd_ga", 1, overlap_chunks=4)
    # a non-default tier without the codec would be silently inert: the
    # run ships fp32 while the summary claims fp8/int4
    with pytest.raises(ValueError, match="inert"):
        SyncConfig("asgd_ga", 1, compress_topk=0.01, value_dtype="fp8")
    with pytest.raises(ValueError, match="inert"):
        SyncConfig("asgd_ga", 1, value_dtype="int4")
    # valid tier configs construct fine
    for dt in ("int8", "fp8", "int4"):
        cfg = SyncConfig("asgd_ga", 4, compress_topk=0.05,
                         quantize_int8=True, value_dtype=dt,
                         error_feedback=True)
        assert cfg.uses_codec and cfg.value_dtype == dt


@pytest.mark.parametrize("value_dtype", ["fp8", "int4"])
def test_codec_tier_sync_round_trip(value_dtype):
    """The sync layer ships each tier end to end: peer message bounded by
    the tier's quantization step, EF residual exact, tier recorded in
    SyncState."""
    g = _grads()
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.25, quantize_int8=True,
                     value_dtype=value_dtype, error_feedback=True,
                     codec_block=512)
    p = jax.tree.map(jnp.zeros_like, g)
    st = init_sync_state(cfg, p)
    assert int(st.tier[0]) == cfg.tier     # one bucket under "single"
    _, st = on_step_gradients(cfg, g, st)
    out, st2 = apply_sync(cfg, p, st, lr=1.0)
    from repro.core.sync import _pack_stacked
    msg = np.asarray(_pack_stacked(st.ga_buffer))
    received = -np.asarray(_pack_stacked(out))
    local = np.roll(received, -cfg.peer_shift, axis=0)
    np.testing.assert_allclose(np.asarray(st2.ef_residual), msg - local,
                               atol=1e-6)
    assert int(st2.tier[0]) == cfg.tier
    # the sync round recorded the controller's signals
    assert (np.asarray(st2.msg_norm) > 0).all()
    assert (np.asarray(st2.resid_norm) > 0).all()
    ratio = np.asarray(st2.resid_norm) / np.asarray(st2.msg_norm)
    assert (ratio < 1.0).all()        # structurally sqrt(1 - capture)


# ------------------------------------------------- convergence parity


def test_compressed_ef_convergence_matches_dense():
    """Acceptance: compressed-with-EF ASGD-GA reaches >=95% of the dense
    run's loss reduction on the emulated 2-pod mesh (the EF residual is what
    makes aggressive compression converge; without it dropped mass is simply
    lost).  Measured as loss *reduction* from the common initial loss —
    both runs converge to near-zero, where a ratio of finals is noise."""
    from repro.data.pipeline import GeoDataset, synthetic_classification
    from repro.models.reference import PAPER_MODELS
    from repro.training.trainer import Trainer, TrainerConfig, \
        stack_pod_batches

    m = PAPER_MODELS["lenet"]
    data = synthetic_classification(1500, m["input_shape"], m["n_classes"],
                                    seed=0)

    def run(sync):
        geo = GeoDataset.partition(data, ["sh", "cq"], [2, 1])
        loaders = [geo.loader("sh", 32, seed=0), geo.loader("cq", 32, seed=1)]
        tr = Trainer(lambda p, b: (m["loss"](p, b), {}), m["init"],
                     TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                                   sync=sync))
        st = tr.init_state(jax.random.key(0))
        st, hist = tr.fit(
            st, lambda s: stack_pod_batches([next(l) for l in loaders]), 120)
        return hist["loss"][0], float(np.mean(hist["loss"][-10:]))

    first, dense = run(SyncConfig("asgd_ga", 4))
    _, comp = run(SyncConfig("asgd_ga", 4, compress_topk=0.05,
                             quantize_int8=True, error_feedback=True,
                             codec_block=1024, overlap_chunks=2))
    assert (first - comp) >= 0.95 * (first - dense), (first, comp, dense)
