"""Pluggable WAN transport seam (PR 5): sim/mesh parity with the legacy
inline ring (bit-exact decoded payloads + identical SyncState telemetry),
EF-residual carry across a retune on each transport, deterministic sim
billing, the measured-feedback probe, and the mesh overlap measurement.

The mesh tests run at any device count (single-device arrays degrade to a
local roll — same numerics); the sharded/collective behaviour and the
overlap speedup are exercised for real in the multi-device CI job
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import AdaptiveSyncController, BucketStats
from repro.core.sync import (BucketOverride, ChunkPayload, SyncConfig,
                             _encode_bucket)
from repro.core.transport import (MeasuredWanProbe, MeshTransport,
                                  SimTransport)
from repro.core.wan import BandwidthTrace, WANConfig, transfer_time
from repro.training.trainer import Trainer, TrainerConfig

SYNC = SyncConfig("asgd_ga", 2, compress_topk=0.2, quantize_int8=True,
                  error_feedback=True, codec_block=128, overlap_chunks=2,
                  bucket_policy="layer-class",
                  buckets=(BucketOverride("norm", compress_topk=0.5),))
TRACE = BandwidthTrace(times_s=(0.0, 3.0), mbps=(100.0, 2.0))


def _loss(params, batch):
    pred = batch["x"] @ params["w"] + params["bias"]
    reg = jnp.mean(params["embed"] ** 2)
    return jnp.mean((pred - batch["y"]) ** 2) + 0.01 * reg, {}


def _init(key):
    kw, ke = jax.random.split(key)
    return {"w": jax.random.normal(kw, (8, 4)) * 0.1,
            "bias": jnp.zeros((4,)),
            "embed": jax.random.normal(ke, (16, 4)) * 0.1}


def _run(transport, n_steps=10, sync=SYNC, retune_at=None, retune_to=None):
    """Drive the production trainer path with the given transport;
    returns (state, trainer, per-sync snapshots)."""
    tr = Trainer(_loss, _init,
                 TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                               sync=sync),
                 transport=transport)
    st = tr.init_state(jax.random.key(0))
    rng = np.random.default_rng(7)
    snaps = []
    for step in range(n_steps):
        if retune_at is not None and step == retune_at:
            tr, st = tr.retune(st, retune_to)
        x = rng.normal(size=(2, 16, 8)).astype(np.float32)
        y = (x[..., :4] * 0.5).astype(np.float32)
        st, _ = tr.train_step(st, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        st = tr.maybe_sync(st, step, model_mb=0.001)
        if transport is not None and hasattr(transport, "tick"):
            transport.tick(0.5)
        snaps.append((np.asarray(st.sync_state.msg_norm).copy(),
                      np.asarray(st.sync_state.ef_residual).copy()))
    return st, tr, snaps


def _assert_same_stream(a, b, label):
    """Bit-identical params + SyncState telemetry after the same stream."""
    st_a, _, snaps_a = a
    st_b, _, snaps_b = b
    for la, lb in zip(jax.tree.leaves(st_a.params),
                      jax.tree.leaves(st_b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{label}: params")
    for field in ("ef_residual", "msg_norm", "resid_norm", "tier"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_a.sync_state, field)),
            np.asarray(getattr(st_b.sync_state, field)),
            err_msg=f"{label}: {field}")
    for i, ((ma, ra), (mb, rb)) in enumerate(zip(snaps_a, snaps_b)):
        np.testing.assert_array_equal(ma, mb, err_msg=f"{label}: step {i}")
        np.testing.assert_array_equal(ra, rb, err_msg=f"{label}: step {i}")


# ------------------------------------------------------------------ parity


def test_sim_and_mesh_bit_identical_to_inline():
    """The satellite property: for the same step stream, every transport
    produces bit-identical decoded payloads (params after the receiver-side
    update) and identical SyncState telemetry — at every sync round, not
    just at the end."""
    inline = _run(None)
    sim = _run(SimTransport(TRACE, WANConfig(fluctuation=0.2, seed=3),
                            probe=MeasuredWanProbe()))
    mesh = _run(MeshTransport(probe=MeasuredWanProbe()))
    _assert_same_stream(inline, sim, "sim vs inline")
    _assert_same_stream(inline, mesh, "mesh vs inline")
    _assert_same_stream(sim, mesh, "sim vs mesh")


def test_ship_bucket_parity_unit():
    """ship_bucket alone: sim (traceable roll) and mesh (jitted, possibly
    sharded collective) permute the same chunks to the same bytes."""
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(3, 512)), jnp.float32)
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                     codec_block=128, overlap_chunks=2)
    chunks, _ = _encode_bucket(cfg, flat, want_local=False)
    sim = SimTransport(TRACE)
    mesh = MeshTransport()
    out_sim = sim.ship_bucket("all", chunks, shift=1)
    out_mesh = mesh.ship_bucket("all", chunks, shift=1, payload_mb=0.01)
    for ca, cb in zip(out_sim, out_mesh):
        for pa, pb in zip(ca, cb):
            np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert len(mesh.records) == 1
    assert mesh.records[0].seconds > 0.0
    assert mesh.records[0].payload_mb == 0.01


# ------------------------------------------- EF carry across retune per transport


@pytest.mark.parametrize("kind", ["inline", "sim", "mesh"])
def test_ef_residual_carries_across_retune_on_transport(kind):
    """The EF-carry guarantee holds on every transport: a mid-run retune
    (tier + interval change) carries the residual byte-identically and the
    post-retune stream stays bit-identical to the inline path's."""
    retuned = dataclasses.replace(
        SYNC, interval=1,
        buckets=(BucketOverride("norm", compress_topk=0.5),
                 BucketOverride("dense", compress_topk=0.05,
                                value_dtype="int4")))

    def make(kind):
        if kind == "sim":
            return SimTransport(TRACE, WANConfig(fluctuation=0.0, seed=0),
                                probe=MeasuredWanProbe())
        if kind == "mesh":
            return MeshTransport(probe=MeasuredWanProbe())
        return None

    # reference: residual right before the retune is what must carry
    st_pre, _, _ = _run(make(kind), n_steps=6)
    resid_pre = np.asarray(st_pre.sync_state.ef_residual)
    assert np.linalg.norm(resid_pre) > 0

    full = _run(make(kind), n_steps=12, retune_at=6, retune_to=retuned)
    inline_full = _run(None, n_steps=12, retune_at=6, retune_to=retuned)
    _assert_same_stream(inline_full, full, f"{kind} retune stream")
    # the retuned run kept compressing under the new knobs
    assert tuple(np.asarray(full[0].sync_state.tier)) == retuned.bucket_tiers


def test_host_seam_split_cache_on_retune():
    """The mesh (host-seam) path follows the same re-jit discipline as the
    monolithic sync step: interval-only retunes and revisited rungs reuse
    the compiled (prepare, finish) pair."""
    mesh = MeshTransport()
    tr = Trainer(_loss, _init,
                 TrainerConfig(n_pods=2, optimizer="sgd", sync=SYNC),
                 transport=mesh)
    st = tr.init_state(jax.random.key(0))
    tr2, st = tr.retune(st, dataclasses.replace(SYNC, interval=4))
    assert tr2._prepare_sync is tr._prepare_sync
    assert tr2._finish_sync is tr._finish_sync
    tier2 = dataclasses.replace(SYNC, value_dtype="int4")
    tr3, st = tr2.retune(st, tier2)
    assert tr3._prepare_sync is not tr2._prepare_sync
    tr4, st = tr3.retune(st, dataclasses.replace(SYNC, interval=8))
    assert tr4._prepare_sync is tr._prepare_sync


# ------------------------------------------------------------- sim billing


def test_sim_billing_is_the_simulator_law():
    """SimTransport bills one _transfer_time draw per round on the round's
    total payload at the trace's bandwidth — reproducible with the same
    seeded rng, i.e. 'exactly as today' in the DES."""
    wan = WANConfig(fluctuation=0.3, latency_s=0.05, seed=11)
    sim = SimTransport(TRACE, wan, probe=MeasuredWanProbe())
    wire = {"dense": 0.8, "norm": 0.2}
    t0 = sim.on_sync(wire, step=0)
    sim.tick(5.0)                      # past the 3 s segment edge -> 2 Mbps
    t1 = sim.on_sync(wire, step=1)
    rng = np.random.default_rng(11)
    assert t0 == pytest.approx(transfer_time(1.0, 100.0, wan, rng))
    assert t1 == pytest.approx(transfer_time(1.0, 2.0, wan, rng))
    # per-bucket records split the round proportionally and sum back
    by_round = {}
    for r in sim.records:
        by_round[r.step] = by_round.get(r.step, 0.0) + r.seconds
    assert by_round[0] == pytest.approx(t0)
    assert by_round[1] == pytest.approx(t1)
    # the probe saw the achieved bandwidth of each round
    assert sim.probe.n_observations == 2
    assert sim.probe.last_mbps == pytest.approx(1.0 * 8.0 / t1)


def test_sim_billing_is_deterministic():
    wan = WANConfig(fluctuation=0.3, seed=5)
    a = SimTransport(TRACE, wan)
    b = SimTransport(TRACE, wan)
    for t in (0.0, 1.0, 4.0):
        a.clock_s = b.clock_s = t
        assert a.on_sync({"all": 0.5}) == b.on_sync({"all": 0.5})


# ---------------------------------------------------------- measured probe


def test_measured_probe_math_and_cliff_snap():
    probe = MeasuredWanProbe(alpha=0.5, cliff_snap=4.0)
    p = probe.observe_transfer(1.0, 0.1)     # 1 MB in 0.1 s = 80 Mbps
    assert probe.last_mbps == pytest.approx(80.0)
    assert p.bandwidth_mbps == pytest.approx(80.0)
    # a collapse snaps the belief instead of EMA-averaging through it
    probe.observe_transfer(1.0, 8.0)         # 1 Mbps, > 4x below the EMA
    assert probe.estimator.bandwidth_mbps == pytest.approx(1.0)
    assert probe.n_observations == 2


def test_measured_loop_reacts_to_crash_without_trace():
    """The acceptance loop in miniature: the controller's only bandwidth
    input is transport-billed transfer times (probe_est injection — no
    observe_wan, no trace, no bus), and a link crash still escalates it."""
    trace = BandwidthTrace(times_s=(0.0, 10.0), mbps=(100.0, 0.5))
    sim = SimTransport(trace, WANConfig(fluctuation=0.0, latency_s=0.0),
                       probe=MeasuredWanProbe())
    base = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                      error_feedback=True)
    tuner = AdaptiveSyncController(base, 44.6, 0.3,
                                   probe_est=sim.probe.estimator,
                                   interval_budget=8, hysteresis=2)
    calm = BucketStats(1.0, 0.3)
    rung0 = tuner.rung
    eff = []
    for step in range(40):
        tuner.update(step, calm)
        if step % tuner.interval == tuner.interval - 1:
            wire = {"all": tuner.current.payload_mb(44.6)}
            sim.on_sync(wire, step=step)
        sim.tick(0.3)
        eff.append(tuner.rung)
    assert sim.probe.n_observations > 0
    # post-crash the measured probe repriced the link and the controller
    # escalated off its starting rung (cheaper payload and/or wider interval)
    assert tuner.rung > rung0 or tuner.interval > base.interval
    assert tuner._probe_est.bandwidth_mbps < 5.0


# ------------------------------------------------------------- mesh layer


def test_mesh_records_per_bucket_and_feeds_probe():
    mesh = MeshTransport(probe=MeasuredWanProbe())
    _, tr, _ = _run(mesh, n_steps=8)
    # interval 2 over 8 steps -> 4 sync rounds; >= 2 non-empty buckets each
    buckets = {r.bucket for r in mesh.records}
    assert {"norm", "dense", "embed"} <= buckets
    assert all(r.seconds > 0 for r in mesh.records)
    assert all(r.payload_mb > 0 for r in mesh.records)
    assert mesh.probe.n_observations == 4
    assert mesh.probe.estimator.bandwidth_mbps is not None
    assert mesh.sharded == (jax.device_count() >= 2)


def test_mesh_overlap_measurement_structure():
    """Runs at any device count (collective when sharded, local roll
    otherwise): the report carries both schedules' wall-clock and their
    ratio, and both schedules decode to the same bytes (asserted
    internally)."""
    cfg = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                     error_feedback=True, codec_block=1024,
                     overlap_chunks=4)
    mesh = MeshTransport(emulate_mbps=2.0)
    rep = mesh.measure_overlap(cfg, n_pods=2, n_elems=1 << 16, reps=1)
    assert rep["chunks"] == 4
    assert rep["t_pipelined_s"] > 0 and rep["t_serialized_s"] > 0
    assert rep["overlap_speedup"] > 0
    assert rep["sharded"] == (jax.device_count() >= 2)


def test_parse_transport_rejects_unknown_options():
    """A typoed sim/mesh knob must refuse, not silently run the default
    (a dropped latency knob biases the measured bandwidth belief)."""
    from repro.launch.train import parse_transport

    sync = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                      error_feedback=True)
    assert parse_transport("inline", None, sync) is None
    t = parse_transport("sim:fluct=0.1,latency=0,seed=3", TRACE, sync)
    assert t.wan.fluctuation == 0.1 and t.wan.latency_s == 0.0
    m = parse_transport("mesh:mbps=5", TRACE, sync)
    assert m.emulate_mbps == 5.0
    with pytest.raises(ValueError, match="unknown option 'latencey'"):
        parse_transport("sim:latencey=0", TRACE, sync)
    with pytest.raises(ValueError, match="unknown option 'fluct'"):
        parse_transport("mesh:fluct=0.2", TRACE, sync)
    with pytest.raises(ValueError, match="needs --wan-trace"):
        parse_transport("sim", None, sync)
    with pytest.raises(ValueError, match="unknown --transport"):
        parse_transport("carrier-pigeon", TRACE, sync)


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs >= 4 devices (multi-device CI job: "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
def test_mesh_overlap_speedup_on_multi_device_mesh():
    """The acceptance criterion: on >= 4 virtual devices MeshTransport
    reports a measured overlap speedup for overlap_chunks > 1 — chunk
    transfers genuinely hide behind the next chunk's encode."""
    cfg = SyncConfig("asgd_ga", 4, compress_topk=0.05, quantize_int8=True,
                     error_feedback=True, overlap_chunks=8)
    mesh = MeshTransport(emulate_mbps=1.0)
    rep = mesh.measure_overlap(cfg, n_pods=4, n_elems=1 << 20, reps=2)
    assert rep["sharded"] and rep["n_devices"] >= 4
    assert rep["chunks"] == 8
    assert rep["overlap_speedup"] > 1.1, rep
