"""Direct coverage for ``repro.checkpoint.checkpoint`` (previously only
exercised indirectly through test_elasticity): save/restore round-trips,
manifest contents, bf16 handling, and — the elasticity-engine surface —
``pod_resize`` restore paths: grow (mean / clone seeding), shrink
(mean-preserving shift / plain drop), same-size no-op, restore into a
different aggregation topology, and every refusal path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core.sync import SyncConfig
from repro.core.topology import HierarchicalTransport, TopologySpec
from repro.core.wan import BandwidthTrace, WANConfig
from repro.training.trainer import Trainer, TrainerConfig


def _tree(n_pods, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(n_pods, 6, 3)), jnp.float32),
        "opt": {"m": jnp.asarray(rng.normal(size=(n_pods, 6, 3)),
                                 jnp.float32)},
        "bias": jnp.asarray(rng.normal(size=(n_pods, 3)), jnp.float32),
    }


# ------------------------------------------------------------- round trips


def test_save_restore_roundtrip_same_size(tmp_path):
    tree = _tree(3)
    ckpt.save(str(tmp_path), tree, step=17, metadata={"model": "t"})
    like = jax.tree.map(jnp.zeros_like, tree)
    out, step = ckpt.restore(str(tmp_path), like)
    assert step == 17
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_contents(tmp_path):
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=5, metadata={"pods": 2})
    m = ckpt.load_manifest(str(tmp_path))
    assert m["step"] == 5
    assert m["metadata"] == {"pods": 2}
    assert set(m["keys"]) == {"w", "opt/m", "bias"}
    assert all(d == "float32" for d in m["dtypes"])


def test_bf16_leaves_roundtrip_via_fp32(tmp_path):
    """bf16 stores upcast (lossless) and restores back to bf16 exactly."""
    tree = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(2, 8)),
                             jnp.bfloat16)}
    ckpt.save(str(tmp_path), tree, step=1)
    out, _ = ckpt.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, tree))
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(tree["w"], np.float32),
                                  np.asarray(out["w"], np.float32))


def test_same_size_roundtrip_with_pod_resize_flag(tmp_path):
    """pod_resize on a matching-size restore is a no-op, any mode."""
    tree = _tree(3)
    ckpt.save(str(tmp_path), tree, step=2)
    like = jax.tree.map(jnp.zeros_like, tree)
    for mode in ("mean", "clone", "drop"):
        out, _ = ckpt.restore(str(tmp_path), like, pod_resize=mode)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- grow paths


def test_grow_mean_seeds_joiners_with_mean_replica(tmp_path):
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=3)
    like = jax.tree.map(
        lambda x: jnp.zeros((4,) + x.shape[1:], x.dtype), tree)
    out, _ = ckpt.restore(str(tmp_path), like, pod_resize="mean")
    for old, new in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        old, new = np.asarray(old), np.asarray(new)
        assert new.shape[0] == 4
        np.testing.assert_array_equal(new[:2], old)       # survivors exact
        want = old.astype(np.float32).mean(axis=0)
        np.testing.assert_allclose(new[2], want, rtol=1e-6)
        np.testing.assert_array_equal(new[2], new[3])     # all joiners alike
        # the global parameter mean is preserved by mean-seeding
        np.testing.assert_allclose(new.mean(axis=0), want, rtol=1e-6)


def test_grow_clone_seeds_joiners_with_pod0(tmp_path):
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=3)
    like = jax.tree.map(
        lambda x: jnp.zeros((3,) + x.shape[1:], x.dtype), tree)
    out, _ = ckpt.restore(str(tmp_path), like, pod_resize="clone")
    for old, new in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(new)[2],
                                      np.asarray(old)[0])


def test_grow_drop_refuses(tmp_path):
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=0)
    like = jax.tree.map(
        lambda x: jnp.zeros((4,) + x.shape[1:], x.dtype), tree)
    with pytest.raises(ValueError, match="cannot grow"):
        ckpt.restore(str(tmp_path), like, pod_resize="drop")


# ----------------------------------------------------------- shrink paths


def test_shrink_mean_preserves_global_mean(tmp_path):
    tree = _tree(4)
    ckpt.save(str(tmp_path), tree, step=9)
    like = jax.tree.map(
        lambda x: jnp.zeros((2,) + x.shape[1:], x.dtype), tree)
    out, step = ckpt.restore(str(tmp_path), like, pod_resize="mean")
    assert step == 9
    for old, new in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        old, new = np.asarray(old, np.float32), np.asarray(new, np.float32)
        assert new.shape[0] == 2
        # survivors shifted so their mean equals the old global mean:
        # departed pods' progress is re-averaged in, not discarded
        np.testing.assert_allclose(new.mean(axis=0), old.mean(axis=0),
                                   rtol=1e-5, atol=1e-6)
        # and the shift is rigid (pairwise differences survive exactly)
        np.testing.assert_allclose(new[0] - new[1], old[0] - old[1],
                                   rtol=1e-5, atol=1e-6)


def test_shrink_drop_keeps_first_pods_verbatim(tmp_path):
    tree = _tree(4)
    ckpt.save(str(tmp_path), tree, step=0)
    like = jax.tree.map(
        lambda x: jnp.zeros((2,) + x.shape[1:], x.dtype), tree)
    for mode in ("drop", "clone"):   # both shrink by plain truncation
        out, _ = ckpt.restore(str(tmp_path), like, pod_resize=mode)
        for old, new in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(new),
                                          np.asarray(old)[:2])


# ---------------------------------------------------------- refusal paths


def test_restore_without_pod_resize_refuses_mismatch(tmp_path):
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=0)
    like = jax.tree.map(
        lambda x: jnp.zeros((3,) + x.shape[1:], x.dtype), tree)
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), like)


def test_restore_refuses_trailing_dim_mismatch(tmp_path):
    """pod_resize covers ONLY the leading dim: a trailing-dim change is a
    different model and must refuse, not silently resize."""
    tree = {"w": jnp.zeros((2, 6, 3))}
    ckpt.save(str(tmp_path), tree, step=0)
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((4, 6, 5))},
                     pod_resize="mean")


def test_restore_refuses_unknown_mode_and_missing_leaf(tmp_path):
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=0)
    with pytest.raises(ValueError, match="unknown pod_resize"):
        ckpt.restore(str(tmp_path), tree, pod_resize="median")
    like = dict(tree)
    like["extra"] = jnp.zeros((2, 3))
    with pytest.raises(KeyError, match="extra"):
        ckpt.restore(str(tmp_path), like)


# ------------------------------------- restore into a different topology


def _loss(params, batch):
    pred = batch["x"] @ params["w"] + params["bias"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _init(key):
    return {"w": jax.random.normal(key, (8, 4)) * 0.1,
            "bias": jnp.zeros((4,))}


def _drive(tr, st, n_steps, n_pods, seed=3):
    rng = np.random.default_rng(seed)
    for step in range(n_steps):
        x = rng.normal(size=(n_pods, 16, 8)).astype(np.float32)
        y = (x[..., :4] * 0.5).astype(np.float32)
        st, _ = tr.train_step(st, {"x": jnp.asarray(x),
                                   "y": jnp.asarray(y)})
        st = tr.maybe_sync(st, step, model_mb=0.001)
    return st


def test_restore_into_different_topology(tmp_path):
    """The elasticity path end-to-end: params trained and checkpointed
    under a flat 2-pod ring restore into a 3-pod run aggregating through
    a hierarchical (2-region tree) transport — pod_resize grows the
    stack, the new topology's transport ships it, and training proceeds
    with the restored values."""
    sync = SyncConfig("asgd_ga", 2, compress_topk=0.2, quantize_int8=True,
                      error_feedback=True, codec_block=128)
    tr2 = Trainer(_loss, _init,
                  TrainerConfig(n_pods=2, optimizer="sgd", lr=0.05,
                                sync=sync))
    st2 = _drive(tr2, tr2.init_state(jax.random.key(0)), 4, 2)
    ckpt.save(str(tmp_path), st2.params, step=4,
              metadata={"pods": 2, "topology": "ring"})

    spec = TopologySpec.from_regions(["sh", "sh", "cq"], kind="tree")
    hier = HierarchicalTransport(
        spec, BandwidthTrace((0.0,), (100.0,)), wan=WANConfig(seed=0))
    tr3 = Trainer(_loss, _init,
                  TrainerConfig(n_pods=3, optimizer="sgd", lr=0.05,
                                sync=sync),
                  transport=hier)
    st3 = tr3.init_state(jax.random.key(1))
    restored, step = ckpt.restore(str(tmp_path), st3.params,
                                  pod_resize="mean")
    assert step == 4
    old = np.asarray(st2.params["w"], np.float32)
    new = np.asarray(restored["w"], np.float32)
    assert new.shape[0] == 3
    np.testing.assert_array_equal(new[:2], old)
    np.testing.assert_allclose(new.mean(axis=0), old.mean(axis=0),
                               rtol=1e-5, atol=1e-6)
    # training continues through the hierarchical transport from the
    # restored values
    st3 = st3._replace(params=restored)
    st3 = _drive(tr3, st3, 4, 3)
    assert np.isfinite(np.asarray(st3.params["w"])).all()
    assert len(hier.records) > 0


def test_restore_same_values_across_topologies(tmp_path):
    """A checkpoint is topology-agnostic by construction: restoring the
    same file under flat and hierarchical trainers yields bit-identical
    parameter stacks (topology lives in the transport, not the state)."""
    sync = SyncConfig("asgd_ga", 2, compress_topk=0.2, quantize_int8=True,
                      error_feedback=True, codec_block=128)
    tr = Trainer(_loss, _init,
                 TrainerConfig(n_pods=3, optimizer="sgd", lr=0.05,
                               sync=sync))
    st = _drive(tr, tr.init_state(jax.random.key(0)), 4, 3)
    ckpt.save(str(tmp_path), st.params, step=4)
    like = jax.tree.map(jnp.zeros_like, st.params)
    flat, _ = ckpt.restore(str(tmp_path), like)
    hier, _ = ckpt.restore(str(tmp_path), like, pod_resize="mean")
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(hier)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------- _resize_pod_dim directly


def test_resize_pod_dim_grow_drop_raises():
    with pytest.raises(ValueError, match="cannot grow"):
        ckpt._resize_pod_dim(np.zeros((2, 4), np.float32), 3, "drop")


def test_resize_pod_dim_shrink_to_one_mean_is_global_mean():
    """Shrinking to a single pod under "mean" must land that pod exactly
    on the old global mean (the shift fully re-averages the departed)."""
    arr = np.random.default_rng(0).normal(size=(4, 5, 2)).astype(np.float32)
    out = ckpt._resize_pod_dim(arr, 1, "mean")
    assert out.shape == (1, 5, 2)
    np.testing.assert_allclose(out[0], arr.mean(axis=0), rtol=1e-6,
                               atol=1e-7)


def test_resize_pod_dim_bf16_roundtrip_keeps_dtype():
    """The mean math upcasts through fp32 but the result stays bf16, both
    growing and shrinking."""
    arr = np.asarray(jnp.asarray(
        np.random.default_rng(1).normal(size=(2, 8)), jnp.bfloat16))
    grown = ckpt._resize_pod_dim(arr, 4, "mean")
    assert grown.dtype == arr.dtype and grown.shape == (4, 8)
    np.testing.assert_array_equal(grown[:2], arr)
    shrunk = ckpt._resize_pod_dim(grown, 2, "mean")
    assert shrunk.dtype == arr.dtype and shrunk.shape == (2, 8)


def test_resize_pod_dim_same_size_is_identity():
    arr = np.random.default_rng(2).normal(size=(3, 4)).astype(np.float32)
    for mode in ("mean", "clone", "drop"):
        assert ckpt._resize_pod_dim(arr, 3, mode) is arr


# ------------------------------------------------ atomicity & corruption


def test_save_leaves_no_staging_dir(tmp_path):
    """The atomic writer stages in a hidden sibling dir and cleans it up:
    after save, the directory holds exactly the committed pair."""
    d = tmp_path / "ck"
    ckpt.save(str(d), _tree(2), step=1)
    assert sorted(p.name for p in d.iterdir()) == ["arrays.npz",
                                                   "manifest.json"]


def test_truncated_arrays_raise_named_corruption_error(tmp_path):
    """A torn write (arrays.npz truncated after commit) must fail restore
    with CheckpointCorruptError, not decode garbage or KeyError."""
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=7)
    apath = tmp_path / "arrays.npz"
    blob = apath.read_bytes()
    apath.write_bytes(blob[: len(blob) // 2])
    like = jax.tree.map(jnp.zeros_like, tree)
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(str(tmp_path), like)


def test_corrupted_arrays_same_length_raise_via_crc(tmp_path):
    """Bit rot that keeps the byte count is caught by the manifest CRC."""
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=7)
    apath = tmp_path / "arrays.npz"
    blob = bytearray(apath.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    apath.write_bytes(bytes(blob))
    with pytest.raises(ckpt.CheckpointCorruptError, match="CRC"):
        ckpt.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, tree))


def test_missing_arrays_raise_corruption_error(tmp_path):
    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=7)
    (tmp_path / "arrays.npz").unlink()
    with pytest.raises(ckpt.CheckpointCorruptError, match="no arrays.npz"):
        ckpt.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, tree))


def test_garbage_manifest_raises_corruption_error(tmp_path):
    ckpt.save(str(tmp_path), _tree(2), step=7)
    (tmp_path / "manifest.json").write_text("{not json")
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.load_manifest(str(tmp_path))


def test_old_manifest_without_commit_record_still_loads(tmp_path):
    """Manifests written before the commit record (no arrays_bytes/crc32)
    must keep restoring — the integrity check is additive."""
    import json

    tree = _tree(2)
    ckpt.save(str(tmp_path), tree, step=4)
    mpath = tmp_path / "manifest.json"
    m = json.loads(mpath.read_text())
    m.pop("arrays_bytes"), m.pop("arrays_crc32")
    mpath.write_text(json.dumps(m))
    out, step = ckpt.restore(str(tmp_path),
                             jax.tree.map(jnp.zeros_like, tree))
    assert step == 4
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
