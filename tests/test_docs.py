"""The docs gate (tools/check_docs.py) as a tier-1 test: intra-repo links
resolve, fenced Python snippets compile, and each sync-related launcher
flag is owned by exactly one cookbook page.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_docs  # noqa: E402


def test_intra_repo_links_resolve():
    errors = []
    n = check_docs.check_links(errors)
    assert n > 0, "link scan found no links — scan is broken"
    assert not errors, errors


def test_python_snippets_compile():
    errors = []
    n = check_docs.check_snippets(errors)
    assert n >= 1, "expected at least one fenced python snippet in docs"
    assert not errors, errors


def test_sync_flags_owned_by_exactly_one_page():
    errors = []
    check_docs.check_flag_ownership(errors)
    assert not errors, errors
