"""Shared fixtures.  NOTE: no XLA device-count flags here — unit/smoke tests
run on the single real CPU device; multi-device tests spawn subprocesses
(see test_dryrun_small.py) so they never leak 512 fake devices into this
process."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
