"""Fault-tolerant WAN sync (chaos injection, bounded retry, degraded
rounds): the ChaosTransport contract (empty plan == bit-exact
passthrough; injected faults retry/degrade/roll back deterministically),
the per-chunk checksum path, the degraded-round mask semantics (EF
residuals preserved, telemetry zeroed, no spurious ef-guard reading),
the ship-loop retry law, EventBus delivery isolation, the probe's
degenerate-observation guard, the DES failure billing, and the
``--faults`` launcher grammar.

The seeded chaos property test reads ``CHAOS_SEED`` (CI runs a small
seed matrix): any plan of retryable faults must recover to parameters
bit-identical to the clean run.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import AdaptiveSyncController, BucketStats
from repro.core.control_plane import (CloudEvent, EventBus,
                                      EventDeliveryError)
from repro.core.faults import (ChaosTransport, FaultEvent, FaultPlan,
                               resolve_round)
from repro.core.sync import (BucketOverride, PodUnreachableError,
                             SyncConfig, TransferFailed, _encode_bucket,
                             chunk_checksum_rows, ship_sync_payloads)
from repro.core.transport import MeasuredWanProbe, SimTransport
from repro.core.wan import (BandwidthTrace, RetryPolicy, SimCloud,
                            SimEvent, WANConfig, retry_schedule, simulate)
from repro.training.trainer import Trainer, TrainerConfig

SYNC = SyncConfig("asgd_ga", 2, compress_topk=0.2, quantize_int8=True,
                  error_feedback=True, codec_block=128, overlap_chunks=2,
                  bucket_policy="layer-class",
                  buckets=(BucketOverride("norm", compress_topk=0.5),))
TRACE = BandwidthTrace(times_s=(0.0,), mbps=(100.0,))
CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


def _loss(params, batch):
    pred = batch["x"] @ params["w"] + params["bias"]
    reg = jnp.mean(params["embed"] ** 2)
    return jnp.mean((pred - batch["y"]) ** 2) + 0.01 * reg, {}


def _init(key):
    kw, ke = jax.random.split(key)
    return {"w": jax.random.normal(kw, (8, 4)) * 0.1,
            "bias": jnp.zeros((4,)),
            "embed": jax.random.normal(ke, (16, 4)) * 0.1}


def _transport(plan=None, tolerate=True, policy=None):
    inner = SimTransport(TRACE, WANConfig(fluctuation=0.0, latency_s=0.0,
                                          seed=0),
                         probe=MeasuredWanProbe())
    if plan is None:
        return inner
    return ChaosTransport(inner, plan, policy=policy, tolerate=tolerate)


def _run(transport, n_steps=6, n_pods=2, sync=SYNC, raises=False):
    """Drive the production trainer path; returns (state, trainer, snaps,
    raised) where snaps are per-step (msg_norm, ef_residual) copies."""
    tr = Trainer(_loss, _init,
                 TrainerConfig(n_pods=n_pods, optimizer="sgd", lr=0.05,
                               sync=sync),
                 transport=transport)
    st = tr.init_state(jax.random.key(0))
    rng = np.random.default_rng(7)
    snaps, raised = [], []
    for step in range(n_steps):
        x = rng.normal(size=(n_pods, 16, 8)).astype(np.float32)
        y = (x[..., :4] * 0.5).astype(np.float32)
        st, _ = tr.train_step(st, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        try:
            st = tr.maybe_sync(st, step, model_mb=0.001)
        except PodUnreachableError as e:
            if not raises:
                raise
            raised.append((step, e.pod))
        if transport is not None and hasattr(transport, "tick"):
            transport.tick(0.5)
        snaps.append((np.asarray(st.sync_state.msg_norm).copy(),
                      np.asarray(st.sync_state.ef_residual).copy()))
    return st, tr, snaps, raised


def _assert_same_stream(a, b, label):
    st_a, _, snaps_a, _ = a
    st_b, _, snaps_b, _ = b
    for la, lb in zip(jax.tree.leaves(st_a.params),
                      jax.tree.leaves(st_b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{label}: params")
    for field in ("ef_residual", "msg_norm", "resid_norm", "tier"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_a.sync_state, field)),
            np.asarray(getattr(st_b.sync_state, field)),
            err_msg=f"{label}: {field}")
    for i, ((ma, ra), (mb, rb)) in enumerate(zip(snaps_a, snaps_b)):
        np.testing.assert_array_equal(ma, mb, err_msg=f"{label}: step {i}")
        np.testing.assert_array_equal(ra, rb, err_msg=f"{label}: step {i}")


# ------------------------------------------------------- passthrough


def test_empty_plan_is_bit_exact_passthrough():
    """ChaosTransport with no events IS the wrapped transport: params,
    telemetry, billed records and probe belief all bit-identical."""
    clean = _run(_transport())
    wrapped_t = _transport(FaultPlan())
    wrapped = _run(wrapped_t)
    _assert_same_stream(clean, wrapped, "empty plan vs bare")
    bare_t = clean[1].transport
    assert [r.seconds for r in bare_t.records] == \
           [r.seconds for r in wrapped_t.records]
    assert bare_t.probe.estimator.bandwidth_mbps == \
           wrapped_t.probe.estimator.bandwidth_mbps
    assert wrapped_t.in_graph        # no ship faults -> in-graph fast path
    assert wrapped_t.retries == 0 and wrapped_t.outcomes == []


# ---------------------------------------------------- retry + checksum


def test_retry_then_succeed_bit_equal_and_billed():
    """Failed attempts retry to success: parameters bit-equal to the
    clean run, every retry counted and billed, the probe fed the
    degraded (not clean) round time."""
    plan = FaultPlan((FaultEvent("fail", step=3, pod=1, attempts=2),))
    chaos = _transport(plan)
    faulted = _run(chaos)
    clean = _run(_transport())
    _assert_same_stream(clean, faulted, "retry-then-succeed vs clean")
    assert chaos.retries == 2
    assert chaos.retried_mb > 0.0
    [o] = [o for o in chaos.outcomes if o["step"] == 3]
    assert o["kinds"] == ["fail"] and o["attempts"] == 2
    assert o["extra_s"] == pytest.approx(
        retry_schedule(o["expected_s"], chaos.retry_policy, 2))
    # the degraded round slowed the measured belief below the clean run's
    clean_bw = clean[1].transport.probe.estimator.bandwidth_mbps
    assert chaos.probe.estimator.bandwidth_mbps < clean_bw


def test_hard_timeout_is_retried_soft_timeout_is_slow():
    policy = RetryPolicy(max_retries=3, timeout_factor=4.0)
    hard = FaultPlan((FaultEvent("timeout", step=3, factor=6.0),))
    soft = FaultPlan((FaultEvent("timeout", step=3, factor=2.0),))
    out_h = resolve_round(hard, policy, 3, 1.0)
    out_s = resolve_round(soft, policy, 3, 1.0)
    assert out_h.attempts == 1 and out_h.extra_s > 0 and out_h.slowdown == 1.0
    assert out_s.attempts == 0 and out_s.extra_s == 0.0 \
        and out_s.slowdown == 2.0
    t = _transport(hard, policy=policy)
    faulted = _run(t)
    _assert_same_stream(_run(_transport()), faulted, "hard timeout retry")
    assert t.retries == 1


def test_corruption_caught_by_checksums_and_reshipped():
    """A wire bit-flip is caught by the per-chunk checksums and the
    bucket re-ships clean: parameters bit-equal to the clean run."""
    plan = FaultPlan((FaultEvent("corrupt", step=3, pod=1),))
    chaos = _transport(plan)
    faulted = _run(chaos)
    _assert_same_stream(_run(_transport()), faulted, "corrupt caught")
    assert chaos.retries == 1


def test_corruption_undetected_without_tolerance_diverges():
    """The no-tolerance baseline ships unverified: the same bit-flip
    decodes straight into the parameters."""
    plan = FaultPlan((FaultEvent("corrupt", step=3, pod=1),))
    chaos = _transport(plan, tolerate=False)
    st, _, _, _ = _run(chaos)
    clean, _, _, _ = _run(_transport())
    assert chaos.retries == 0        # nothing caught, nothing retried
    damage = max(float(np.abs(np.asarray(l)).max()) if np.isfinite(
                     np.asarray(l)).all() else np.inf
                 for l in jax.tree.leaves(st.params))
    clean_scale = max(float(np.abs(np.asarray(l)).max())
                      for l in jax.tree.leaves(clean.params))
    assert damage > 1e4 * clean_scale


def test_chunk_checksums_catch_any_row_flip():
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(3, 512)), jnp.float32)
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                     codec_block=128)
    chunks, _ = _encode_bucket(cfg, flat, want_local=False)
    crc = chunk_checksum_rows(chunks)
    assert len(crc) == 3 and len(set(crc)) == 3
    # same content -> same checksums; one flipped scale row -> changed
    assert chunk_checksum_rows(chunks) == crc
    scales = np.asarray(chunks[0].scales).copy()
    scales.view(np.uint32)[1] ^= np.uint32(0x40000000)
    bad = (chunks[0]._replace(scales=jnp.asarray(scales)),) + \
        tuple(chunks[1:])
    bad_crc = chunk_checksum_rows(bad)
    assert bad_crc[1] != crc[1] and bad_crc[0] == crc[0]


def test_ship_retry_exhaustion_raises_pod_unreachable():
    """A transport that keeps failing past the retry budget surfaces
    PodUnreachableError from the ship loop (the defensive contract —
    ChaosTransport itself degrades the round before ever reaching it)."""

    class AlwaysFail:
        in_graph = False
        verify_checksums = False
        retry_policy = RetryPolicy(max_retries=2)

        def __init__(self):
            self.notes = []

        def note_retry(self, bucket, attempt, err):
            self.notes.append((bucket, attempt, err.reason))

        def ship_bucket(self, name, chunks, shift, payload_mb=0.0):
            raise TransferFailed(name, 0, "fail", pod=1)

    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(2, 256)), jnp.float32)
    cfg = SyncConfig("asgd_ga", 1, compress_topk=0.1, quantize_int8=True,
                     codec_block=128)
    chunks, _ = _encode_bucket(cfg, flat, want_local=False)
    ship = AlwaysFail()
    with pytest.raises(PodUnreachableError) as ei:
        ship_sync_payloads(cfg, {"all": chunks}, ship, {"all": 0.1})
    assert ei.value.pod == 1 and ei.value.bucket == "all"
    assert [a for _, a, _ in ship.notes] == [1, 2]   # budget exhausted


# ------------------------------------------------------ degraded rounds


def test_degraded_round_masks_membership_and_preserves_ef():
    """3 pods, pod 2 dead: the round completes over the survivors — the
    one delivered message applies bit-identically to the clean run, the
    undelivered senders keep their FULL message in the EF residual, and
    the dead rows' telemetry zeroes out (no fake reading)."""
    sync = dataclasses.replace(SYNC, bucket_policy="single", buckets=())
    plan = FaultPlan((FaultEvent("crash", step=1, pod=2),))
    chaos = _transport(plan)
    st_f, _, snaps_f, _ = _run(chaos, n_steps=2, n_pods=3, sync=sync)
    st_c, _, snaps_c, _ = _run(_transport(), n_steps=2, n_pods=3, sync=sync)
    assert chaos.degraded_rounds == 1
    # shift 1: applied = (0, 1, 0) — only pod 1 received (from pod 0);
    # delivered = (1, 0, 0) — only pod 0's message landed
    for lf, lc in zip(jax.tree.leaves(st_f.params),
                      jax.tree.leaves(st_c.params)):
        np.testing.assert_array_equal(np.asarray(lf)[1], np.asarray(lc)[1])
    msg = np.asarray(st_f.sync_state.msg_norm)
    assert msg[0].sum() > 0.0
    assert msg[1].sum() == 0.0 and msg[2].sum() == 0.0
    resid_f = np.asarray(st_f.sync_state.ef_residual)
    resid_c = np.asarray(st_c.sync_state.ef_residual)
    # delivered sender: residual identical to the clean run's
    np.testing.assert_array_equal(resid_f[0], resid_c[0])
    # undelivered senders: the WHOLE message stays in the residual —
    # strictly more energy than the clean run's dropped-part residual
    for p in (1, 2):
        assert np.linalg.norm(resid_f[p]) > np.linalg.norm(resid_c[p])


def test_degraded_round_never_trips_ef_guard():
    """2 pods, peer dead => NO message delivered anywhere: telemetry is
    all-zero, BucketStats reads 'no reading yet', and the controller must
    NOT de-escalate on it (the ef-guard fires on evidence, not absence)."""
    plan = FaultPlan((FaultEvent("crash", step=1, pod=1),))
    chaos = _transport(plan)
    st, tr, _, _ = _run(chaos, n_steps=2)
    assert chaos.degraded_rounds == 1
    stats = BucketStats.from_sync_state(st.sync_state)
    assert stats.msg_norm == 0.0 and stats.resid_norm == 0.0
    tuner = AdaptiveSyncController(tr.cfg.sync, 44.6, 0.3, ef_guard=0.9)
    tuner.observe_wan(100.0)
    rung0 = tuner.rung
    upd = tuner.update(2, stats)
    assert upd is None and tuner.rung == rung0


def test_crash_rollback_raises_once_then_degrades():
    plan = FaultPlan((FaultEvent("crash", step=1, pod=1,
                                 mode="rollback"),))
    chaos = _transport(plan)
    st, tr, snaps, raised = _run(chaos, n_steps=6, raises=True)
    assert raised == [(1, 1)]            # one rollback, at the first round
    assert chaos.degraded_rounds == 2    # steps 3 and 5 complete degraded
    assert chaos.take_new_crashes() == (1,)
    assert chaos.take_new_crashes() == ()    # reported exactly once
    chaos.clear_crash(1)
    assert chaos.crash_recoveries == 1
    chaos.begin_round(7)
    assert chaos.round_failed_pods == ()     # removed pod stops degrading


# -------------------------------------------------- chaos property test


def test_seeded_chaos_plan_always_recovers():
    """Property (seed from CHAOS_SEED, CI runs a matrix): ANY plan of
    retryable faults — failed attempts, hard timeouts, corruption —
    within the retry budget recovers to parameters and telemetry
    bit-identical to the clean run, with every injection counted."""
    rng = np.random.default_rng(CHAOS_SEED)
    policy = RetryPolicy(max_retries=3)
    steps = rng.choice([1, 3, 5, 7, 9], size=3, replace=False)
    events, expected_retries = [], 0
    for s in steps:
        kind = rng.choice(["fail", "timeout", "corrupt"])
        if kind == "fail":
            n = int(rng.integers(1, policy.max_retries + 1))
            events.append(FaultEvent("fail", step=int(s), pod=1,
                                     attempts=n))
            expected_retries += n
        elif kind == "timeout":
            events.append(FaultEvent("timeout", step=int(s), pod=1,
                                     factor=float(policy.timeout_factor
                                                  + rng.integers(0, 4))))
            expected_retries += 1
        else:
            events.append(FaultEvent("corrupt", step=int(s),
                                     pod=int(rng.integers(0, 2))))
            expected_retries += 1
    plan = FaultPlan(tuple(events), seed=CHAOS_SEED)
    chaos = _transport(plan, policy=policy)
    faulted = _run(chaos, n_steps=10)
    clean = _run(_transport(), n_steps=10)
    _assert_same_stream(clean, faulted, f"chaos seed {CHAOS_SEED}")
    assert chaos.retries == expected_retries
    # the decision stream replays exactly through the shared pure law,
    # JSON round-trip included (the check_regression discipline)
    for o in json.loads(json.dumps(chaos.outcomes)):
        out = resolve_round(plan, policy, o["step"], o["expected_s"])
        assert [list(out.kinds), out.attempts, out.extra_s, out.slowdown] \
            == [o["kinds"], o["attempts"], o["extra_s"], o["slowdown"]]


# ------------------------------------------------------- event delivery


def test_event_bus_isolates_subscriber_errors():
    bus = EventBus()
    seen = []
    bus.subscribe("pod_crashed", lambda e: seen.append(("a", e.region)))

    def boom(e):
        raise KeyError(f"unknown region {e.region!r}")

    bus.subscribe("pod_crashed", boom)
    bus.subscribe("pod_crashed", lambda e: seen.append(("c", e.region)))
    with pytest.raises(KeyError, match="pod9"):
        bus.publish(CloudEvent("pod_crashed", region="pod9"))
    # every subscriber heard the event BEFORE the error surfaced
    assert seen == [("a", "pod9"), ("c", "pod9")]


def test_event_bus_collects_multiple_errors():
    bus = EventBus()
    seen = []

    def boom1(e):
        raise KeyError("first")

    def boom2(e):
        raise ValueError("second")

    bus.subscribe("pod_crashed", boom1)
    bus.subscribe("pod_crashed", lambda e: seen.append(e.kind))
    bus.subscribe("pod_crashed", boom2)
    with pytest.raises(EventDeliveryError) as ei:
        bus.publish(CloudEvent("pod_crashed", region="pod1"))
    assert seen == ["pod_crashed"]
    assert [type(e) for _, e in ei.value.errors] == [KeyError, ValueError]
    assert ei.value.event.region == "pod1"


# ------------------------------------------------------- probe guard


def test_observe_transfer_ignores_degenerate_observations():
    probe = MeasuredWanProbe(alpha=0.5, cliff_snap=4.0)
    probe.observe_transfer(1.0, 0.1)             # 80 Mbps belief
    before = probe.estimator.bandwidth_mbps
    probe.observe_transfer(0.0, 1.0)             # zero-byte round
    probe.observe_transfer(1.0, 0.0)             # zero-time round
    probe.observe_transfer(-1.0, 1.0)
    assert probe.estimator.bandwidth_mbps == before
    assert probe.n_observations == 1


# ---------------------------------------------------------- DES billing


def test_simulate_link_failed_bills_retries_and_traffic():
    clouds = [SimCloud("sh", iter_time_s=0.1, units=4),
              SimCloud("cq", iter_time_s=0.1, units=4)]
    sync = SyncConfig("asgd_ga", 4)
    kw = dict(n_iters=60, model_mb=0.6, wan=WANConfig(seed=1))
    base = simulate(clouds, sync, **kw)
    failed = simulate(clouds, sync,
                      events=[SimEvent(1.0, "link_failed", duration_s=2.0,
                                       n_failures=2)], **kw)
    for b, f in zip(base.clouds, failed.clouds):
        assert f.total_s > b.total_s           # retry/backoff wall-clock
        assert f.traffic_mb > b.traffic_mb     # retried bytes at full cost


def test_simulate_pod_crashed_departs_and_stalls_survivors():
    clouds = [SimCloud("sh", iter_time_s=0.1, units=4),
              SimCloud("cq", iter_time_s=0.1, units=4)]
    sync = SyncConfig("asgd_ga", 4)
    kw = dict(n_iters=60, model_mb=0.6, wan=WANConfig(seed=1))
    r = simulate(clouds, sync,
                 events=[SimEvent(1.0, "pod_crashed", region="cq",
                                  pause_s=3.0)], **kw)
    by = {c.region: c for c in r.clouds}
    assert by["sh"].reconfig_s >= 3.0          # barrier rollback stall
    assert by["cq"].total_s < by["sh"].total_s  # cq died early
    with pytest.raises(ValueError, match="unknown sim event kind"):
        SimEvent(0.0, "pod_exploded")


# ----------------------------------------------------- validation + CLI


def test_fault_event_and_retry_policy_validation():
    with pytest.raises(ValueError, match="kind 'melt'"):
        FaultEvent("melt", step=0)
    with pytest.raises(ValueError, match="step must be >= 0"):
        FaultEvent("fail", step=-1)
    with pytest.raises(ValueError, match="attempts must be >= 1"):
        FaultEvent("fail", step=0, attempts=0)
    with pytest.raises(ValueError, match="duration must be >= 1"):
        FaultEvent("flap", step=0, duration=0)
    with pytest.raises(ValueError, match="mode 'panic'"):
        FaultEvent("crash", step=0, mode="panic")
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="timeout_factor"):
        RetryPolicy(timeout_factor=0.5)
    with pytest.raises(ValueError, match="backoff_base"):
        RetryPolicy(backoff_base=0.0)
    assert retry_schedule(1.0, RetryPolicy(), 0) == 0.0
    # 2 failures: 2 timeouts at 4x + backoff 0.5 * (2^0 + 2^1)
    assert retry_schedule(1.0, RetryPolicy(), 2) == pytest.approx(9.5)


def test_parse_faults_grammar_and_errors():
    from repro.launch.train import parse_faults

    assert parse_faults("") is None
    plan = parse_faults("fail:x2@39,timeout:x6@67,corrupt@95,"
                        "flap:x8@119+6,crash:pod1@183:rollback,seed=3")
    assert plan.seed == 3 and len(plan.events) == 5
    assert plan.events[0] == FaultEvent("fail", step=39, attempts=2)
    assert plan.events[1].factor == 6.0
    assert plan.events[3].duration == 6
    assert plan.events[4] == FaultEvent("crash", step=183, pod=1,
                                        mode="rollback")
    assert plan.needs_host_seam and plan.has_crashes
    assert not parse_faults("flap:x4@10+2").needs_host_seam
    with pytest.raises(ValueError, match="missing '@step'"):
        parse_faults("corrupt")
    with pytest.raises(ValueError, match="unknown kind 'melt'"):
        parse_faults("melt@3")
    with pytest.raises(ValueError, match="step must be an integer"):
        parse_faults("corrupt@soon")
    with pytest.raises(ValueError, match="factor must be a number"):
        parse_faults("timeout:xfast@3")
    with pytest.raises(ValueError, match="needs a slowdown factor"):
        parse_faults("flap@3+2")
    with pytest.raises(ValueError, match="'\\+duration' only applies"):
        parse_faults("fail@3+2")
    with pytest.raises(ValueError, match="recovery mode only applies"):
        parse_faults("corrupt@3:rollback")
    with pytest.raises(ValueError, match="needs the dying pod"):
        parse_faults("crash:1@3")
    with pytest.raises(ValueError, match="corrupt takes no argument"):
        parse_faults("corrupt:x2@3")
    with pytest.raises(ValueError, match="seed must be an integer"):
        parse_faults("seed=pi")


def test_launcher_rejects_inconsistent_fault_flags():
    from repro.launch.train import main

    base = ["--preset", "tiny", "--pods", "2", "--steps", "1"]
    with pytest.raises(SystemExit, match="needs a billing transport"):
        main(base + ["--faults", "corrupt@3"])
    with pytest.raises(SystemExit, match="host-seam codec"):
        main(base + ["--faults", "corrupt@3", "--transport", "sim",
                     "--wan-trace", "100@0"])
    with pytest.raises(SystemExit, match="out of range"):
        main(base + ["--faults", "crash:pod5@3", "--transport", "sim",
                     "--wan-trace", "100@0", "--compress-topk", "0.1",
                     "--int8"])
    with pytest.raises(SystemExit, match="needs --faults"):
        main(base + ["--no-tolerance"])


# ------------------------------- crash with an async snapshot in flight


def test_crash_with_async_snapshot_in_flight_recovers_from_durable():
    """Rollback-mode crash while the async engine still has snapshots in
    flight: recovery comes from ``last_durable()`` (the queue drains
    first), the restored state is bit-equal to the barrier capture it
    committed, no torn or partial snapshot is ever visible, and the
    post-rollback degraded rounds keep their invariants (dead row's
    telemetry zeroed)."""
    import shutil
    import tempfile
    import threading

    from repro.checkpoint import checkpoint as ckpt
    from repro.checkpoint.async_engine import (AsyncCheckpointEngine,
                                               list_steps, step_dir)
    from repro.core.sync import is_sync_step

    sync = dataclasses.replace(SYNC, bucket_policy="single", buckets=())
    plan = FaultPlan((FaultEvent("crash", step=3, pod=2,
                                 mode="rollback"),))
    chaos = _transport(plan)
    tr = Trainer(_loss, _init,
                 TrainerConfig(n_pods=3, optimizer="sgd", lr=0.05,
                               sync=sync),
                 transport=chaos)
    st = tr.init_state(jax.random.key(0))
    root = tempfile.mkdtemp(prefix="chaos_snap_")
    try:
        eng = AsyncCheckpointEngine(root, keep=2)
        gate = threading.Event()
        orig = eng._commit_snapshot

        def gated(*item):
            assert gate.wait(timeout=30)
            orig(*item)

        eng._commit_snapshot = gated
        eng.snapshot(st, 0)
        captures = {0: jax.device_get(st)}
        rng = np.random.default_rng(7)
        rollbacks = 0
        for step in range(6):
            x = rng.normal(size=(3, 16, 8)).astype(np.float32)
            y = (x[..., :4] * 0.5).astype(np.float32)
            st, _ = tr.train_step(st, {"x": jnp.asarray(x),
                                       "y": jnp.asarray(y)})
            try:
                st = tr.maybe_sync(st, step, model_mb=0.001)
            except PodUnreachableError:
                # the crash caught the engine mid-commit: release it and
                # recover from the last DURABLE snapshot, not the queue
                assert eng.last_durable() is None
                gate.set()
                st, snap_step = eng.restore_last(like=st)
                rollbacks += 1
                want = captures[snap_step]
                for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(st)):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
            else:
                if is_sync_step(sync, step):
                    eng.snapshot(st, step + 1)
                    captures[step + 1] = jax.device_get(st)
        assert rollbacks == 1
        gate.set()
        eng.wait()
        # no torn state: nothing staged left behind, and every committed
        # snapshot restores cleanly bit-equal to its barrier capture
        assert not any(n.endswith(".tmp") for n in os.listdir(root))
        steps = list_steps(root)
        assert steps == sorted(steps) and len(steps) <= 2
        for s in steps:
            out, got = ckpt.restore(step_dir(root, s), like=st)
            assert got == s
            for a, b in zip(jax.tree.leaves(captures[s]),
                            jax.tree.leaves(out)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # degraded rounds after the rollback keep the mask invariants:
        # the dead pod's telemetry row is zero, the survivors' state sane
        assert chaos.degraded_rounds >= 1
        msg = np.asarray(st.sync_state.msg_norm)
        assert msg[2].sum() == 0.0
        assert np.isfinite(np.asarray(st.params["w"])).all()
        eng.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
