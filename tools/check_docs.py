"""Docs gate: intra-repo links resolve, fenced Python compiles, and every
sync-related launcher flag is documented in exactly one cookbook page.

Three checks over ``docs/*.md`` + the READMEs, cheapest first:

- **Links**: every relative markdown link target (``[x](path)`` with no
  scheme) must exist on disk, resolved against the linking file's
  directory (``#anchors`` are stripped).  Dead intra-repo links are how
  a docs suite rots silently.
- **Snippets**: every fenced ```` ```python ```` block must *compile*
  (``compile(src, ..., "exec")``) — no execution, so docs can show
  snippets with side effects, but renamed APIs in illustrative code at
  least fail on syntax and the snippet author is forced to keep them
  plausible.  Import-level validity is the test suite's job, not the
  docs gate's.
- **Flag ownership**: each sync-related ``repro.launch.train`` flag and
  each serving-plane flag (``repro.launch.serve`` + ``--serve``) must
  appear in *exactly one* of the cookbook pages (sync-tuning /
  control-loops / fault-tolerance / serving — the acceptance rule for
  the operator docs: one page owns each flag, no drift between pages),
  and every flag in the list must still exist in its launcher source
  (catches renames).

Exit code 1 on any failure.  Run:  python tools/check_docs.py
"""
from __future__ import annotations

import os
import re
import sys
from typing import List, Tuple

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.normpath(os.path.join(HERE, ".."))

DOC_FILES = (
    "README.md",
    "benchmarks/README.md",
    "ROADMAP.md",
)

# one cookbook page owns each sync-related launcher flag
FLAG_PAGES = ("docs/sync-tuning.md", "docs/control-loops.md",
              "docs/fault-tolerance.md", "docs/serving.md",
              "docs/checkpointing.md")
SYNC_FLAGS = (
    "--sync", "--interval", "--compress-topk", "--int8", "--value-dtype",
    "--error-feedback", "--overlap-chunks", "--codec-block",
    "--bucket-policy", "--bucket-override", "--bucket-patterns",
    "--adaptive-sync", "--ef-guard", "--wan-trace", "--step-time",
    "--transport", "--topology", "--faults", "--no-tolerance",
    "--async-checkpoint", "--snapshot-every", "--keep-snapshots",
    "--stream-retune", "--stream-cliff", "--stream-hysteresis",
)
LAUNCHER = "src/repro/launch/train.py"

# serving-plane flags live in two launchers; map each to its source so the
# existence check catches renames in either file
SERVING_FLAGS = {
    "--serve": "src/repro/launch/train.py",
    "--scheduler": "src/repro/launch/serve.py",
    "--slots": "src/repro/launch/serve.py",
    "--batch": "src/repro/launch/serve.py",
    "--prompt-len": "src/repro/launch/serve.py",
    "--new-tokens": "src/repro/launch/serve.py",
    "--requests": "src/repro/launch/serve.py",
    "--router": "src/repro/launch/serve.py",
    "--replicas": "src/repro/launch/serve.py",
    "--autoscale": "src/repro/launch/serve.py",
}

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^```(\w*)\s*$")


def _doc_paths() -> List[str]:
    docs = [os.path.join("docs", f) for f in
            sorted(os.listdir(os.path.join(ROOT, "docs")))
            if f.endswith(".md")]
    return docs + [f for f in DOC_FILES
                   if os.path.exists(os.path.join(ROOT, f))]


def check_links(errors: List[str]) -> int:
    n = 0
    for rel in _doc_paths():
        base = os.path.dirname(os.path.join(ROOT, rel))
        with open(os.path.join(ROOT, rel)) as f:
            text = f.read()
        for target in _LINK.findall(text):
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            n += 1
            if not os.path.exists(os.path.normpath(os.path.join(base, path))):
                errors.append(f"{rel}: dead link -> {target}")
    return n


def _python_fences(rel: str) -> List[Tuple[int, str]]:
    blocks, buf, lang, start = [], None, None, 0
    with open(os.path.join(ROOT, rel)) as f:
        for i, line in enumerate(f, 1):
            m = _FENCE.match(line.strip())
            if m and buf is None:
                lang, buf, start = m.group(1).lower(), [], i
            elif line.strip() == "```" and buf is not None:
                if lang == "python":
                    blocks.append((start, "".join(buf)))
                buf = lang = None
            elif buf is not None:
                buf.append(line)
    return blocks


def check_snippets(errors: List[str]) -> int:
    n = 0
    for rel in _doc_paths():
        for lineno, src in _python_fences(rel):
            n += 1
            try:
                compile(src, f"{rel}:{lineno}", "exec")
            except SyntaxError as e:
                errors.append(f"{rel}:{lineno}: python snippet does not "
                              f"compile: {e}")
    return n


def check_flag_ownership(errors: List[str]) -> int:
    with open(os.path.join(ROOT, LAUNCHER)) as f:
        launcher_src = f.read()
    pages = {}
    for rel in FLAG_PAGES:
        with open(os.path.join(ROOT, rel)) as f:
            pages[rel] = f.read()
    for flag in SYNC_FLAGS:
        if f'"{flag}"' not in launcher_src:
            errors.append(f"{LAUNCHER}: sync flag {flag} no longer exists "
                          f"(update tools/check_docs.py SYNC_FLAGS)")
            continue
        owners = [rel for rel, text in pages.items() if flag in text]
        if len(owners) != 1:
            errors.append(
                f"flag {flag} must appear in exactly one of {FLAG_PAGES}, "
                f"found in {owners or 'none'}")
    for flag, launcher in SERVING_FLAGS.items():
        with open(os.path.join(ROOT, launcher)) as f:
            if f'"{flag}"' not in f.read():
                errors.append(
                    f"{launcher}: serving flag {flag} no longer exists "
                    f"(update tools/check_docs.py SERVING_FLAGS)")
                continue
        owners = [rel for rel, text in pages.items() if flag in text]
        if len(owners) != 1:
            errors.append(
                f"flag {flag} must appear in exactly one of {FLAG_PAGES}, "
                f"found in {owners or 'none'}")
    return len(SYNC_FLAGS) + len(SERVING_FLAGS)


def main() -> int:
    errors: List[str] = []
    n_links = check_links(errors)
    n_snips = check_snippets(errors)
    n_flags = check_flag_ownership(errors)
    print(f"docs-check: {len(_doc_paths())} files, {n_links} intra-repo "
          f"links, {n_snips} python snippets, {n_flags} launcher flags")
    for e in errors:
        print(f"[FAIL] {e}")
    if not errors:
        print("[PASS] docs are consistent")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
