"""End-to-end geo-distributed training driver.

Drives the whole stack the way the paper's workflow does:

1. **Control plane** — a ``TrainingRequest`` goes through the scheduler
   function (elastic resource plan, Algorithm 1), PS registration and the
   global communicator (ring topology + WAN identities).
2. **Data plane** — per-pod synthetic token shards (uneven distribution
   supported, per the request's data ratio).
3. **Physical training plane** — the vmapped-over-pods SPMD step with the
   selected synchronization strategy, run for ``--steps`` host steps with
   sync rounds at the strategy's interval; checkpoints via
   ``repro.checkpoint``.

Examples:
  # ~100M dense model, 2 emulated pods, ASGD-GA sync every 8 steps
  PYTHONPATH=src python -m repro.launch.train --preset 100m --pods 2 \
      --sync asgd_ga --interval 8 --steps 200

  # any assigned architecture at smoke scale
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-27b --smoke \
      --steps 50
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.checkpoint.async_engine import AsyncCheckpointEngine
from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import dense
from repro.core.autotune import (AdaptiveSyncController, BucketStats,
                                 BucketedSyncController,
                                 StreamingShipController,
                                 bucket_stats_from_sync_state)
from repro.core.control_plane import (CloudEvent, ElasticityController,
                                      EventBus, ReconfigPlan,
                                      TrainingRequest, build_training_plan)
from repro.core.faults import (FAULT_KINDS, ChaosTransport, FaultEvent,
                               FaultPlan)
from repro.core.scheduler import CloudResources, diff_plans
from repro.core.sync import (BUCKET_CLASSES, BUCKET_POLICIES, VALUE_DTYPES,
                             BucketOverride, BucketSpec,
                             PodUnreachableError, SyncConfig,
                             bucket_weights_of, is_sync_step,
                             traffic_per_step_mb)
from repro.core.topology import (HierarchicalTransport, TopologyPlanner,
                                 TopologySpec)
from repro.core.transport import (MeasuredWanProbe, MeshTransport,
                                  SimTransport)
from repro.core.wan import BandwidthTrace, WANConfig
from repro.data.pipeline import TokenStream
from repro.models.registry import get_model_fns
from repro.training.trainer import (LiveMigrator, Trainer, TrainerConfig,
                                    apply_reconfig)


def parse_events(spec: str) -> Dict[int, list]:
    """Parse ``--events`` into step-indexed control-plane events.

    Comma-separated ``kind:arg@step`` entries:
      ``cloud_left:pod1@40``  ``bandwidth:25@60``  ``straggler:pod0x2.0@80``
      ``cloud_joined:pod7@100`` (joins with the default v5e x4 slice).
    """
    out: Dict[int, list] = {}
    if not spec:
        return out
    for entry in spec.split(","):
        body, step_s = entry.strip().rsplit("@", 1)
        kind, _, arg = body.partition(":")
        step = int(step_s)
        if kind == "cloud_left":
            ev = CloudEvent("cloud_left", region=arg, time_s=step)
        elif kind == "bandwidth":
            ev = CloudEvent("bandwidth_changed", bandwidth_mbps=float(arg),
                            time_s=step)
        elif kind == "straggler":
            region, _, factor = arg.partition("x")
            ev = CloudEvent("straggler_detected", region=region,
                            slowdown=float(factor or 2.0), time_s=step)
        elif kind == "cloud_joined":
            ev = CloudEvent("cloud_joined", time_s=step,
                            resources=CloudResources(
                                region=arg, devices=(("v5e", 4),),
                                data_size=1.0))
        else:
            raise ValueError(f"unknown event kind {kind!r} in {entry!r}")
        out.setdefault(step, []).append(ev)
    return out


def parse_wan_trace(spec: str, steps: int, step_time_s: float
                    ) -> Optional[BandwidthTrace]:
    """Parse ``--wan-trace`` into a :class:`BandwidthTrace`.

    Two forms:
      ``100@0,25@60,80@120``            — explicit mbps@step segments
      ``random:seed=3,base=100,sigma=0.6,period=20``
                                        — lognormal random walk (step units)
    Steps convert to seconds at ``step_time_s`` (the emulated per-step
    wall-clock the WAN timeline is measured in)."""
    if not spec:
        return None
    if spec.startswith("random:") or spec == "random":
        kw = {}
        for part in spec.partition(":")[2].split(","):
            if part:
                k, _, v = part.partition("=")
                kw[k.strip()] = float(v)
        return BandwidthTrace.fluctuating(
            base_mbps=kw.get("base", 100.0),
            duration_s=steps * step_time_s,
            period_s=kw.get("period", 20.0) * step_time_s,
            sigma=kw.get("sigma", 0.6),
            seed=int(kw.get("seed", 0)))
    times, mbps = [], []
    for entry in spec.split(","):
        b, _, at = entry.strip().partition("@")
        times.append(float(at) * step_time_s)
        mbps.append(float(b))
    return BandwidthTrace(times_s=tuple(times), mbps=tuple(mbps))


def parse_bucket_overrides(spec: str) -> tuple:
    """Parse ``--bucket-override`` into :class:`BucketOverride` entries.

    Comma-separated per-bucket entries, colon-separated ``key=value``
    knobs:  ``embed:topk=0.02:dtype=int4:block=1024,norm:dtype=int8``.
    Keys: ``topk`` (compress fraction), ``dtype`` (codec tier) and
    ``block`` (per-bucket top-k block size)."""
    out = []
    if not spec:
        return ()
    for entry in spec.split(","):
        name, _, rest = entry.strip().partition(":")
        kw = {}
        for part in rest.split(":"):
            if not part:
                continue
            k, _, v = part.partition("=")
            if k == "topk":
                kw["compress_topk"] = float(v)
            elif k == "dtype":
                kw["value_dtype"] = v
            elif k == "block":
                kw["codec_block"] = int(v)
            else:
                raise ValueError(
                    f"bucket {name!r}: unknown override key {k!r} in "
                    f"{entry!r} (keys: topk, dtype, block)")
        out.append(BucketOverride(name=name, **kw))
    return tuple(out)


def parse_transport(spec: str, trace: Optional[BandwidthTrace],
                    sync_cfg: SyncConfig):
    """Parse ``--transport`` into a WAN transport (or ``None`` = inline).

    Forms: ``inline`` (legacy in-jit ring, no timing), ``sim`` /
    ``sim:fluct=0.2,latency=0.05,seed=3`` (trace-driven billing — needs
    ``--wan-trace``), ``mesh`` / ``mesh:mbps=5`` (host-timed collectives
    on the device mesh; ``mbps`` adds an emulated WAN hop so measured
    times are WAN-scale).  Sim and mesh both feed a
    :class:`~repro.core.transport.MeasuredWanProbe` — under
    ``--adaptive-sync`` the controller then runs from measured transfer
    times only, with no trace wired to it."""
    kind, _, rest = spec.partition(":")
    known = {"sim": ("fluct", "latency", "seed"), "mesh": ("mbps",),
             "inline": (), "": ()}
    if kind not in known:
        raise ValueError(f"unknown --transport {spec!r} (inline, sim, mesh)")
    kw = {}
    for part in rest.split(","):
        if part:
            k, _, v = part.partition("=")
            k = k.strip()
            if k not in known.get(kind, ()):
                raise ValueError(
                    f"--transport {kind}: unknown option {k!r} in {spec!r} "
                    f"(options: {known.get(kind, ())}) — a dropped knob "
                    f"would run with its default silently")
            kw[k] = float(v)
    if kind in ("", "inline"):
        return None
    if kind == "sim":
        if trace is None:
            raise ValueError("--transport sim needs --wan-trace: the sim "
                             "transport bills transfers against a "
                             "bandwidth trace")
        wan = WANConfig(bandwidth_mbps=trace.mbps[0],
                        fluctuation=kw.get("fluct", 0.25),
                        latency_s=kw.get("latency", 0.05),
                        seed=int(kw.get("seed", 0)))
        return SimTransport(trace, wan, probe=MeasuredWanProbe())
    # kind == "mesh" (kind membership was validated above)
    if not sync_cfg.uses_codec:
        raise ValueError(
            "--transport mesh requires the fused codec (the host-seam "
            "ship times codec payloads): add --compress-topk F --int8")
    return MeshTransport(probe=MeasuredWanProbe(),
                         emulate_mbps=kw.get("mbps"))


def parse_faults(spec: str) -> Optional[FaultPlan]:
    """Parse ``--faults`` into a :class:`FaultPlan` (``None`` when empty).

    Comma-separated fault entries keyed to the sync step they first bite
    at, plus an optional plan seed:
      ``fail:x2@39``       — 2 failed attempts, then success (retried)
      ``timeout:x6@67``    — transfer 6x slower than the bandwidth belief
                             (>= the retry policy's timeout_factor means
                             the attempt is declared failed and retried)
      ``corrupt@95``       — wire bit-flip on the shipped payload (caught
                             by the per-chunk checksums, then re-shipped)
      ``flap:x8@119+6``    — link 8x slower for a 6-round window
      ``crash:pod1@183``   — pod 1 dies; rounds degrade over the
                             surviving membership until it is removed
      ``crash:pod1@183:rollback`` — mid-round crash: the run first rolls
                             back to the last sync-barrier snapshot
      ``seed=3``           — seed of the plan's deterministic stream
    """
    if not spec:
        return None
    events, seed = [], 0
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        if entry.startswith("seed="):
            val = entry.partition("=")[2]
            try:
                seed = int(val)
            except ValueError:
                raise ValueError(
                    f"--faults: seed must be an integer, got {val!r}"
                ) from None
            continue
        body, at_sep, tail = entry.partition("@")
        if not at_sep:
            raise ValueError(
                f"--faults entry {entry!r}: missing '@step' — every fault "
                f"is keyed to the sync step it first bites at")
        kind, _, arg = body.partition(":")
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"--faults entry {entry!r}: unknown kind {kind!r} "
                f"(kinds: {', '.join(FAULT_KINDS)})")
        step_part, _, mode = tail.partition(":")
        step_s, plus, dur_s = step_part.partition("+")
        try:
            step = int(step_s)
        except ValueError:
            raise ValueError(
                f"--faults entry {entry!r}: step must be an integer, "
                f"got {step_s!r}") from None
        kw = {}
        if plus:
            if kind != "flap":
                raise ValueError(
                    f"--faults entry {entry!r}: '+duration' only applies "
                    f"to flap (a window of slowed rounds)")
            try:
                kw["duration"] = int(dur_s)
            except ValueError:
                raise ValueError(
                    f"--faults entry {entry!r}: duration must be an "
                    f"integer number of rounds, got {dur_s!r}") from None
        if mode:
            if kind != "crash":
                raise ValueError(
                    f"--faults entry {entry!r}: trailing {':' + mode!r} — "
                    f"a recovery mode only applies to crash")
            kw["mode"] = mode       # FaultEvent validates the mode name
        if kind in ("timeout", "flap"):
            if not arg.startswith("x"):
                raise ValueError(
                    f"--faults entry {entry!r}: {kind} needs a slowdown "
                    f"factor 'xF' (e.g. {kind}:x6@{step}), got {arg!r}")
            try:
                kw["factor"] = float(arg[1:])
            except ValueError:
                raise ValueError(
                    f"--faults entry {entry!r}: factor must be a number, "
                    f"got {arg[1:]!r}") from None
        elif kind == "fail":
            if arg:
                if not arg.startswith("x"):
                    raise ValueError(
                        f"--faults entry {entry!r}: fail takes an attempt "
                        f"count 'xN' (e.g. fail:x2@{step}), got {arg!r}")
                try:
                    kw["attempts"] = int(arg[1:])
                except ValueError:
                    raise ValueError(
                        f"--faults entry {entry!r}: attempts must be an "
                        f"integer, got {arg[1:]!r}") from None
        elif kind == "crash":
            if not arg.startswith("pod"):
                raise ValueError(
                    f"--faults entry {entry!r}: crash needs the dying pod "
                    f"'podP' (e.g. crash:pod1@{step}), got {arg!r}")
            try:
                kw["pod"] = int(arg[3:])
            except ValueError:
                raise ValueError(
                    f"--faults entry {entry!r}: pod must be an integer "
                    f"index, got {arg[3:]!r}") from None
        elif arg:                   # corrupt takes no argument
            raise ValueError(
                f"--faults entry {entry!r}: corrupt takes no argument "
                f"(the bit-flip lands on the shipped payload itself)")
        events.append(FaultEvent(kind=kind, step=step, **kw))
    return FaultPlan(events=tuple(events), seed=seed)


def preset_100m():
    """~100M-parameter dense decoder for the end-to-end driver."""
    return dense("dense-100m", n_layers=8, d_model=768, n_heads=12,
                 n_kv_heads=4, d_ff=3072, vocab=32_000, tie_embeddings=True,
                 vocab_multiple=128, param_dtype="float32",
                 compute_dtype="float32", remat="none")


def preset_tiny():
    """~1M-parameter decoder for fast CPU system tests."""
    return dense("dense-tiny", n_layers=2, d_model=128, n_heads=4,
                 n_kv_heads=2, d_ff=512, vocab=512, tie_embeddings=True,
                 vocab_multiple=64, param_dtype="float32",
                 compute_dtype="float32", remat="none")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--preset", choices=["100m", "tiny"],
                    help="built-in config instead of --arch")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sync", default="asgd_ga",
                    choices=["asgd", "asgd_ga", "ama", "sma", "asp"])
    ap.add_argument("--interval", type=int, default=8)
    ap.add_argument("--compress-topk", type=float, default=0.0,
                    help="ship only this fraction of accumulated-gradient "
                         "entries (asgd_ga; 0 = dense)")
    ap.add_argument("--int8", action="store_true",
                    help="fused WAN codec: block-local top-k + quantized "
                         "payload (with --compress-topk; --value-dtype "
                         "picks the tier)")
    ap.add_argument("--value-dtype", default="int8", choices=VALUE_DTYPES,
                    help="codec payload tier: int8 (1 B), fp8 e4m3 (1 B, "
                         "relative rounding), int4 (0.5 B nibble-packed)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="EF-SGD: re-inject what the codec dropped at the "
                         "next sync (with --int8)")
    ap.add_argument("--overlap-chunks", type=int, default=1,
                    help=">1: pipeline the ring permute of one chunk with "
                         "the encode of the next")
    ap.add_argument("--codec-block", type=int, default=4096)
    ap.add_argument("--bucket-policy", default="single",
                    choices=list(BUCKET_POLICIES),
                    help="layer-class: partition the codec payload into "
                         f"{BUCKET_CLASSES} groups, each with its own "
                         "(top-k, dtype) knobs, EF telemetry and — under "
                         "--adaptive-sync — its own controller rung")
    ap.add_argument("--bucket-override", default="",
                    help="per-bucket knob overrides (with --bucket-policy "
                         "layer-class), e.g. "
                         "'embed:topk=0.02:dtype=int4:block=1024,"
                         "norm:dtype=int8'; unnamed groups inherit the "
                         "global knobs")
    ap.add_argument("--bucket-patterns", default="default",
                    help="layer-class pattern table: 'default' (four-class),"
                         " 'moe-router' (routers get their own group), or a"
                         " custom 'name=sub1|sub2;...' table "
                         "(see BucketSpec.parse)")
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--data-ratio", default="1:1",
                    help="per-pod data distribution, e.g. 2:1")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--async-checkpoint", action="store_true",
                    help="stream snapshots off the training step: an "
                         "AsyncCheckpointEngine captures the full train "
                         "state at every sync barrier on a background "
                         "thread (atomic step-tagged dirs), and pod "
                         "reconfigurations migrate live from the last "
                         "durable snapshot instead of pausing to "
                         "checkpoint-restore.  See docs/checkpointing.md")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="with --async-checkpoint: also snapshot every N "
                         "steps between barriers (0 = barriers only)")
    ap.add_argument("--keep-snapshots", type=int, default=2,
                    help="with --async-checkpoint: retention depth — the "
                         "engine prunes to the N newest durable snapshots")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--events", default="",
                    help="mid-run cloud events, e.g. "
                         "'cloud_left:pod1@40,bandwidth:25@60' "
                         "(see parse_events)")
    ap.add_argument("--adaptive-sync", action="store_true",
                    help="close the loop: AdaptiveSyncController retunes "
                         "compress_topk / value dtype / interval from EF "
                         "stats + WAN probes (needs --int8 "
                         "--error-feedback --compress-topk)")
    ap.add_argument("--wan-trace", default="",
                    help="emulated bandwidth trace, 'MBPS@step,...' or "
                         "'random:seed=3,base=100,sigma=0.6,period=20' "
                         "(see parse_wan_trace); drives the adaptive "
                         "controller's WAN probe")
    ap.add_argument("--step-time", type=float, default=0.5,
                    help="emulated seconds per training step for the WAN "
                         "trace timeline + controller comm-fraction math")
    ap.add_argument("--ef-guard", type=float, default=0.9,
                    help="adaptive sync: EF-residual ratio bound the "
                         "controller must never trade away")
    ap.add_argument("--stream-retune", action="store_true",
                    help="chunk-granular streaming rounds: ship sync "
                         "payloads chunk by chunk, compare each chunk's "
                         "achieved bandwidth against the measured belief, "
                         "and on a mid-round cliff abort the unsent "
                         "schedule and re-encode the tail one codec rung "
                         "cheaper (EF residual carries the fidelity "
                         "delta).  Needs the fused codec with error "
                         "feedback and a streaming-capable transport with "
                         "a measured probe (sim, mesh, or topology "
                         "tree/auto).  See docs/sync-tuning.md")
    ap.add_argument("--stream-cliff", type=float, default=4.0,
                    help="with --stream-retune: a chunk's achieved "
                         "bandwidth must fall this factor below the "
                         "believed bandwidth to count as a cliff "
                         "(same scale as the probe's cliff-snap)")
    ap.add_argument("--stream-hysteresis", type=int, default=1,
                    help="with --stream-retune: consecutive cliff chunks "
                         "required before the mid-round retune fires "
                         "(1 = react to the first chunk)")
    ap.add_argument("--transport", default="inline",
                    help="who ships sync payloads: 'inline' (legacy in-jit "
                         "ring), 'sim[:fluct=F,latency=L,seed=S]' (billed "
                         "against --wan-trace; feeds the measured probe), "
                         "'mesh[:mbps=B]' (host-timed collectives on the "
                         "device mesh, optional emulated WAN hop).  With "
                         "--adaptive-sync + sim/mesh the controller runs "
                         "from measured transfer times only — no trace is "
                         "wired to it")
    ap.add_argument("--faults", default="",
                    help="seeded chaos schedule keyed to sync steps, e.g. "
                         "'fail:x2@39,timeout:x6@67,corrupt@95,"
                         "flap:x8@119+6,crash:pod1@183,seed=0' "
                         "(see parse_faults); wraps the transport in a "
                         "ChaosTransport with bounded retry/backoff, "
                         "per-chunk checksum verification and degraded "
                         "rounds over the surviving membership")
    ap.add_argument("--no-tolerance", action="store_true",
                    help="with --faults: disable checksums, retries and "
                         "degraded rounds — the baseline the fault-"
                         "tolerant path is measured against (corruption "
                         "decodes into the parameters; a crashed peer "
                         "hangs every round)")
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "tree", "auto"],
                    help="aggregation topology over the plan's regions: "
                         "'ring' (flat pod ring, legacy billing), 'tree' "
                         "(hierarchical transport: intra-region reduce + "
                         "gather/broadcast through the best-connected "
                         "root, auxiliary routes around collapsed links; "
                         "needs --wan-trace), 'auto' (tree/ring chosen by "
                         "the TopologyPlanner from measured link beliefs "
                         "— the third actuator; needs --adaptive-sync).  "
                         "Numerics are identical either way; topology "
                         "changes the billing and the traffic accounting")
    ap.add_argument("--serve", action="store_true",
                    help="after training, run a short continuous-batching "
                         "serving smoke on pod-0's final parameters "
                         "(prefill -> slot insert -> generate over a "
                         "4-slot pool); decoder-only modules only — "
                         "encoder-decoder modules print a skip.  See "
                         "docs/serving.md")
    args = ap.parse_args(argv)

    # ----------------------------------------------------------- model
    if args.preset or (not args.arch):
        cfg = preset_tiny() if args.preset == "tiny" else preset_100m()
        module = "transformer"
        name = cfg.name
    else:
        arch = get_arch(args.arch)
        cfg = arch.smoke if args.smoke else arch.config
        module = arch.module
        name = cfg.name
    fns = get_model_fns(module)

    # ----------------------------------------------------- control plane
    ratio = [float(x) for x in args.data_ratio.split(":")]
    while len(ratio) < args.pods:
        ratio.append(ratio[-1])
    clouds = tuple(
        CloudResources(region=f"pod{i}", devices=(("v5e", 4),),
                       data_size=ratio[i])
        for i in range(args.pods))
    bucket_spec = BucketSpec.parse(args.bucket_patterns)
    if args.bucket_policy == "single" and \
            args.bucket_patterns.strip().lower() not in ("", "default"):
        raise SystemExit(
            "--bucket-patterns is inert without --bucket-policy "
            "layer-class: the single policy packs one unnamed bucket")
    sync_cfg = SyncConfig(args.sync, args.interval,
                          compress_topk=args.compress_topk,
                          quantize_int8=args.int8,
                          value_dtype=args.value_dtype,
                          error_feedback=args.error_feedback,
                          codec_block=args.codec_block,
                          overlap_chunks=args.overlap_chunks,
                          bucket_policy=args.bucket_policy,
                          buckets=parse_bucket_overrides(args.bucket_override),
                          bucket_spec=bucket_spec)
    request = TrainingRequest(model=name, clouds=clouds, sync=sync_cfg,
                              n_iters=args.steps, global_batch=args.batch)
    plan = build_training_plan(request)
    print(f"[control-plane] ring topology: {plan.topology}")
    print(f"[control-plane] PS identities: {plan.ps_identities}")
    print(f"[control-plane] batch split:   {plan.batch_split}")

    # ------------------------------------------------------------- data
    def make_batches(active_plan):
        """Per-pod stacked batch closure for the current plan (rebuilt after
        every applied reconfiguration: pod count / batch split may change)."""
        n_pods = len(active_plan.resource_plans)
        per_pod = max(active_plan.batch_split)  # stacked shape pads to max
        streams = [TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               batch_size=per_pod, seed=7, shard=i,
                               n_shards=n_pods)
                   for i in range(n_pods)]

        def batches(step: int) -> Dict[str, jnp.ndarray]:
            parts = [s.batch(step) for s in streams]
            stacked = {k: jnp.asarray(np.stack([p[k] for p in parts]))
                       for k in parts[0]}
            # elastic batch split: mask out padding rows of trimmed pods
            mask = np.zeros((n_pods, per_pod, args.seq), np.float32)
            for i, b in enumerate(active_plan.batch_split):
                mask[i, :b] = 1.0
            stacked["mask"] = jnp.asarray(mask)
            return stacked

        return batches

    batches = make_batches(plan)

    # ---------------------------------------------------------- trainer
    trace = parse_wan_trace(args.wan_trace, args.steps, args.step_time)
    transport = parse_transport(args.transport, trace, sync_cfg)
    if args.topology != "ring":
        if transport is not None:
            raise SystemExit(
                "--topology tree/auto builds its own hierarchical "
                "transport; it composes with --transport inline only")
        if trace is None:
            raise SystemExit(
                "--topology tree/auto needs --wan-trace: the hierarchical "
                "transport bills the schedule against per-link bandwidth")
        topo_spec = TopologySpec.from_plan(
            plan, kind="tree" if args.topology == "tree" else "ring")
        transport = HierarchicalTransport(
            topo_spec, trace,
            wan=WANConfig(bandwidth_mbps=trace.mbps[0]),
            probe=MeasuredWanProbe())
        print(f"[topology] {args.topology}: regions "
              f"{list(topo_spec.regions)}, start kind {topo_spec.kind}, "
              f"{transport.wan_transfers_per_round} WAN transfers/round")
    if transport is not None:
        print(f"[transport] {args.transport}: "
              f"{type(transport).__name__}"
              + (f", {jax.device_count()} devices"
                 if isinstance(transport, MeshTransport) else ""))
    if not args.async_checkpoint:
        if args.snapshot_every:
            raise SystemExit(
                "--snapshot-every tunes the async snapshot engine's "
                "cadence: it needs --async-checkpoint")
        if args.keep_snapshots != 2:
            raise SystemExit(
                "--keep-snapshots tunes the async snapshot engine's "
                "retention: it needs --async-checkpoint")
    elif args.keep_snapshots < 1:
        raise SystemExit(
            "--keep-snapshots must keep at least the one snapshot the "
            "rollback/migration paths recover from")
    fault_plan = parse_faults(args.faults)
    if args.no_tolerance and fault_plan is None:
        raise SystemExit(
            "--no-tolerance is a --faults baseline switch: it picks how "
            "injected faults are (not) handled, so it needs --faults")
    if fault_plan is not None:
        if transport is None:
            raise SystemExit(
                "--faults needs a billing transport to inject into: add "
                "--transport sim (with --wan-trace) or --transport mesh")
        if fault_plan.needs_host_seam and not sync_cfg.uses_codec:
            raise SystemExit(
                "--faults with fail/timeout/corrupt/crash events injects "
                "at the host-seam codec ship: add --compress-topk F --int8")
        bad = next((ev for ev in fault_plan.events
                    if ev.kind == "crash" and ev.pod >= args.pods), None)
        if bad is not None:
            raise SystemExit(
                f"--faults: crash pod {bad.pod} is out of range for "
                f"--pods {args.pods} (pods are 0..{args.pods - 1})")
        transport = ChaosTransport(transport, fault_plan,
                                   tolerate=not args.no_tolerance)
        print(f"[faults] {len(fault_plan.events)} scheduled events, seed "
              f"{fault_plan.seed}, "
              f"{'tolerant' if transport.tolerate else 'NO-TOLERANCE'}: "
              f"retry budget {transport.retry_policy.max_retries}, "
              f"timeout {transport.retry_policy.timeout_factor}x belief")
    tcfg = TrainerConfig(n_pods=args.pods, optimizer=args.optimizer,
                         lr=args.lr, sync=sync_cfg)
    trainer = Trainer(lambda p, b: fns.loss_fn(p, cfg, b),
                      lambda k: fns.init_params(k, cfg), tcfg,
                      transport=transport)
    state = trainer.init_state(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params)) // args.pods
    model_mb = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(state.params)) / args.pods / 1e6
    print(f"[train] {name}: {n_params:,} params/pod ({model_mb:.1f} MB), "
          f"{args.pods} pods, sync={args.sync}@{args.interval}")
    bweights = (bucket_weights_of(sync_cfg, state.params)
                if sync_cfg.bucket_policy != "single" else None)
    if sync_cfg.uses_codec:
        payload = sync_cfg.payload_mb(model_mb, bucket_weights=bweights)
        print(f"[train] wan codec: top-k {sync_cfg.compress_topk} + "
              f"{sync_cfg.value_dtype}, block {sync_cfg.codec_block}, "
              f"ef={'on' if sync_cfg.error_feedback else 'off'}, "
              f"chunks {sync_cfg.overlap_chunks}, payload "
              f"{payload:.2f} MB/sync "
              f"({model_mb / max(payload, 1e-9):.0f}x below dense)")
        if bweights is not None:
            knobs = {n: sync_cfg.bucket_knobs(n)
                     for n in sync_cfg.bucket_names if bweights.get(n, 0) > 0}
            print(f"[train] bucket groups: "
                  + ", ".join(f"{n} {bweights[n] * model_mb:.1f} MB "
                              f"(topk {f}, {d}, block {blk})"
                              for n, (f, d, blk) in knobs.items()))

    # ------------------------------------------------- streaming retune
    # the chunk-level control loop: first-chunk feedback, at most one
    # mid-round retune, EF residual carries the unsent tail's fidelity
    # delta (docs/sync-tuning.md / docs/control-loops.md)
    stream_ctl = None
    if args.stream_retune:
        if not (sync_cfg.uses_codec and sync_cfg.error_feedback):
            raise SystemExit(
                "--stream-retune re-encodes the unsent tail against the "
                "carried residual: add --compress-topk F --int8 "
                "--error-feedback")
        if transport is None or not getattr(transport,
                                            "supports_streaming", False):
            raise SystemExit(
                "--stream-retune needs a streaming-capable transport: "
                "--transport sim/mesh or --topology tree/auto "
                "(the inline ring has no chunk barrier to observe)")
        if transport.probe is None:
            raise SystemExit(
                "--stream-retune compares achieved vs believed bandwidth: "
                "the transport must carry a measured probe")
        stream_ctl = StreamingShipController(
            sync_cfg, model_mb, cliff_ratio=args.stream_cliff,
            hysteresis=args.stream_hysteresis, ef_guard=args.ef_guard,
            probe_est=transport.probe.estimator)
        trainer.stream = stream_ctl
        print(f"[stream] chunk-granular rounds: cliff {args.stream_cliff}x "
              f"below belief, hysteresis {args.stream_hysteresis}, "
              f"{len(stream_ctl.ladder)} retune rungs")
    else:
        if args.stream_cliff != 4.0:
            raise SystemExit(
                "--stream-cliff tunes the streaming retune's cliff "
                "threshold: it needs --stream-retune")
        if args.stream_hysteresis != 1:
            raise SystemExit(
                "--stream-hysteresis tunes the streaming retune's "
                "debounce: it needs --stream-retune")

    # -------------------------------------------------------- elasticity
    # one control plane: the EventBus carries bandwidth/cloud churn to BOTH
    # actuators — the ElasticityController (re-plan resources) and the
    # AdaptiveSyncController (retune the codec)
    bus = EventBus()
    events = parse_events(args.events)
    # crashes are involuntary cloud_left events: the elasticity controller
    # must be live to re-match the surviving pods when one dies
    chaos = transport if isinstance(transport, ChaosTransport) else None
    need_elastic = bool(events) or (chaos is not None and chaos.tolerate
                                    and chaos.plan.has_crashes)
    # measured mode: the transport's probe owns the bandwidth belief —
    # the controller reads it and nothing else (no trace, no bus events)
    measured = transport is not None and transport.probe is not None
    controller = (ElasticityController(
        plan, bus=bus,
        # the elasticity replan reads the same measured belief the sync
        # controllers act on — one bandwidth picture across both actuators
        probe_est=transport.probe.estimator if measured else None)
        if need_elastic else None)
    tuner = None
    if args.topology == "auto" and not args.adaptive_sync:
        raise SystemExit(
            "--topology auto is the controller's third actuator: it needs "
            "--adaptive-sync (use --topology tree for a fixed hierarchy)")
    if args.adaptive_sync:
        if not (sync_cfg.uses_codec and sync_cfg.error_feedback):
            raise SystemExit(
                "--adaptive-sync requires the fused codec with error "
                "feedback: add --compress-topk F --int8 --error-feedback")
        probe_kw = (dict(probe_est=transport.probe.estimator, bus=None)
                    if measured else dict(bus=bus))
        if args.topology == "auto":
            # the planner shares the transport's link beliefs and actuates
            # through its set_kind — controller decides, transport reshapes
            # (both controllers carry the actuator, under the same
            # fresh-stats-only consultation rule)
            probe_kw["topology"] = TopologyPlanner(
                transport.spec, transport.beliefs, apply=transport.set_kind)
        if sync_cfg.bucket_policy == "layer-class":
            bucket_mb = {n: w * model_mb for n, w in bweights.items()}
            tuner = BucketedSyncController(
                sync_cfg, bucket_mb, args.step_time, ef_guard=args.ef_guard,
                **probe_kw)
            print(f"[autotune] per-bucket rungs: "
                  + ", ".join(f"{n} ({b.model_mb:.1f} MB, "
                              f"{len(b.ladder)} rungs)"
                              for n, b in tuner.buckets.items())
                  + f", ef_guard {args.ef_guard}, "
                  f"budget {tuner.interval_budget}")
        else:
            tuner = AdaptiveSyncController(
                sync_cfg, model_mb, args.step_time, ef_guard=args.ef_guard,
                **probe_kw)
            print(f"[autotune] ladder: "
                  f"{[f'{c.value_dtype}@{c.compress_topk}' for c in tuner.ladder]}"
                  f", ef_guard {args.ef_guard}, budget {tuner.interval_budget}")
        if measured:
            print("[autotune] probe: measured transfer times from the "
                  "transport (no trace wired to the controller)")
        elif trace is not None:
            tuner.observe_wan(trace.at(0.0))
    last_bw = trace.at(0.0) if trace is not None else None
    # several events may fire between two barriers: the reconfig applied at
    # the barrier is composed against the plan that is actually live on the
    # trainer (pending_base), not against the latest event's predecessor
    pending_base = None     # live plan when the first un-applied event fired
    pending_event = None
    pending_crashes = []    # crashed pods awaiting removal at a barrier
    n_reconfigs = 0
    n_retunes = 0
    n_rollbacks = 0

    # async snapshot engine: full-train-state snapshots streamed off the
    # step at every sync barrier; reconfigurations migrate live from the
    # last durable snapshot and crashes roll back to it
    engine = migrator = None
    if args.async_checkpoint:
        snap_root = (f"{args.ckpt_dir}/snapshots" if args.ckpt_dir
                     else tempfile.mkdtemp(prefix="snapshots_"))
        engine = AsyncCheckpointEngine(snap_root, keep=args.keep_snapshots)
        migrator = LiveMigrator(engine)
        engine.snapshot(state, 0,
                        metadata={"model": name, "pods": trainer.cfg.n_pods})
        print(f"[ckpt] async snapshot engine at {snap_root}: keep "
              f"{args.keep_snapshots}, cadence "
              f"{'every ' + str(args.snapshot_every) + ' steps + ' if args.snapshot_every else ''}"
              f"sync barriers")

    # mid-round crash recovery: keep a snapshot of the FULL train state at
    # the last completed sync barrier — a rollback-mode crash unwinds to it
    # (the async engine's durable snapshots subsume this blocking path)
    barrier_dir = None
    if engine is None and chaos is not None and chaos.tolerate \
            and chaos.plan.has_crashes:
        barrier_dir = (f"{args.ckpt_dir}/fault_barrier" if args.ckpt_dir
                       else tempfile.mkdtemp(prefix="fault_barrier_"))

    if barrier_dir is not None:
        ckpt.save(barrier_dir, state, step=0,
                  metadata={"model": name, "pods": trainer.cfg.n_pods})

    # ------------------------------------------------------------- loop
    t0 = time.time()
    losses = []

    def fire_event(ev):
        """Publish a control-plane event on the shared bus and book any
        resulting reconfig for application at the next sync barrier."""
        nonlocal pending_base, pending_event
        rc = next((r for r in bus.publish(ev)
                   if isinstance(r, ReconfigPlan)), None)
        if rc is not None:
            if pending_base is None:
                pending_base = rc.old
            pending_event = ev
            print(f"[elasticity] {ev.kind} at step {step}: "
                  f"diff {rc.diff.summary()}, "
                  f"batch split {rc.new.batch_split}, "
                  f"interval {rc.new.request.sync.interval}")
            if migrator is not None and not rc.diff.is_empty:
                # live migration: pre-move the target-pod-count state from
                # the last durable snapshot off the step path — surviving
                # pods keep stepping until the barrier reconciles
                keep_pods, n_new = rc.pod_transition()
                migrator.stage(state, n_new, keep=keep_pods)
                print(f"[elasticity] staging {n_new}-pod migration from "
                      f"the last durable snapshot (background)")

    for step in range(args.steps):
        # WAN trace: segment changes surface as bandwidth_changed events on
        # the shared bus (the monitor side of the paper's communicator) —
        # the elasticity controller AND the codec autotuner both hear them
        # at the TOP of the step, before this step's transfer is paid
        if trace is not None:
            bw = trace.at_step(step, args.step_time)
            if bw != last_bw:
                fire_event(CloudEvent("bandwidth_changed", bandwidth_mbps=bw,
                                      time_s=step * args.step_time))
                last_bw = bw

        # adaptive sync: the controller decides at the TOP of the step —
        # freshest WAN probe + the last sync's bucket stats (they persist
        # in SyncState) — so a link crash is acted on BEFORE this step's
        # transfer is paid at the stale config
        if tuner is not None and trainer.cfg.n_pods > 1:
            if isinstance(tuner, BucketedSyncController):
                upd = tuner.update(step, bucket_stats_from_sync_state(
                    state.sync_state, trainer.cfg.sync.bucket_names))
            else:
                upd = tuner.update(step, BucketStats.from_sync_state(
                    state.sync_state))
            if upd is not None:
                trainer, state = trainer.retune(state, upd.sync)
                n_retunes += 1
                detail = (f", ef_ratio {upd.stats.ef_ratio:.3f}"
                          if getattr(upd, "stats", None) else "")
                print(f"[autotune] step {step + 1}: {upd.summary()} "
                      f"(payload "
                      f"{upd.sync.payload_mb(model_mb, bucket_weights=bweights):.3f}"
                      f" MB{detail})")

        state, metrics = trainer.train_step(state, batches(step))
        try:
            state = trainer.maybe_sync(state, step, model_mb)
        except PodUnreachableError as crash:
            # mid-round crash: progress since the barrier includes the dead
            # pod's replica and cannot be re-stacked — restore the snapshot
            # (the crash then degrades rounds until the pod is removed)
            if engine is not None:
                state, _ = engine.restore_last(like=state)
            else:
                state, _ = ckpt.restore(barrier_dir, like=state)
            n_rollbacks += 1
            print(f"[faults] pod {crash.pod} unreachable mid-round at "
                  f"step {step + 1}: rolled back to the last sync barrier")
        else:
            at_sync = trainer.cfg.n_pods > 1 and \
                is_sync_step(trainer.cfg.sync, step)
            if engine is not None and (
                    at_sync or (args.snapshot_every and
                                (step + 1) % args.snapshot_every == 0)):
                engine.snapshot(state, step + 1,
                                metadata={"model": name,
                                          "pods": trainer.cfg.n_pods})
            elif barrier_dir is not None and at_sync:
                ckpt.save(barrier_dir, state, step=step + 1,
                          metadata={"model": name,
                                    "pods": trainer.cfg.n_pods})
        losses.append(float(metrics["loss"]))
        if transport is not None and hasattr(transport, "tick"):
            # the sim transport's clock advances by emulated compute time;
            # its sync-round billing (and the measured probe) read it
            transport.tick(args.step_time)

        # control-plane events fire now; the reconfiguration they produce is
        # applied at the next sync barrier via checkpointed pod re-stacking
        if controller is not None:
            if chaos is not None:
                # each crash surfaces on the shared bus exactly once; the
                # resulting reconfig removes the pod at the next barrier,
                # after which the transport stops degrading rounds for it
                for p in chaos.take_new_crashes():
                    pending_crashes.append(p)
                    fire_event(CloudEvent("pod_crashed", region=f"pod{p}",
                                          time_s=step * args.step_time))
            for ev in events.pop(step, ()):
                fire_event(ev)
            at_barrier = (trainer.cfg.sync.strategy == "asgd"
                          or is_sync_step(trainer.cfg.sync, step))
            if pending_base is not None and at_barrier:
                pending = ReconfigPlan(
                    event=pending_event, old=pending_base,
                    new=controller.plan,
                    diff=diff_plans(pending_base.resource_plans,
                                    controller.plan.resource_plans))
                if args.ckpt_dir:
                    ckpt.save(f"{args.ckpt_dir}/pre_reconfig_{step + 1}",
                              state.params, step=step + 1,
                              metadata={"model": name,
                                        "pods": trainer.cfg.n_pods})
                if migrator is not None:
                    # one barrier, not a pause: the staged migration joins
                    # here and the live state is re-stacked in place
                    trainer, state, applied = migrator.reconcile(
                        trainer, state, pending)
                else:
                    trainer, state, applied = apply_reconfig(
                        trainer, state, pending)
                if applied:
                    n_reconfigs += 1
                    plan = pending.new
                    batches = make_batches(plan)
                    if chaos is not None and pending_crashes:
                        for p in pending_crashes:
                            chaos.clear_crash(p)
                        pending_crashes.clear()
                    if tuner is not None:
                        # the reconfig rewrote the live sync settings:
                        # re-anchor the autotuner's belief so its next
                        # update reasons about the knobs actually running
                        tuner.resync(trainer.cfg.sync)
                    if engine is not None:
                        # re-anchor the durable base on the new membership
                        # (an old-pod-count snapshot cannot back a rollback)
                        engine.snapshot(state, step + 1,
                                        metadata={"model": name,
                                                  "pods": trainer.cfg.n_pods})
                    print(f"[elasticity] reconfig applied at barrier "
                          f"step {step + 1}: {trainer.cfg.n_pods} pods, "
                          f"sync interval "
                          f"{trainer.cfg.sync.interval}")
                else:
                    print(f"[elasticity] empty diff at step {step + 1}: "
                          f"no-op, state untouched")
                pending_base = pending_event = None

        if args.log_every and (step + 1) % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {step + 1:5d}  loss {losses[-1]:.4f}  "
                  f"({dt / (step + 1):.2f} s/step)  "
                  f"wan-traffic {trainer.traffic_mb:.1f} MB")
        if args.ckpt_dir and args.ckpt_every and \
                (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, state.params, step=step + 1,
                      metadata={"model": name, "sync": args.sync})

    last_durable = None
    if engine is not None:
        engine.wait()
        durable = engine.last_durable()
        last_durable = durable[0] if durable is not None else None
        engine.close()
        print(f"[ckpt] async engine: {engine.committed} snapshots "
              f"committed, last durable step {last_durable}")

    # -------------------------------------------------- serving smoke
    serve_info = None
    if args.serve:
        if fns.prefill is None:
            print(f"[serve] module '{module}' has no prefill/decode-cache "
                  f"path (encoder-decoder) — skipping serving smoke")
            serve_info = {"skipped": module}
        else:
            from repro.serving.engine import (ContinuousEngine,
                                              ContinuousScheduler)
            pod0 = jax.tree.map(lambda x: x[0], state.params)
            sched = ContinuousScheduler(ContinuousEngine(
                None, pod0, n_slots=4, cache_len=64, cfg=cfg,
                module=module))
            srng = np.random.default_rng(0)
            for _ in range(6):
                plen = int(srng.integers(4, 17))
                sched.submit(srng.integers(0, cfg.vocab_size, plen)
                             .astype(np.int32), max_new=8)
            outs = sched.run()
            serve_info = {
                "requests": len(outs),
                "new_tokens": sum(len(v) for v in outs.values()),
                "decode_steps": sched.engine.decode_steps,
            }
            print(f"[serve] continuous-batching smoke on pod-0 params: "
                  f"{serve_info['requests']} requests, "
                  f"{serve_info['new_tokens']} tokens in "
                  f"{serve_info['decode_steps']} pool decode steps")

    summary = {
        "model": name, "pods": args.pods, "sync": args.sync,
        "interval": args.interval, "steps": args.steps,
        "compress_topk": args.compress_topk, "int8": args.int8,
        "value_dtype": args.value_dtype,
        "error_feedback": args.error_feedback,
        "overlap_chunks": args.overlap_chunks,
        "codec_block": args.codec_block,
        "loss_first": losses[0], "loss_last": float(np.mean(losses[-5:])),
        "wan_traffic_mb": trainer.traffic_mb,
        "reconfigs": n_reconfigs,
        "retunes": n_retunes,
        "final_pods": trainer.cfg.n_pods,
        "final_interval": trainer.cfg.sync.interval,
        "final_tier": trainer.cfg.sync.tier,
        "final_compress_topk": trainer.cfg.sync.compress_topk,
        "final_value_dtype": trainer.cfg.sync.value_dtype,
        "bucket_policy": args.bucket_policy,
        "final_buckets": {
            n: {"compress_topk": f, "value_dtype": d, "codec_block": blk}
            for n in trainer.cfg.sync.bucket_names
            for f, d, blk in [trainer.cfg.sync.bucket_knobs(n)]
        } if args.bucket_policy != "single" else None,
        "max_ef_ratio": round(tuner.max_ef_ratio, 4) if tuner else None,
        "max_ef_ratio_by_bucket": (
            {n: round(r, 4)
             for n, r in tuner.max_ef_ratio_by_bucket.items()}
            if isinstance(tuner, BucketedSyncController) else None),
        "transport": args.transport,
        "stream_retune": args.stream_retune,
        "stream_retunes": (trainer.stream_retunes
                           if stream_ctl is not None else None),
        "stream_rounds": (len(transport.stream_rounds)
                          if stream_ctl is not None else None),
        "stream_decisions": (len(stream_ctl.decisions)
                             if stream_ctl is not None else None),
        "topology": args.topology,
        "final_topology": (transport.spec.kind
                           if isinstance(transport, HierarchicalTransport)
                           else None),
        "topology_switches": (len(transport.switches)
                              if isinstance(transport, HierarchicalTransport)
                              else None),
        "topology_reroutes": (len(transport.reroutes)
                              if isinstance(transport, HierarchicalTransport)
                              else None),
        "wan_transfers_per_round": getattr(
            transport, "wan_transfers_per_round", None),
        "transfers": len(transport.records) if transport else None,
        "measured_bandwidth_mbps": (
            round(transport.probe.estimator.bandwidth_mbps, 3)
            if transport is not None and transport.probe is not None
            and transport.probe.estimator.bandwidth_mbps is not None
            else None),
        "bucket_patterns": args.bucket_patterns,
        "faults": args.faults or None,
        "fault_tolerant": (chaos.tolerate if chaos is not None else None),
        "retries": chaos.retries if chaos is not None else None,
        "retried_mb": (round(chaos.retried_mb, 3)
                       if chaos is not None else None),
        "degraded_rounds": (chaos.degraded_rounds
                            if chaos is not None else None),
        "crash_recoveries": (chaos.crash_recoveries
                             if chaos is not None else None),
        "rollbacks": n_rollbacks if chaos is not None else None,
        "async_checkpoint": args.async_checkpoint,
        "snapshots": engine.committed if engine is not None else None,
        "last_durable_step": last_durable,
        "migrations": migrator.migrations if migrator is not None else None,
        "staged_mb": (round(migrator.staged_mb, 3)
                      if migrator is not None else None),
        "serve": serve_info,
        "wall_s": round(time.time() - t0, 1),
    }
    print(json.dumps(summary, indent=1))
    return summary


if __name__ == "__main__":
    main()
