import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods x 256 v5e chips.
For each combination the step function is jit-compiled with explicit
in/out shardings; we record

  - ``compiled.memory_analysis()``   (per-device bytes — proves it fits)
  - ``compiled.cost_analysis()``     (FLOPs / bytes for the roofline)
  - collective bytes parsed from the partitioned HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute), split
    into intra-pod vs cross-pod by replica-group membership

into ``experiments/dryrun/<arch>__<shape>__<mesh>[__tag].json``, which
``benchmarks/roofline.py`` and EXPERIMENTS.md consume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
      --shape train_4k --mesh multi_pod
  PYTHONPATH=src python -m repro.launch.dryrun --all        # full sweep
"""
import argparse
import json
import math
import re
import time
import traceback
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, Arch, get_arch
from repro.core.sync import SyncConfig
from repro.launch import context as C
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.launch.shapes import (INPUT_SHAPES, InputShape, decode_specs,
                                 prefill_specs, shape_supported,
                                 train_batch_specs)
from repro.models.registry import get_model_fns
from repro.sharding.rules import spec_tree_for_params

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO result type (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _crosses_pod(line: str, pod_boundary: int) -> Optional[bool]:
    """Best-effort: does this collective's replica group span pods?
    Device ids < pod_boundary are pod 0 (mesh is row-major, pod slowest)."""
    m = re.search(r"replica_groups=\{\{([0-9,{} ]*)\}\}", line)
    if m:
        first = m.group(1).split("}")[0]
        ids = [int(x) for x in first.replace("{", "").split(",") if x.strip()]
        return any(i >= pod_boundary for i in ids) and any(
            i < pod_boundary for i in ids)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]", line)
    if m:
        groups, per_group, total = map(int, m.groups())
        if "T(" not in line:
            # contiguous iota groups: group 0 = ids [0, per_group)
            return per_group > pod_boundary
        return None   # transposed iota: undetermined
    return None


def parse_collectives(hlo: str, n_pods: int, n_devices: int) -> Dict:
    """Sum operand/result bytes per collective kind from partitioned HLO."""
    pod_boundary = n_devices // max(n_pods, 1)
    out = {k: 0 for k in _COLLECTIVES}
    cross = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    unknown_cross = 0
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
                     r"([a-z\-]+)", ls)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-done"):
            continue   # counted at the -start (async pair)
        kind = op[:-6] if op.endswith("-start") else op
        if kind not in _COLLECTIVES:
            continue
        nbytes = _shape_bytes(m.group(1))
        out[kind] += nbytes
        counts[kind] += 1
        if n_pods > 1:
            c = _crosses_pod(ls, pod_boundary)
            if c is True:
                cross[kind] += nbytes
            elif c is None:
                unknown_cross += nbytes
    return {
        "bytes_by_kind": out,
        "counts_by_kind": counts,
        "total_bytes": sum(out.values()),
        "cross_pod_bytes": sum(cross.values()),
        "cross_pod_unknown_bytes": unknown_cross,
    }


def _memory_analysis_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                    # pragma: no cover
        return {"error": str(e)}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def _cost_analysis_dict(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:                                    # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


# ---------------------------------------------------------------------------
# lowering builders
# ---------------------------------------------------------------------------


def lower_train(arch: Arch, shape: InputShape, mesh: Mesh, *,
                sync: SyncConfig, optimizer: str,
                config_overrides: Optional[dict] = None):
    setup = C.make_train_setup(arch, mesh, sync=sync, optimizer=optimizer,
                               config_overrides=config_overrides)
    info = mesh_info(mesh)
    bspecs = train_batch_specs(arch, shape, info["n_pods"])
    bshard = C.batch_sharding(bspecs, mesh, setup.rules, stacked=True)

    from repro.sharding.rules import axis_rules
    step = setup.trainer._train_step_impl
    with axis_rules(setup.rules, mesh):
        jf = jax.jit(step, in_shardings=(setup.state_sharding, bshard),
                     out_shardings=(setup.state_sharding, None))
        lowered = jf.lower(setup.abstract_state, bspecs)

    # the sync step (the paper's WAN round) lowered separately
    with axis_rules(setup.rules, mesh):
        js = jax.jit(setup.trainer._sync_step_impl,
                     in_shardings=(setup.state_sharding,),
                     out_shardings=setup.state_sharding)
        sync_lowered = js.lower(setup.abstract_state)
    return lowered, sync_lowered, setup


def lower_prefill(arch: Arch, shape: InputShape, mesh: Mesh):
    cfg = arch.config
    fns = get_model_fns(arch.module)
    rules = C.serve_rules()
    from repro.sharding.rules import axis_rules

    pspecs = prefill_specs(arch, shape)
    pshard = C.batch_sharding(pspecs, mesh, rules, stacked=False)
    param_axes = fns.param_logical_axes(cfg)
    abstract_params = fns.abstract_params(cfg)
    pspec_tree = spec_tree_for_params(param_axes, abstract_params, rules, mesh)
    psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                             is_leaf=lambda x: isinstance(x, P))

    if arch.module == "encdec":
        # enc-dec prefill == encode + build cross/self caches; lower forward
        def fn(params, batch):
            from repro.models import encdec
            logits, _ = encdec.forward(params, cfg, batch["tokens"],
                                       batch["audio_emb"])
            return logits
    else:
        def fn(params, batch):
            return fns.prefill(params, cfg, batch["tokens"], shape.seq_len,
                               positions=batch.get("positions"),
                               patch_emb=batch.get("patch_emb"))

    with axis_rules(rules, mesh):
        jf = jax.jit(fn, in_shardings=(psharding, pshard))
        return jf.lower(abstract_params, pspecs), None, None


def lower_decode(arch: Arch, shape: InputShape, mesh: Mesh):
    cfg = arch.config
    fns = get_model_fns(arch.module)
    rules = C.serve_rules()
    from repro.sharding.rules import axis_rules

    dspecs = decode_specs(arch, shape)
    abstract_params = fns.abstract_params(cfg)
    param_axes = fns.param_logical_axes(cfg)

    def abstract_cache():
        if arch.module == "encdec":
            from repro.models import encdec
            return jax.eval_shape(
                lambda: encdec.init_cache(cfg, shape.global_batch,
                                          shape.seq_len))
        return jax.eval_shape(
            lambda: fns.init_cache(cfg, shape.global_batch, shape.seq_len))

    cache = abstract_cache()
    cache_axes = fns.cache_logical_axes(cfg, shape.seq_len)
    cache_specs = spec_tree_for_params(cache_axes, cache, rules, mesh)
    cache_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs,
                               is_leaf=lambda x: isinstance(x, P))
    pspec_tree = spec_tree_for_params(param_axes, abstract_params, rules, mesh)
    psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                             is_leaf=lambda x: isinstance(x, P))
    tshard = C.batch_sharding(dspecs, mesh, rules, stacked=False)

    def fn(params, token, cache, cache_pos):
        return fns.decode_step(params, cfg, token, cache, cache_pos)

    with axis_rules(rules, mesh):
        jf = jax.jit(fn, in_shardings=(psharding, tshard["token"],
                                       cache_shard, tshard["cache_pos"]),
                     out_shardings=(None, cache_shard))
        lowered = jf.lower(abstract_params, dspecs["token"], cache,
                           dspecs["cache_pos"])
    return lowered, None, None


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def _lower_for(arch: Arch, shape: InputShape, mesh: Mesh, *,
               sync: SyncConfig, optimizer: str,
               config_overrides: Optional[dict]):
    if shape.kind == "train":
        return lower_train(arch, shape, mesh, sync=sync, optimizer=optimizer,
                           config_overrides=config_overrides)
    # serve paths read the (possibly overridden) config off a shallow copy
    if config_overrides:
        arch = Arch(name=arch.name,
                    config=arch.config.replace(**config_overrides),
                    smoke=arch.smoke, module=arch.module)
    if shape.kind == "prefill":
        return lower_prefill(arch, shape, mesh)
    return lower_decode(arch, shape, mesh)


def _extrapolate_costs(arch: Arch, shape: InputShape, mesh: Mesh, *,
                       sync: SyncConfig, optimizer: str,
                       base_overrides: Optional[dict]) -> Dict:
    """XLA-CPU cost_analysis counts while-loop (scan) bodies ONCE.  Compile
    python-unrolled 1-group and 2-group variants; per-group cost = c2 - c1,
    total = (c1 - body) + n_groups * body.  Exact because the stack is
    group-homogeneous."""
    cfg = arch.config
    if base_overrides:
        cfg = cfg.replace(**base_overrides)
    period, n_groups = cfg.period, cfg.n_groups
    info = mesh_info(mesh)

    def one(n_layers: int) -> Dict:
        ov = dict(base_overrides or {})
        ov.update({"n_layers": n_layers, "scan_layers": False})
        lowered, _, _ = _lower_for(arch, shape, mesh, sync=sync,
                                   optimizer=optimizer, config_overrides=ov)
        compiled = lowered.compile()
        cost = _cost_analysis_dict(compiled)
        coll = parse_collectives(compiled.as_text(), info["n_pods"],
                                 info["n_devices"])
        return {"flops": cost.get("flops", 0.0),
                "bytes": cost.get("bytes accessed", 0.0),
                "collective_bytes": float(coll["total_bytes"]),
                "cross_pod_bytes": float(coll["cross_pod_bytes"]),
                "bytes_by_kind": coll["bytes_by_kind"]}

    c1 = one(period)
    c2 = one(2 * period)

    def combine(k1, k2):
        body = max(k2 - k1, 0.0)
        fixed = max(k1 - body, 0.0)
        return fixed + n_groups * body

    out = {k: combine(c1[k], c2[k]) for k in
           ("flops", "bytes", "collective_bytes", "cross_pod_bytes")}
    out["bytes_by_kind"] = {
        k: combine(float(c1["bytes_by_kind"][k]), float(c2["bytes_by_kind"][k]))
        for k in c1["bytes_by_kind"]}
    out["one_group"] = c1
    out["two_group"] = c2
    out["n_groups"] = n_groups
    return out


def run_one(arch_name: str, shape_name: str, mesh_kind: str, *,
            sync_strategy: str = "ama", sync_interval: int = 8,
            sync_compress: float = 0.0,
            optimizer: str = "sgd", tag: str = "",
            config_overrides: Optional[dict] = None,
            out_dir: Optional[str] = None,
            extrapolate: bool = True) -> Dict:
    arch = get_arch(arch_name)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi_pod"))
    info = mesh_info(mesh)

    ok, reason = shape_supported(arch, shape_name)
    rec: Dict = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "mesh_info": info, "tag": tag,
        "params": arch.config.param_count(),
        "active_params": arch.config.active_param_count(),
        "sync": {"strategy": sync_strategy, "interval": sync_interval,
                 "compress_topk": sync_compress},
        "optimizer": optimizer,
        "config_overrides": config_overrides or {},
        "tokens": (shape.global_batch * shape.seq_len
                   if shape.kind != "decode" else shape.global_batch),
    }
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        _write(rec, out_dir)
        return rec

    t0 = time.time()
    sync = SyncConfig(sync_strategy, sync_interval,
                      compress_topk=sync_compress)
    try:
        lowered, sync_lowered, _ = _lower_for(
            arch, shape, mesh, sync=sync, optimizer=optimizer,
            config_overrides=config_overrides)
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo, info["n_pods"],
                                               info["n_devices"])
        rec["memory"] = _memory_analysis_dict(compiled)
        rec["cost"] = _cost_analysis_dict(compiled)
        rec["status"] = "ok"

        if sync_lowered is not None:
            cs = sync_lowered.compile()
            rec["sync_step"] = {
                "collectives": parse_collectives(cs.as_text(), info["n_pods"],
                                                 info["n_devices"]),
                "cost": _cost_analysis_dict(cs),
                "memory": _memory_analysis_dict(cs),
            }

        if extrapolate:
            t2 = time.time()
            rec["extrapolated"] = _extrapolate_costs(
                arch, shape, mesh, sync=sync, optimizer=optimizer,
                base_overrides=config_overrides)
            rec["extrapolate_s"] = round(time.time() - t2, 2)
    except Exception as e:                                    # pragma: no cover
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    _write(rec, out_dir)
    return rec


def _write(rec: Dict, out_dir: Optional[str] = None) -> None:
    d = os.path.abspath(out_dir or OUT_DIR)
    os.makedirs(d, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        d, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {rec['arch']} {rec['shape']} {rec['mesh']} "
          f"-> {rec['status']} ({rec.get('total_s', 0)}s)", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single_pod", "multi_pod"],
                    default="single_pod")
    ap.add_argument("--all", action="store_true",
                    help="full sweep: every arch x shape x both meshes")
    ap.add_argument("--sync", default="ama")
    ap.add_argument("--interval", type=int, default=8)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        jobs = [(a, s, m) for a in ARCH_IDS for s in INPUT_SHAPES
                for m in ("single_pod", "multi_pod")]
    else:
        assert args.arch and args.shape
        jobs = [(args.arch, args.shape, args.mesh)]

    for a, s, m in jobs:
        if args.skip_existing:
            tag = f"__{args.tag}" if args.tag else ""
            p = os.path.join(os.path.abspath(args.out_dir or OUT_DIR),
                             f"{a}__{s}__{m}{tag}.json")
            if os.path.exists(p):
                with open(p) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        continue
        run_one(a, s, m, sync_strategy=args.sync,
                sync_interval=args.interval, optimizer=args.optimizer,
                tag=args.tag, out_dir=args.out_dir)


if __name__ == "__main__":
    main()
