"""Shared launch context: rule sets, abstract state, sharding trees.

Rule sets (logical axis -> mesh axes) per step kind:

- **train**: training state is *stacked* over pods (leading ``pod_stack``
  dim -> ``"pod"``); the in-pod batch shards over ``"data"``; parameters are
  FSDP-sharded over ``"data"`` and tensor-parallel over ``"model"``.
- **decode/prefill**: serving is per-pod-replica, so the request batch
  shards over ``("pod", "data")`` and full KV caches shard their sequence
  dim over ``"model"`` (flash-decoding style).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import Arch
from repro.core.sync import SyncConfig, SyncState
from repro.models.registry import ModelFns, get_model_fns
from repro.optim.optimizers import AdamState
from repro.sharding.rules import (DEFAULT_RULES, LA, is_la, logical_to_spec,
                                  spec_tree_for_params)
from repro.training.trainer import Trainer, TrainerConfig, TrainState

Pytree = Any


def ensure_partitionable_threefry() -> None:
    """Pin the sharding-invariant RNG before any sharded-launch tracing.

    Under jax<0.5 the default (non-partitionable) threefry lowering is not
    sharding-invariant — the SPMD partitioner splits the counter stream per
    device, so a jitted init with sharded out_shardings draws DIFFERENT
    initial parameters than the same init run unsharded (observed as the
    ~0.39 loss divergence on the 8-device debug mesh).  The partitionable
    implementation generates identical bits regardless of how the consumer
    is partitioned (and is the jax>=0.5 default).

    NOTE: jax.config is process-global and the partitionable stream is a
    *different* bit-stream, so this is a deliberate function call at the
    sharded-launch entry point (``make_train_setup``), not an import side
    effect: merely importing launch helpers never flips a process's seeded
    draws mid-stream.  Emulation-only processes keep the legacy streams;
    any process that builds a sharded setup gets the partitionable stream
    consistently for sharded AND unsharded execution from that point on —
    exactly the invariance the parity tests need.
    """
    jax.config.update("jax_threefry_partitionable", True)


def train_rules() -> Dict:
    r = dict(DEFAULT_RULES)
    r.update({
        "pod_stack": "pod",
        "batch": "data",          # in-pod batch (the stacked dim carries pods)
        "fsdp": "data",
        "cache_seq": None,
    })
    return r


def serve_rules() -> Dict:
    r = dict(DEFAULT_RULES)
    r.update({
        "batch": ("pod", "data"),
        "cache_seq": "model",
        "fsdp": "data",
    })
    return r


# ---------------------------------------------------------------------------
# logical axes for composite state
# ---------------------------------------------------------------------------


def stacked_param_axes(fns: ModelFns, cfg) -> Pytree:
    axes = fns.param_logical_axes(cfg)
    return jax.tree.map(lambda la: LA(("pod_stack",) + la.names), axes,
                        is_leaf=is_la)


def opt_state_axes(optimizer: str, param_axes: Pytree) -> Pytree:
    if optimizer == "sgd":
        return ()
    if optimizer == "momentum":
        return param_axes
    if optimizer == "adamw":
        return AdamState(mu=param_axes, nu=param_axes, count=LA(()))
    raise KeyError(optimizer)


def sync_state_axes(sync: SyncConfig, param_axes: Pytree) -> SyncState:
    if sync.strategy in ("asgd_ga", "asp"):
        buf = param_axes
    else:
        buf = jax.tree.map(lambda la: LA((None,)), param_axes, is_leaf=is_la)
    return SyncState(ga_buffer=buf, steps_since_sync=LA(()),
                     significant_frac=LA(()),
                     ef_residual=LA(("pod_stack", None)),
                     tier=LA((None,)),              # (n_buckets,) vector
                     msg_norm=LA(("pod_stack", None)),
                     resid_norm=LA(("pod_stack", None)))


def train_state_axes(fns: ModelFns, cfg, tcfg: TrainerConfig) -> TrainState:
    p = stacked_param_axes(fns, cfg)
    return TrainState(
        params=p,
        opt_state=opt_state_axes(tcfg.optimizer, p),
        sync_state=sync_state_axes(tcfg.sync, p),
        step=LA(()),
    )


def batch_axes(batch: Dict, *, stacked: bool) -> Dict:
    """Logical axes for a flat batch dict (dims: [pod_stack,] batch, ...).

    ``positions`` leads with the M-RoPE component dim (3, B, S); scalars
    (``cache_pos``) are unsharded.
    """
    out = {}
    for k, v in batch.items():
        rank = len(v.shape)
        inner_rank = rank - (1 if stacked else 0)
        if inner_rank == 0:
            base: Tuple = ()
        elif k == "positions":
            base = (None, "batch") + (None,) * (inner_rank - 2)
        else:
            base = ("batch",) + (None,) * (inner_rank - 1)
        out[k] = LA((("pod_stack",) if stacked else ()) + base)
    return out


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------


@dataclass
class TrainSetup:
    arch: Arch
    cfg: Any
    fns: ModelFns
    trainer: Trainer
    abstract_state: Pytree
    state_sharding: Pytree
    rules: Dict


def wrap_loss(fns: ModelFns, cfg) -> Callable:
    def loss(params, batch):
        return fns.loss_fn(params, cfg, batch)
    return loss


def make_train_setup(arch: Arch, mesh: Mesh, *,
                     sync: SyncConfig = SyncConfig(),
                     optimizer: str = "sgd", lr: float = 0.01,
                     smoke: bool = False,
                     config_overrides: Optional[dict] = None) -> TrainSetup:
    ensure_partitionable_threefry()
    cfg = arch.smoke if smoke else arch.config
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    fns = get_model_fns(arch.module)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_pods = sizes.get("pod", 1)
    tcfg = TrainerConfig(n_pods=n_pods, optimizer=optimizer, lr=lr, sync=sync)
    trainer = Trainer(wrap_loss(fns, cfg), lambda k: fns.init_params(k, cfg),
                      tcfg)
    abstract_state = jax.eval_shape(trainer.init_state, jax.random.key(0))
    rules = train_rules()
    axes = train_state_axes(fns, cfg, tcfg)
    specs = spec_tree_for_params(axes, abstract_state, rules, mesh)
    sharding = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))
    return TrainSetup(arch=arch, cfg=cfg, fns=fns, trainer=trainer,
                      abstract_state=abstract_state, state_sharding=sharding,
                      rules=rules)


def batch_sharding(batch_specs: Dict, mesh: Mesh, rules: Dict, *,
                   stacked: bool) -> Dict:
    axes = batch_axes(batch_specs, stacked=stacked)
    specs = spec_tree_for_params(axes, batch_specs, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
