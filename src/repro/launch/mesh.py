"""Production mesh construction.

Single-pod: (16, 16) = 256 v5e chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
``"pod"`` axis is the paper's cloud-partition axis: cheap ICI inside a pod,
scarce inter-pod links across it, synchronized by the strategies in
``repro.core.sync``.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh

# hardware constants (TPU v5e) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~intra-pod)
INTER_POD_BW = 12.5e9             # bytes/s per chip (DCN-ish, conservative)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_pods: int = 2, data: int = 2, model: int = 2) -> Mesh:
    """Small mesh for CPU multi-device tests (8 host devices)."""
    if n_pods > 1:
        return jax.make_mesh((n_pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_info(mesh: Mesh) -> Dict[str, int]:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return {
        "n_devices": mesh.devices.size,
        "n_pods": sizes.get("pod", 1),
        "data": sizes.get("data", 1),
        "model": sizes.get("model", 1),
    }
