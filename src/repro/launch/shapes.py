"""Assigned input shapes and per-architecture ``input_specs``.

``input_specs(arch, shape, n_pods)`` returns ``jax.ShapeDtypeStruct``
stand-ins for every model input — weak-type-correct, shardable, zero
allocation — which is what the dry-run lowers against.

Shape semantics:
- ``train_4k``    -> train_step   (stacked per-pod batches, labels shifted)
- ``prefill_32k`` -> prefill      (build the KV cache from a 32k prompt)
- ``decode_32k``  -> serve_step   (ONE new token, 32k cache)
- ``long_500k``   -> serve_step   (ONE token, 524k cache) — sub-quadratic
  state only (SSM / hybrid / windowed attention); skips recorded per arch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import Arch

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def long_context_supported(arch: Arch) -> Tuple[bool, str]:
    """Which archs run long_500k (see DESIGN.md §long_500k applicability)."""
    cfg = arch.config
    if arch.module == "encdec":
        return False, "enc-dec decoder context is architecturally bounded (448)"
    if cfg.subquadratic:
        return True, ""
    return False, "pure global attention; no windowed variant in model card"


def shape_supported(arch: Arch, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k":
        return long_context_supported(arch)
    return True, ""


def _token_specs(cfg, batch: int, seq: int, *, labels: bool) -> Dict[str, SDS]:
    d: Dict[str, SDS] = {"tokens": SDS((batch, seq), jnp.int32)}
    if labels:
        d["labels"] = SDS((batch, seq), jnp.int32)
    return d


def _extras(arch: Arch, batch: int, seq: int) -> Dict[str, SDS]:
    cfg = arch.config
    cdt = cfg.dtype("compute")
    out: Dict[str, SDS] = {}
    if arch.module == "encdec":
        out["audio_emb"] = SDS((batch, cfg.encoder_ctx, cfg.d_model), cdt)
    if cfg.vision_patches:
        out["patch_emb"] = SDS((batch, cfg.vision_patches, cfg.d_model), cdt)
        out["positions"] = SDS((3, batch, seq), jnp.int32)
    return out


def train_batch_specs(arch: Arch, shape: InputShape, n_pods: int
                      ) -> Dict[str, SDS]:
    """Stacked per-pod train batch: leaves (n_pods, B/pods, ...)."""
    assert shape.global_batch % n_pods == 0
    b = shape.global_batch // n_pods
    flat = {**_token_specs(arch.config, b, shape.seq_len, labels=True),
            **_extras(arch, b, shape.seq_len)}
    return {k: SDS((n_pods,) + v.shape, v.dtype) for k, v in flat.items()}


def prefill_specs(arch: Arch, shape: InputShape) -> Dict[str, SDS]:
    b = shape.global_batch
    return {**_token_specs(arch.config, b, shape.seq_len, labels=False),
            **_extras(arch, b, shape.seq_len)}


def decode_specs(arch: Arch, shape: InputShape) -> Dict[str, SDS]:
    b = shape.global_batch
    out = {"token": SDS((b, 1), jnp.int32),
           "cache_pos": SDS((), jnp.int32)}
    return out
