"""Batched serving driver: prefill + decode with the KV-cache engine.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
      --smoke --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models.registry import get_model_fns
from repro.serving.engine import BatchScheduler, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.smoke
    fns = get_model_fns(arch.module)
    params = fns.init_params(jax.random.key(0), cfg)

    cache_len = args.prompt_len + args.new_tokens
    engine = ServingEngine(arch, params, cache_len=cache_len, use_smoke=True)
    sched = BatchScheduler(engine, batch_size=args.batch)

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        sched.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                     args.new_tokens)

    t0 = time.time()
    results = sched.run()
    dt = time.time() - t0
    total_new = sum(len(v) for v in results.values())
    print(json.dumps({
        "arch": args.arch, "requests": len(results),
        "new_tokens": total_new, "wall_s": round(dt, 2),
        "tok_per_s": round(total_new / dt, 1),
    }, indent=1))
    for rid, toks in sorted(results.items())[:3]:
        print(f"req {rid}: {toks[:12].tolist()} ...")
    return results


if __name__ == "__main__":
    main()
