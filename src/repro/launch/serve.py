"""Serving launcher: geo-routed continuous batching over replica slot pools.

The serving counterpart of ``repro.launch.train``: builds one slot-pool
engine per regional replica (all replicas share the same parameters), a
:class:`~repro.serving.router.GeoRouter` that places each request by
measured link beliefs + catalog cost/latency, and — with ``--autoscale``
— a :class:`~repro.core.control_plane.ServingElasticityController` that
sizes the replica count from the offered load before the engines are
built (on TPU the serving control plane, like the training one, runs at
plan time).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
      --scheduler continuous --slots 4 --prompt-len 32 --new-tokens 16
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
      --replicas 3 --router balanced --requests 12
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.core.control_plane import CloudEvent, ServingElasticityController
from repro.models.registry import get_model_fns
from repro.serving.engine import (BatchScheduler, ContinuousEngine,
                                  ContinuousScheduler, ServingEngine)
from repro.serving.router import GeoRouter, ReplicaSpec, ROUTER_MODES

# replica regions are assigned from this palette in order
REGIONS = ("us-east", "eu-west", "ap-south", "us-west", "eu-north",
           "ap-north", "sa-east", "af-south")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--scheduler", default="continuous",
                    choices=["batch", "continuous"],
                    help="'continuous': slot-pool engine with per-slot "
                         "insert/evict (prefill->insert->generate); "
                         "'batch': run-to-completion baseline — a group "
                         "decodes until every member finishes before the "
                         "next group is admitted")
    ap.add_argument("--slots", type=int, default=4,
                    help="slot-pool width per replica (continuous): max "
                         "requests decoding concurrently in one engine")
    ap.add_argument("--batch", type=int, default=4,
                    help="group size for the run-to-completion baseline "
                         "(--scheduler batch)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--router", default="balanced", choices=ROUTER_MODES,
                    help="placement objective: 'nearest' (network seconds "
                         "on measured link beliefs), 'cheapest' (catalog "
                         "$/token), 'balanced' (network + queue + compute "
                         "seconds)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="regional replicas serving the same parameters "
                         "(with --autoscale: the replica-count ceiling)")
    ap.add_argument("--autoscale", action="store_true",
                    help="size the replica count from the offered load "
                         "via the ServingElasticityController (scale-up "
                         "immediate, scale-down after hysteresis) instead "
                         "of taking --replicas literally")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.config
    fns = get_model_fns(arch.module)
    params = fns.init_params(jax.random.key(0), cfg)
    cache_len = args.prompt_len + args.new_tokens

    # ------------------------------------------------- replica scaling
    n_replicas, autoscale_reason = args.replicas, None
    if args.autoscale:
        ctrl = ServingElasticityController(
            replicas=1, max_replicas=max(1, args.replicas))
        # offered load: the whole request burst over one observation window
        d = ctrl.handle(CloudEvent("load_changed", time_s=0.0,
                                   rps=args.requests / 10.0))
        n_replicas, autoscale_reason = ctrl.replicas, d.reason
    regions = REGIONS[:n_replicas]

    router = GeoRouter([ReplicaSpec(region=r, n_slots=args.slots)
                        for r in regions], mode=args.router)
    if args.scheduler == "continuous":
        scheds = {r: ContinuousScheduler(ContinuousEngine(
            arch, params, n_slots=args.slots, cache_len=cache_len,
            use_smoke=args.smoke)) for r in regions}
    else:
        scheds = {r: BatchScheduler(
            ServingEngine(arch, params, cache_len=cache_len,
                          use_smoke=args.smoke),
            batch_size=args.batch) for r in regions}

    # ------------------------------------------------- route + submit
    rng = np.random.default_rng(0)
    placed = {}                      # global rid -> (region, local rid)
    for rid in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        src = regions[int(rng.integers(len(regions)))]
        region = router.route(rid, src, plen, args.new_tokens)
        placed[rid] = (region, scheds[region].submit(prompt,
                                                     args.new_tokens))

    t0 = time.time()
    by_region = {r: s.run() for r, s in scheds.items()}
    dt = time.time() - t0
    results = {}
    for rid, (region, local) in placed.items():
        results[rid] = by_region[region][local]
        router.complete(rid)

    total_new = sum(len(v) for v in results.values())
    print(json.dumps({
        "arch": args.arch, "scheduler": args.scheduler,
        "router": args.router, "replicas": list(regions),
        "autoscale": autoscale_reason,
        "requests": len(results), "new_tokens": total_new,
        "routes": {r: sum(1 for reg, _ in placed.values() if reg == r)
                   for r in regions},
        "wall_s": round(dt, 2),
        "tok_per_s": round(total_new / dt, 1),
    }, indent=1))
    for rid, toks in sorted(results.items())[:3]:
        print(f"req {rid}: {np.asarray(toks)[:12].tolist()} ...")
    return results


if __name__ == "__main__":
    main()
