"""Sharding-aware pytree checkpointing (no orbax dependency).

Saves a pytree as a flat ``.npz`` plus a JSON treedef manifest with dtype /
shape / step metadata.  ``save`` gathers addressable shards to host;
``restore`` re-places leaves onto a target sharding tree when one is given
(so a checkpoint written under one mesh can be restored under another —
needed when the elastic scheduler changes the resource plan between runs,
the paper's rescheduling path).

Writes are atomic: both files are staged in a tmp sibling directory and
``os.replace``d into place, arrays first, manifest last.  The manifest is
the commit record — it carries the byte size and CRC of the arrays file it
was written against, and ``restore`` verifies them — so a crash mid-save
leaves either the previous intact checkpoint or a mismatch that raises
:class:`CheckpointCorruptError`, never a silently torn restore.
"""
from __future__ import annotations

import json
import io
import os
import shutil
import tempfile
import zlib
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


class CheckpointCorruptError(RuntimeError):
    """The checkpoint directory is torn: a file is missing, truncated, or
    fails the manifest's integrity record.  Callers distinguish this
    ("fall back to an older snapshot") from shape/key mismatches (a
    programming error)."""


def _flatten_with_paths(tree: Pytree):
    # jax.tree.flatten_with_path only exists in newer jax; the tree_util
    # spelling works across the versions this repo supports
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def _host_leaf(x) -> np.ndarray:
    a = np.asarray(jax.device_get(x))
    if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
        # npz has no cast for ml_dtypes extension types; store upcast
        # (bf16 ⊂ fp32, lossless) — the manifest keeps the true dtype
        a = a.astype(np.float32)
    return a


def _commit(directory: str, host_leaves, manifest: dict) -> None:
    """Stage arrays + manifest in a tmp sibling dir, then ``os.replace``
    into ``directory`` (arrays first, manifest last — the manifest, which
    records the arrays' size and CRC, is the commit point)."""
    parent = os.path.dirname(os.path.abspath(directory)) or "."
    tmp = tempfile.mkdtemp(prefix=".ckpt-stage-", dir=parent)
    try:
        apath = os.path.join(tmp, _ARRAYS)
        np.savez(apath, **{f"a{i}": a for i, a in enumerate(host_leaves)})
        with open(apath, "rb") as f:
            blob = f.read()
        manifest = dict(manifest,
                        arrays_bytes=len(blob),
                        arrays_crc32=zlib.crc32(blob))
        mpath = os.path.join(tmp, _MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(apath, os.path.join(directory, _ARRAYS))
        os.replace(mpath, os.path.join(directory, _MANIFEST))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def build_manifest(keys, leaves, host_leaves, step: int,
                   metadata: Optional[dict]) -> dict:
    return {
        "step": step,
        "keys": keys,
        "dtypes": [str(x.dtype) for x in leaves],
        "shapes": [list(a.shape) for a in host_leaves],
        "metadata": metadata or {},
    }


def save(directory: str, tree: Pytree, step: int = 0,
         metadata: Optional[dict] = None) -> None:
    os.makedirs(directory, exist_ok=True)
    keys, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [_host_leaf(x) for x in leaves]
    _commit(directory, host_leaves,
            build_manifest(keys, leaves, host_leaves, step, metadata))


def load_manifest(directory: str) -> dict:
    path = os.path.join(directory, _MANIFEST)
    try:
        with open(path) as f:
            return json.load(f)
    except json.JSONDecodeError as e:
        raise CheckpointCorruptError(
            f"checkpoint manifest {path!r} is not valid JSON "
            f"(torn write?): {e}") from e


def _load_arrays(directory: str, manifest: dict):
    """Read + integrity-check ``arrays.npz`` against the manifest."""
    apath = os.path.join(directory, _ARRAYS)
    try:
        with open(apath, "rb") as f:
            blob = f.read()
    except FileNotFoundError as e:
        raise CheckpointCorruptError(
            f"checkpoint {directory!r} has a manifest but no {_ARRAYS} "
            f"(torn write?)") from e
    want_bytes = manifest.get("arrays_bytes")
    if want_bytes is not None:   # absent only in pre-atomic checkpoints
        if len(blob) != want_bytes:
            raise CheckpointCorruptError(
                f"checkpoint {apath!r} is {len(blob)} bytes but the "
                f"manifest committed {want_bytes} (truncated or torn "
                f"write)")
        if zlib.crc32(blob) != manifest.get("arrays_crc32"):
            raise CheckpointCorruptError(
                f"checkpoint {apath!r} fails its manifest CRC "
                f"(corrupted or torn write)")
    try:
        with np.load(io.BytesIO(blob)) as data:
            return {k: data[f"a{i}"]
                    for i, k in enumerate(manifest["keys"])}
    except Exception as e:   # BadZipFile / npy-header ValueError / KeyError
        raise CheckpointCorruptError(
            f"checkpoint {apath!r} is unreadable (truncated or torn "
            f"write): {e}") from e


def _resize_pod_dim(arr: np.ndarray, n_new: int, how: str) -> np.ndarray:
    """Host-side pod-dimension resize, matching ``repro.core.sync``'s
    transforms: grow seeds new pods with the mean replica ("mean") or copies
    of pod 0 ("clone"); shrink keeps the first ``n_new`` pods, shifted so
    their mean equals the old global mean ("mean") or plainly dropped
    ("drop" / "clone")."""
    n_old = arr.shape[0]
    if n_new == n_old:
        return arr
    if n_new > n_old:
        if how == "drop":
            raise ValueError(
                f"pod_resize='drop' cannot grow {n_old} -> {n_new} pods")
        if how == "clone":
            fill = np.broadcast_to(arr[:1], (n_new - n_old,) + arr.shape[1:])
        else:
            fill = np.broadcast_to(
                arr.astype(np.float32).mean(axis=0, keepdims=True),
                (n_new - n_old,) + arr.shape[1:]).astype(arr.dtype)
        return np.concatenate([arr, fill], axis=0)
    kept = arr[:n_new]
    if how == "mean":
        shift = (arr.astype(np.float32).mean(axis=0, keepdims=True)
                 - kept.astype(np.float32).mean(axis=0, keepdims=True))
        kept = (kept.astype(np.float32) + shift).astype(arr.dtype)
    return kept


def restore(directory: str, like: Pytree,
            shardings: Optional[Pytree] = None,
            pod_resize: Optional[str] = None) -> tuple[Pytree, int]:
    """Restore into the structure of ``like``; keys are matched by path so
    the pytree may be re-laid-out.  Returns (tree, step).

    ``pod_resize`` ("mean" | "clone" | "drop") makes the restore
    resharding-aware for the elasticity engine: a checkpoint written with one
    leading pod-dimension size restores into a model stacked for another —
    the leading dimension is grown/shrunk with the named transform while all
    trailing dimensions must still match exactly.

    Raises :class:`CheckpointCorruptError` when the directory's files are
    missing, truncated, or fail the manifest's size/CRC record.
    """
    if pod_resize not in (None, "mean", "clone", "drop"):
        raise ValueError(f"unknown pod_resize mode {pod_resize!r}")
    manifest = load_manifest(directory)
    by_key = _load_arrays(directory, manifest)

    keys, leaves, treedef = _flatten_with_paths(like)
    out = []
    for k, ref in zip(keys, leaves):
        if k not in by_key:
            raise KeyError(f"checkpoint missing leaf {k!r}")
        arr = by_key[k]
        if tuple(arr.shape) != tuple(ref.shape):
            if (pod_resize is not None and arr.ndim == len(ref.shape)
                    and arr.ndim >= 1
                    and tuple(arr.shape[1:]) == tuple(ref.shape[1:])):
                arr = _resize_pod_dim(arr, ref.shape[0], pod_resize)
            else:
                raise ValueError(
                    f"shape mismatch for {k!r}: ckpt {arr.shape} "
                    f"vs model {ref.shape}")
        out.append(arr.astype(ref.dtype))

    if shardings is not None:
        sh_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        out = [jax.device_put(a, s) for a, s in zip(out, sh_leaves)]
    else:
        out = [jax.device_put(a) for a in out]
    return jax.tree.unflatten(treedef, out), manifest["step"]
