"""Asynchronous checkpoint engine: snapshots streamed off the training step.

The training loop's only blocking cost is :meth:`AsyncCheckpointEngine.
snapshot` — it captures the (immutable) device arrays, kicks off the
device-to-host copies asynchronously, and enqueues the rest to a background
worker thread.  The worker finalizes the host copies into *donated host
buffers* (a per-leaf pool reused across snapshots, so steady-state
snapshotting allocates nothing), serializes them with the checkpoint
layer's writer, and commits each snapshot as a step-tagged directory
(``step_00000042``) via a single atomic directory rename — a crash at any
point leaves only fully-committed snapshots plus an ignorable ``.tmp``
staging dir, never a torn checkpoint.

API contract (what the trainer/launcher rely on):

- ``snapshot(tree, step)`` returns immediately; at most ``max_inflight``
  snapshots queue before it applies backpressure.
- ``wait()`` blocks until the queue drains and re-raises any background
  failure as :class:`SnapshotError`.
- ``last_durable()`` names the newest *committed* snapshot — the recovery
  base for rollback crash handling and the migration source for live pod
  resizes.  It only ever advances after the atomic rename.
- ``restore_last(like=...)`` drains the queue, then restores the newest
  durable snapshot, falling back to older ones if an externally-damaged
  directory fails its integrity check.
- Retention: after each commit the engine prunes to the ``keep`` newest
  snapshots.

This is the subsystem that makes aggressive elasticity affordable: the
live-migration path (``repro.training.trainer.LiveMigrator``) stages pod
grow/shrink state from ``last_durable()`` while surviving pods keep
stepping, so a reconfiguration costs one sync barrier instead of a full
checkpoint-restore pause.
"""
from __future__ import annotations

import os
import queue
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from . import checkpoint as ckpt

Pytree = Any

STEP_PREFIX = "step_"
_STEP_RE = re.compile(rf"^{STEP_PREFIX}(\d+)$")
_STOP = object()


class SnapshotError(RuntimeError):
    """A background snapshot failed; raised by ``wait()`` / ``snapshot()``
    on the next call so the failure cannot pass silently."""


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"{STEP_PREFIX}{step:08d}")


def list_steps(root: str) -> List[int]:
    """Steps of fully-committed snapshots under ``root``, ascending.  Only
    directories holding a manifest count — a ``.tmp`` staging dir from an
    interrupted commit is invisible here."""
    out = []
    try:
        names = os.listdir(root)
    except FileNotFoundError:
        return out
    for name in names:
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(root, name, ckpt._MANIFEST)):
            out.append(int(m.group(1)))
    return sorted(out)


class AsyncCheckpointEngine:
    """Background-thread snapshot engine over step-tagged directories."""

    def __init__(self, root: str, *, keep: int = 2, max_inflight: int = 2):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.root = os.fspath(root)
        self.keep = int(keep)
        os.makedirs(self.root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, int(max_inflight)))
        self._lock = threading.Lock()
        self._error: Optional[Exception] = None
        self._durable: List[int] = list_steps(self.root)
        self._host_bufs: Dict[int, np.ndarray] = {}   # donated, reused
        self.committed = 0
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="ckpt-engine")
        self._thread.start()

    # ------------------------------------------------------------ enqueue
    def snapshot(self, tree: Pytree, step: int,
                 metadata: Optional[dict] = None) -> None:
        """Enqueue an async snapshot of ``tree`` tagged ``step``.  Returns
        once the device arrays are captured and their host copies kicked
        off — the serialize + commit happens on the worker thread."""
        if self._closed:
            raise RuntimeError("engine is closed")
        self._raise_pending()
        keys, leaves, _ = ckpt._flatten_with_paths(tree)
        for x in leaves:
            # start the D2H DMA now so the worker's device_get finds the
            # bytes already on host (jax arrays are immutable, so the
            # training step can race ahead safely)
            if hasattr(x, "copy_to_host_async"):
                x.copy_to_host_async()
        self._q.put((keys, leaves, int(step), dict(metadata or {})))

    # ------------------------------------------------------------- worker
    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                try:
                    self._commit_snapshot(*item)
                except Exception as e:   # noqa: BLE001 — surfaced by wait()
                    with self._lock:
                        self._error = e
            finally:
                self._q.task_done()

    def _host_copy(self, i: int, x) -> np.ndarray:
        """Finalize one leaf's host copy into the donated buffer pool."""
        a = ckpt._host_leaf(x)
        buf = self._host_bufs.get(i)
        if (buf is not None and buf.shape == a.shape
                and buf.dtype == a.dtype and buf is not a):
            np.copyto(buf, a)
            return buf
        if not (a.flags.owndata and a.flags.writeable
                and a.flags.c_contiguous):
            a = np.array(a)   # owned, writable donated buffer
        self._host_bufs[i] = a
        return a

    def _commit_snapshot(self, keys, leaves, step: int, metadata: dict) -> None:
        host = [self._host_copy(i, x) for i, x in enumerate(leaves)]
        manifest = ckpt.build_manifest(keys, leaves, host, step, metadata)
        final = step_dir(self.root, step)
        tmp = final + ".tmp"
        for stale in (tmp, final):
            if os.path.isdir(stale):
                shutil.rmtree(stale)
        os.makedirs(tmp)
        ckpt._commit(tmp, host, manifest)
        os.replace(tmp, final)               # the atomic commit point
        with self._lock:
            self._durable = sorted(set(self._durable) | {step})
            self.committed += 1
        self._prune()

    def _prune(self) -> None:
        with self._lock:
            drop = self._durable[:-self.keep]
            self._durable = self._durable[-self.keep:]
        for s in drop:
            shutil.rmtree(step_dir(self.root, s), ignore_errors=True)

    # -------------------------------------------------------------- query
    def _raise_pending(self) -> None:
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise SnapshotError(f"background snapshot failed: {err!r}") from err

    def wait(self) -> None:
        """Block until every enqueued snapshot is committed (or failed);
        re-raise the first background failure."""
        self._q.join()
        self._raise_pending()

    def last_durable(self) -> Optional[Tuple[int, str]]:
        """(step, directory) of the newest committed snapshot, or None.
        Never names an in-flight or torn snapshot — the step list only
        advances after the atomic directory rename."""
        with self._lock:
            if not self._durable:
                return None
            s = self._durable[-1]
        return s, step_dir(self.root, s)

    def restore_last(self, like: Pytree, *,
                     pod_resize: Optional[str] = None) -> Tuple[Pytree, int]:
        """Drain the queue, then restore the newest durable snapshot.

        A snapshot this engine committed can only be damaged externally
        (disk truncation, an operator's stray rm); on a
        ``CheckpointCorruptError`` the damaged directory is skipped and the
        next-newest durable snapshot is tried."""
        self.wait()
        while True:
            with self._lock:
                if not self._durable:
                    raise FileNotFoundError(
                        f"no durable snapshot under {self.root!r}")
                s = self._durable[-1]
            try:
                return ckpt.restore(step_dir(self.root, s), like=like,
                                    pod_resize=pod_resize)
            except ckpt.CheckpointCorruptError:
                with self._lock:
                    if self._durable and self._durable[-1] == s:
                        self._durable.pop()

    # ------------------------------------------------------------ shutdown
    def close(self) -> None:
        """Drain the queue and stop the worker.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._q.put(_STOP)
        self._thread.join()
        self._raise_pending()

    def __enter__(self) -> "AsyncCheckpointEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def blocking_equivalent(tree: Pytree, step: int, directory: str,
                        metadata: Optional[dict] = None) -> str:
    """Reference semantics for one engine snapshot: the blocking
    ``checkpoint.save`` of the same tree at the same step, written under
    ``directory`` with the engine's step-dir naming.  The property suite
    asserts an async snapshot is bit-identical to this."""
    d = step_dir(directory, step)
    ckpt.save(d, tree, step=step, metadata=metadata)
    return d
