"""Model configuration system.

A model is described by a :class:`ModelConfig` holding global dimensions plus a
repeating *layer pattern* (a list of :class:`LayerSpec`).  ``n_layers`` must be a
multiple of the pattern period; the decoder stack is executed as a
``jax.lax.scan`` over ``n_layers // period`` *groups*, each group applying the
pattern positions in order with its own parameters.  This keeps the lowered HLO
small (one group body regardless of depth), which matters both for compile time
and for remat policies.

The pattern mechanism expresses every assigned architecture:

- dense llama-style        -> period 1:  [attn+mlp]
- gemma2 local:global 1:1  -> period 2:  [attn(window)+mlp, attn+mlp]
- gemma3 local:global 5:1  -> period 6:  [attn(window)]*5 + [attn]
- qwen3-moe / kimi-k2      -> period 1:  [attn+moe]
- jamba 1:7 attn:mamba     -> period 8:  mamba*3, attn, mamba*4 with MoE on odd
- mamba2                   -> period 1:  [ssm+(no mlp)]
- whisper / qwen2-vl       -> dense patterns + modality stubs (see encdec.py /
                              transformer.py input handling)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

ATTN = "attn"
SSM = "ssm"


@dataclass(frozen=True)
class LayerSpec:
    """One position inside the repeating layer pattern."""

    kind: str = ATTN                 # "attn" | "ssm"
    window: Optional[int] = None     # sliding-window size (None = global attention)
    moe: bool = False                # MoE FFN instead of dense FFN
    mlp: bool = True                 # whether the position has an FFN at all

    def __post_init__(self):
        if self.kind not in (ATTN, SSM):
            raise ValueError(f"unknown layer kind: {self.kind!r}")


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance auxiliary loss weight
    router_z_weight: float = 1e-3     # router-z loss weight


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128      # N, the SSM state size
    head_dim: int = 64        # P, channels per SSM head
    n_groups: int = 1         # B/C groups (Mamba2 "G")
    conv_width: int = 4       # causal depthwise conv width
    chunk_size: int = 256     # SSD chunk length
    expand: int = 2           # d_inner = expand * d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default: d_model // n_heads
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # position encoding: "rope" | "mrope" | "learned" | "none"
    pos_embed: str = "rope"
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # qwen2-vl M-RoPE split of head_dim/2

    # gemma-style logit soft-capping (0 = disabled)
    attn_softcap: float = 0.0
    final_softcap: float = 0.0

    # encoder-decoder (whisper): number of encoder layers, encoder context length
    encoder_layers: int = 0
    encoder_ctx: int = 0              # e.g. 1500 audio frames
    # vlm stub: number of vision patch embeddings prepended to the text sequence
    vision_patches: int = 0

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # dtypes (string so the config is hashable / serializable)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # attention implementation: "xla" (jnp reference), "xla_chunked"
    # (flash-style blockwise in pure XLA), "pallas", "pallas_interpret"
    attention_impl: str = "xla"
    # embedding lookup: "gather" | "onehot" (vocab-sharded-friendly matmul)
    embed_impl: str = "gather"
    # MoE dispatch: "global" (one sort over the whole token set) |
    # "grouped" (sort/scatter local to each batch row; only the expert
    # einsum's all-to-all crosses shards)
    moe_dispatch: str = "global"
    # expert-weight sharding: "fsdp" (gather weights over data axis) | "ff"
    # (shard the expert FFN hidden dim over data; activations reduce instead
    # of weights gathering — wins when weights >> activations per step)
    moe_param_shard: str = "fsdp"
    # remat policy for the scanned group body: "none" | "full" | "dots"
    remat: str = "full"
    # scan over layer groups (compact HLO) vs python-unrolled groups (exact
    # cost_analysis — XLA-CPU counts while bodies once, so the dry-run
    # extrapolates totals from small unrolled variants)
    scan_layers: bool = True
    # vocab padding multiple (sharding-friendly)
    vocab_multiple: int = 2048

    # ---------------------------------------------------------------- helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_groups(self) -> int:
        if self.n_layers % self.period:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {self.period}"
            )
        return self.n_layers // self.period

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim

    def dtype(self, which: str) -> jnp.dtype:
        return jnp.dtype({"param": self.param_dtype, "compute": self.compute_dtype}[which])

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def max_window(self) -> Optional[int]:
        """Largest sliding window in the pattern, None if any position is global attn."""
        w = 0
        for spec in self.pattern:
            if spec.kind == ATTN:
                if spec.window is None:
                    return None
                w = max(w, spec.window)
        return w or None

    @property
    def has_attention(self) -> bool:
        return any(s.kind == ATTN for s in self.pattern)

    @property
    def has_ssm(self) -> bool:
        return any(s.kind == SSM for s in self.pattern)

    @property
    def has_moe(self) -> bool:
        return any(s.moe for s in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """True if the arch can serve unbounded context with bounded-or-linear
        attention state: SSM positions carry O(1) state; hybrids qualify
        because only a small minority of layers keep a (sequence-sharded) KV
        cache; local:global dense patterns qualify because local layers keep
        a bounded ring cache.  Pure global-attention stacks do not."""
        if not self.has_attention:
            return True
        if self.has_ssm:
            return True   # hybrid: attention is a small minority of layers
        n_global = sum(1 for s in self.pattern if s.kind == ATTN and s.window is None)
        if n_global == 0:
            return True
        # mostly-local dense patterns (gemma2/gemma3)
        return len(self.pattern) > 1 and n_global < len(self.pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; used for 6ND roofline)."""
        D, V = self.d_model, self.padded_vocab
        Dh, H, K = self.resolved_head_dim, self.n_heads, self.n_kv_heads
        total = V * D                                  # token embedding
        if not self.tie_embeddings:
            total += D * V                             # lm head
        total += D                                     # final norm
        per_pattern = 0
        for spec in self.pattern:
            per_pattern += D                           # pre-norm
            if spec.kind == ATTN:
                per_pattern += D * H * Dh + 2 * D * K * Dh + H * Dh * D
            else:
                c = self.ssm
                d_in = self.d_inner
                n_h = self.ssm_heads
                # in_proj: z, x, B, C, dt
                zxbcdt = 2 * d_in + 2 * c.n_groups * c.state_dim + n_h
                per_pattern += D * zxbcdt
                per_pattern += c.conv_width * (d_in + 2 * c.n_groups * c.state_dim)
                per_pattern += 3 * n_h                 # A_log, dt_bias, D skip
                per_pattern += d_in                    # gated norm
                per_pattern += d_in * D                # out_proj
            if spec.mlp:
                per_pattern += D                       # post/mlp norm
                if spec.moe:
                    e = self.moe.num_experts
                    per_pattern += D * e               # router
                    per_pattern += e * 3 * D * self.d_ff
                else:
                    per_pattern += 3 * D * self.d_ff
        total += per_pattern * self.n_groups
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if not self.has_moe:
            return self.param_count()
        D = self.d_model
        total = self.param_count()
        for spec in self.pattern:
            if spec.moe:
                e, k = self.moe.num_experts, self.moe.top_k
                inactive = (e - k) * 3 * D * self.d_ff
                total -= inactive * self.n_groups
        return total
