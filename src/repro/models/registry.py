"""Uniform model-function dispatch over the two model modules."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.models import encdec, transformer
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ModelFns:
    init_params: Callable
    abstract_params: Callable
    param_logical_axes: Callable
    loss_fn: Callable
    forward: Callable
    decode_step: Callable
    init_cache: Callable
    cache_logical_axes: Callable
    prefill: Any = None


def get_model_fns(module: str) -> ModelFns:
    if module == "transformer":
        return ModelFns(
            init_params=transformer.init_params,
            abstract_params=transformer.abstract_params,
            param_logical_axes=transformer.param_logical_axes,
            loss_fn=transformer.loss_fn,
            forward=transformer.forward,
            decode_step=transformer.decode_step,
            init_cache=transformer.init_cache,
            cache_logical_axes=transformer.cache_logical_axes,
            prefill=transformer.prefill,
        )
    if module == "encdec":
        return ModelFns(
            init_params=encdec.init_params,
            abstract_params=encdec.abstract_params,
            param_logical_axes=encdec.param_logical_axes,
            loss_fn=encdec.loss_fn,
            forward=encdec.forward,
            decode_step=encdec.decode_step,
            init_cache=encdec.init_cache,
            cache_logical_axes=encdec.cache_logical_axes,
        )
    raise KeyError(module)
