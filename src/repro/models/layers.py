"""Core neural-net layers as pure functions over explicit parameter pytrees.

Conventions
-----------
- Arrays are ``(B, S, D)`` activations; attention uses ``(B, S, H, Dh)``.
- Every layer has ``<name>_init(key, ...) -> params`` and ``<name>(params, ...)``.
- Params are created in ``cfg.param_dtype``; compute runs in ``cfg.compute_dtype``
  with fp32 softmax/normalization accumulation.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, LayerSpec

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE and qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = _rope_freqs(x.shape[-1], theta)                     # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]                        # (B, S, 1, half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: Tuple[int, ...],
) -> jnp.ndarray:
    """Multimodal RoPE (qwen2-vl): ``positions`` is (3, B, S) — (t, h, w).

    The ``head_dim/2`` frequency slots are split into ``sections`` (summing to
    head_dim/2); slot group i rotates by the i-th position component.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(x.shape[-1], theta)                     # (half,)
    # pick per-frequency-slot position component
    comp = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )                                                           # (half,)
    pos = jnp.take(positions, comp, axis=0)                     # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)          # (B, S, half)
    angles = pos * freqs                                        # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def position_embed(cfg: ModelConfig, x: jnp.ndarray, positions) -> jnp.ndarray:
    if cfg.pos_embed == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.pos_embed == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return x


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Decode-time cache for one attention layer position.

    ``k``/``v``: (B, C, K, Dh) where C is the cache capacity (full seq_len for
    global layers, window size for sliding-window layers).  ``ring`` marks a
    circular buffer (sliding window).
    """

    k: jnp.ndarray
    v: jnp.ndarray


def attention_init(key, cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pdt = cfg.dtype("param")
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, D, H * Dh, pdt),
        "wk": dense_init(kk, D, K * Dh, pdt),
        "wv": dense_init(kv, D, K * Dh, pdt),
        "wo": dense_init(ko, H * Dh, D, pdt, scale=1.0 / math.sqrt(H * Dh)),
    }


def _softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


def attn_bias(
    q_pos: jnp.ndarray,          # (B, Sq) absolute positions of queries
    k_pos: jnp.ndarray,          # (B, Sk) absolute positions of keys
    k_valid: Optional[jnp.ndarray],  # (B, Sk) bool — False for empty cache slots
    causal: bool,
    window: Optional[int],
) -> jnp.ndarray:
    """Additive fp32 bias of shape (B, 1, Sq, Sk)."""
    ok = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), bool)
    if causal:
        ok &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        ok &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if k_valid is not None:
        ok &= k_valid[:, None, :]
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[:, None, :, :]


def sdpa_reference(q, k, v, bias, softcap: float = 0.0) -> jnp.ndarray:
    """Pure-XLA scaled-dot-product attention.

    q: (B, Sq, H, Dh); k, v: (B, Sk, K, Dh) with H a multiple of K (GQA).
    bias: (B, 1, Sq, Sk) additive fp32.
    """
    B, Sq, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qh = q.reshape(B, Sq, K, G, Dh)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qh.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(Dh)
    logits = _softcap(logits, softcap)
    logits = logits + bias[:, :, None, :, :]  # (B,K,G,Sq,Sk) + (B,1,1,Sq,Sk)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def sdpa_chunked(q, k, v, q_pos, k_pos, *, causal: bool,
                 window: Optional[int], softcap: float = 0.0,
                 chunk: int = 512) -> jnp.ndarray:
    """Flash-style blockwise attention in pure XLA (lax.scan over key chunks
    with online softmax).  Never materializes the (Sq, Sk) score matrix —
    peak attention memory drops from O(Sq*Sk) to O(Sq*chunk).  This is the
    beyond-paper memory-term optimization for the XLA (non-Pallas) path;
    numerics match ``sdpa_reference`` to fp32 rounding."""
    B, Sq, H, Dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    c = min(chunk, Sk)
    pad = (-Sk) % c
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1_000_000)
    nc = (Sk + pad) // c

    qh = q.reshape(B, Sq, K, G, Dh).astype(jnp.float32) / math.sqrt(Dh)
    kc = jnp.moveaxis(k.reshape(B, nc, c, K, Dh), 1, 0).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(B, nc, c, K, Dh), 1, 0).astype(jnp.float32)
    pc = jnp.moveaxis(k_pos.reshape(B, nc, c), 1, 0)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp                       # (B,c,K,Dh),(B,c,K,Dh),(B,c)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qh, kb)
        s = _softcap(s, softcap)
        vis = pb[:, None, :] > (-1_000_000 + 1)    # padding slots off
        if causal:
            vis &= pb[:, None, :] <= q_pos[:, :, None]
        if window is not None:
            vis &= pb[:, None, :] > (q_pos[:, :, None] - window)
        vis = jnp.broadcast_to(vis[:, None, None, :, :], s.shape)
        s = jnp.where(vis, s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        m_safe = jnp.where(m_new <= -1e29, 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(vis, p, 0.0)
        alpha = jnp.where(m <= -1e29, 0.0, jnp.exp(m - m_safe))
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = alpha * acc + jnp.einsum("bkgqs,bskd->bkgqd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l)                            # (B,K,G,Sq,Dh)
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dh).astype(q.dtype)


def _sdpa(cfg: ModelConfig, q, k, v, bias, *, causal: bool, window,
          positions=None) -> jnp.ndarray:
    """Dispatch between XLA reference, XLA chunked, and the Pallas kernel."""
    if cfg.attention_impl in ("pallas", "pallas_interpret") and q.shape[1] > 1:
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v,
            causal=causal,
            window=window,
            softcap=cfg.attn_softcap,
            bias=bias,
            interpret=cfg.attention_impl == "pallas_interpret",
        )
    if cfg.attention_impl == "xla_chunked" and q.shape[1] > 1 \
            and positions is not None:
        k_pos = jnp.broadcast_to(jnp.arange(k.shape[1])[None],
                                 (k.shape[0], k.shape[1]))
        return sdpa_chunked(q, k, v, positions, k_pos, causal=causal,
                            window=window, softcap=cfg.attn_softcap)
    return sdpa_reference(q, k, v, bias, softcap=cfg.attn_softcap)


def attention_apply(
    params: dict,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: jnp.ndarray,                     # (B, S, D)
    positions: jnp.ndarray,             # (B, S) or (3, B, S) for mrope
    *,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    cache_pos: Optional[jnp.ndarray] = None,   # scalar int32: tokens already cached
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Self- or cross-attention with optional decode cache.

    Modes:
      - train/prefill: ``cache is None`` — full-sequence attention; returns
        (out, None).
      - decode: ``cache`` given, S == 1 — appends K/V at ``cache_pos`` (ring
        buffer when ``spec.window`` is set and capacity == window) and attends
        over the cache; returns (out, new_cache).
      - cross-attention: ``kv_override`` provides precomputed (k, v).
    """
    B, S, D = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = cfg.dtype("compute")
    x = x.astype(cdt)

    q = (x @ params["wq"].astype(cdt)).reshape(B, S, H, Dh)

    tok_pos = positions if positions.ndim == 2 else positions[0]  # (B, S)

    if kv_override is not None:
        k, v = kv_override
        q = position_embed(cfg, q, positions) if cfg.pos_embed != "none" else q
        k_pos = jnp.broadcast_to(jnp.arange(k.shape[1])[None], (B, k.shape[1]))
        bias = attn_bias(tok_pos, k_pos, None, causal=False, window=None)
        out = _sdpa(cfg, q, k, v, bias, causal=False, window=None)
        return (out.reshape(B, S, H * Dh) @ params["wo"].astype(cdt)), None

    k = (x @ params["wk"].astype(cdt)).reshape(B, S, K, Dh)
    v = (x @ params["wv"].astype(cdt)).reshape(B, S, K, Dh)
    q = position_embed(cfg, q, positions)
    k = position_embed(cfg, k, positions)

    if cache is None:
        if cfg.attention_impl == "xla_chunked" and S > 1:
            bias = None   # masks are built chunk-wise from positions
        else:
            bias = attn_bias(tok_pos, tok_pos, None, causal=causal,
                             window=spec.window)
        out = _sdpa(cfg, q, k, v, bias, causal=causal, window=spec.window,
                    positions=tok_pos)
        return (out.reshape(B, S, H * Dh) @ params["wo"].astype(cdt)), None

    # ------------------------------------------------------------- decode
    assert S == 1, "decode path expects a single query token"
    C = cache.k.shape[1]
    ring = spec.window is not None and C == spec.window
    slot = (cache_pos % C) if ring else jnp.minimum(cache_pos, C - 1)
    new_k = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))

    slots = jnp.arange(C)
    if ring:
        # slot j holds absolute position p = cache_pos - ((cache_pos - j) mod C)
        k_pos_row = cache_pos - ((cache_pos - slots) % C)
        k_valid_row = k_pos_row >= 0
    else:
        k_pos_row = slots
        k_valid_row = slots <= cache_pos
    k_pos = jnp.broadcast_to(k_pos_row[None], (B, C))
    k_valid = jnp.broadcast_to(k_valid_row[None], (B, C))

    bias = attn_bias(tok_pos, k_pos, k_valid, causal=True, window=spec.window)
    out = sdpa_reference(q, new_k.astype(cdt), new_v.astype(cdt), bias,
                         softcap=cfg.attn_softcap)
    out = out.reshape(B, S, H * Dh) @ params["wo"].astype(cdt)
    return out, KVCache(k=new_k, v=new_v)


def init_kv_cache(
    cfg: ModelConfig, spec: LayerSpec, batch: int, seq_len: int, dtype=None
) -> KVCache:
    """Allocate an empty decode cache for one attention position."""
    dtype = dtype or cfg.dtype("compute")
    cap = min(spec.window, seq_len) if spec.window is not None else seq_len
    shape = (batch, cap, cfg.n_kv_heads, cfg.resolved_head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# Dense (SwiGLU) MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    pdt = cfg.dtype("param")
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, D, F, pdt),
        "wu": dense_init(ku, D, F, pdt),
        "wd": dense_init(kd, F, D, pdt, scale=1.0 / math.sqrt(F)),
    }


def mlp_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    cdt = x.dtype
    g = jax.nn.silu((x @ params["wg"].astype(cdt)).astype(jnp.float32)).astype(cdt)
    u = x @ params["wu"].astype(cdt)
    return (g * u) @ params["wd"].astype(cdt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig) -> dict:
    V, D = cfg.padded_vocab, cfg.d_model
    pdt = cfg.dtype("param")
    p = {"tokens": dense_init(key, V, D, pdt, scale=1.0)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(jax.random.fold_in(key, 1), D, V, pdt)
    return p


def embed_apply(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    cdt = cfg.dtype("compute")
    if cfg.embed_impl == "onehot":
        # one-hot matmul: distributes cleanly over a vocab-sharded table
        # (XLA SPMD handles x @ W_sharded with a partial-sum all-reduce),
        # avoiding the gather path's involuntary full rematerialization of
        # the embedding table on every device.
        oh = jax.nn.one_hot(tokens, cfg.padded_vocab, dtype=cdt)
        from repro.sharding.rules import shard
        oh = shard(oh, "batch", "seq", "vocab")
        emb = oh @ params["tokens"].astype(cdt)
    else:
        emb = params["tokens"].astype(cdt)[tokens]
    if cfg.tie_embeddings:
        emb = emb * jnp.asarray(math.sqrt(cfg.d_model), cdt)
    return emb


def unembed_apply(params: dict, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    cdt = cfg.dtype("compute")
    if cfg.tie_embeddings:
        logits = h @ params["tokens"].astype(cdt).T
    else:
        logits = h @ params["lm_head"].astype(cdt)
    logits = _softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits
