"""Encoder-decoder backbone (whisper-tiny).

Per the modality carve-out, the mel-spectrogram + conv frontend is a STUB:
the encoder consumes precomputed frame embeddings ``(B, encoder_ctx, D)``
delivered by ``input_specs()``.  The transformer backbone itself — encoder
self-attention stack, decoder with causal self-attention + cross-attention,
and the decode cache machinery — is fully implemented.

Adaptation note (recorded in DESIGN.md): the backbone uses RoPE rather than
Whisper's learned absolute embeddings — positionally equivalent for the
backbone-scale experiments here and uniform with the rest of the framework.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import LayerSpec, ModelConfig
from repro.models.transformer import softmax_cross_entropy, scan_groups
from repro.sharding.rules import LA
from repro.sharding.rules import shard

Params = Dict[str, Any]
_SPEC = LayerSpec()  # plain global attention


class EncDecCache(NamedTuple):
    self_kv: L.KVCache          # (G, B, C, K, Dh) stacked over decoder groups
    cross_k: jnp.ndarray        # (G, B, Senc, K, Dh)
    cross_v: jnp.ndarray


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, cross: bool) -> Params:
    D = cfg.d_model
    pdt = cfg.dtype("param")
    ks = jax.random.split(key, 3)
    p = {
        "ln1": L.rmsnorm_init(D, pdt),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(D, pdt),
        "mlp": L.mlp_init(ks[1], cfg),
    }
    if cross:
        p["lnx"] = L.rmsnorm_init(D, pdt)
        p["xattn"] = L.attention_init(ks[2], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    ke, kd, kemb = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": L.embed_init(kemb, cfg),
        "encoder": {
            "blocks": jax.vmap(lambda k: _block_init(k, cfg, cross=False))(enc_keys),
            "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype("param")),
        },
        "decoder": {
            "blocks": jax.vmap(lambda k: _block_init(k, cfg, cross=True))(dec_keys),
            "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype("param")),
        },
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


def param_logical_axes(cfg: ModelConfig) -> Params:
    g = lambda *names: LA(("layers",) + names)  # noqa: E731
    attn = {"wq": g("fsdp", "heads"), "wk": g("fsdp", "kv_heads"),
            "wv": g("fsdp", "kv_heads"), "wo": g("heads", "fsdp")}
    mlp = {"wg": g("fsdp", "d_ff"), "wu": g("fsdp", "d_ff"), "wd": g("d_ff", "fsdp")}
    block = {"ln1": {"scale": g(None)}, "attn": dict(attn),
             "ln2": {"scale": g(None)}, "mlp": dict(mlp)}
    dec_block = dict(block)
    dec_block["lnx"] = {"scale": g(None)}
    dec_block["xattn"] = dict(attn)
    embed: Params = {"tokens": LA(("vocab", "fsdp"))}
    if not cfg.tie_embeddings:
        embed["lm_head"] = LA(("fsdp", "vocab"))
    return {
        "embed": embed,
        "encoder": {"blocks": block, "final_norm": {"scale": LA((None,))}},
        "decoder": {"blocks": dec_block, "final_norm": {"scale": LA((None,))}},
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(params: Params, cfg: ModelConfig, audio_emb: jnp.ndarray) -> jnp.ndarray:
    """audio_emb: (B, Senc, D) stub frame embeddings -> (B, Senc, D)."""
    cdt = cfg.dtype("compute")
    h = audio_emb.astype(cdt)
    B, Senc, _ = h.shape
    pos = jnp.broadcast_to(jnp.arange(Senc, dtype=jnp.int32)[None], (B, Senc))

    def body(h, p):
        hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        out, _ = L.attention_apply(p["attn"], cfg, _SPEC, hn, pos, causal=False)
        h = h + out
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + L.mlp_apply(p["mlp"], hn)
        return shard(h, "batch", "seq", "d_model"), None

    h, _ = scan_groups(lambda c, x: (body(c, x)[0], 0), h,
                       params["encoder"]["blocks"],
                       length=cfg.encoder_layers, use_scan=cfg.scan_layers)
    return L.rmsnorm(params["encoder"]["final_norm"], h, cfg.norm_eps)


def _cross_kv(p: Params, cfg: ModelConfig, enc: jnp.ndarray):
    B, Senc, _ = enc.shape
    K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = cfg.dtype("compute")
    k = (enc @ p["xattn"]["wk"].astype(cdt)).reshape(B, Senc, K, Dh)
    v = (enc @ p["xattn"]["wv"].astype(cdt)).reshape(B, Senc, K, Dh)
    return k, v


# ---------------------------------------------------------------------------
# decoder forward (teacher-forced training)
# ---------------------------------------------------------------------------


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            audio_emb: jnp.ndarray) -> Tuple[jnp.ndarray, dict]:
    """Teacher-forced decoder over (B, S) tokens given stub audio embeddings."""
    enc = encode(params, cfg, audio_emb)
    B, Sq = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    h = L.embed_apply(params["embed"], cfg, tokens)
    h = shard(h, "batch", "seq", "d_model")

    def body(h, p):
        hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        out, _ = L.attention_apply(p["attn"], cfg, _SPEC, hn, pos, causal=True)
        h = h + out
        hn = L.rmsnorm(p["lnx"], h, cfg.norm_eps)
        out, _ = L.attention_apply(p["xattn"], cfg, _SPEC, hn, pos,
                                   kv_override=_cross_kv(p, cfg, enc))
        h = h + out
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + L.mlp_apply(p["mlp"], hn)
        return shard(h, "batch", "seq", "d_model"), None

    h, _ = scan_groups(lambda c, x: (body(c, x)[0], 0), h,
                       params["decoder"]["blocks"],
                       length=cfg.n_layers, use_scan=cfg.scan_layers)
    h = L.rmsnorm(params["decoder"]["final_norm"], h, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], cfg, h)
    return shard(logits, "batch", "seq", "vocab"), {}


def loss_fn(params: Params, cfg: ModelConfig, batch: dict) -> Tuple[jnp.ndarray, dict]:
    logits, _ = forward(params, cfg, batch["tokens"], batch["audio_emb"])
    ce = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce, {"loss": ce, "ce": ce}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               enc: Optional[jnp.ndarray] = None,
               params: Optional[Params] = None) -> EncDecCache:
    K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = cfg.dtype("compute")
    G = cfg.n_layers
    kv = L.KVCache(
        k=jnp.zeros((G, batch, seq_len, K, Dh), cdt),
        v=jnp.zeros((G, batch, seq_len, K, Dh), cdt))
    Senc = cfg.encoder_ctx
    if enc is not None and params is not None:
        ck, cv = jax.vmap(
            lambda p: _cross_kv(p, cfg, enc))(params["decoder"]["blocks"])
    else:
        ck = jnp.zeros((G, batch, Senc, K, Dh), cdt)
        cv = jnp.zeros((G, batch, Senc, K, Dh), cdt)
    return EncDecCache(self_kv=kv, cross_k=ck, cross_v=cv)


def cache_logical_axes(cfg: ModelConfig, seq_len: int):
    return EncDecCache(
        self_kv=L.KVCache(k=LA(("layers", "batch", "cache_seq", "kv_heads", None)),
                          v=LA(("layers", "batch", "cache_seq", "kv_heads", None))),
        cross_k=LA(("layers", "batch", None, "kv_heads", None)),
        cross_v=LA(("layers", "batch", None, "kv_heads", None)),
    )


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: EncDecCache, cache_pos: jnp.ndarray
                ) -> Tuple[jnp.ndarray, EncDecCache]:
    """One decoder token; cross-attention reads the precomputed encoder K/V."""
    B = token.shape[0]
    pos = jnp.broadcast_to(cache_pos.astype(jnp.int32), (B, 1))
    h = L.embed_apply(params["embed"], cfg, token)

    def body(h, xs):
        p, kv, ck, cv = xs
        hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        out, new_kv = L.attention_apply(p["attn"], cfg, _SPEC, hn, pos,
                                        cache=kv, cache_pos=cache_pos)
        h = h + out
        hn = L.rmsnorm(p["lnx"], h, cfg.norm_eps)
        out, _ = L.attention_apply(p["xattn"], cfg, _SPEC, hn, pos,
                                   kv_override=(ck, cv))
        h = h + out
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + L.mlp_apply(p["mlp"], hn)
        return h, new_kv

    h, new_kv = scan_groups(
        body, h,
        (params["decoder"]["blocks"], cache.self_kv, cache.cross_k,
         cache.cross_v),
        length=cfg.n_layers, use_scan=cfg.scan_layers)
    h = L.rmsnorm(params["decoder"]["final_norm"], h, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], cfg, h)
    return logits, cache._replace(self_kv=new_kv)
