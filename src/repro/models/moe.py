"""Mixture-of-Experts FFN with sort-based token dispatch (TPU-native).

Instead of the Mesh-TensorFlow one-hot dispatch einsum — whose ``(tokens, E,
capacity)`` mask tensor is prohibitively large at assigned-architecture scale
(e.g. kimi-k2: 384 experts) — tokens are *sorted by expert id* and scattered
into a dense ``(E, capacity, D)`` buffer.  This keeps peak memory at exactly
the buffer the expert matmuls need, and the expert dimension shards cleanly
over the ``"model"`` mesh axis (expert parallelism: XLA inserts the
all-to-all between the data-sharded token dim and the model-sharded expert
dim, matching the paper-era PS all-to-all role on TPU).

Top-k token-choice routing with capacity dropping; auxiliary load-balance and
router-z losses are returned for the trainer to add to the objective.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.rules import shard


def moe_init(key, cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    pdt = cfg.dtype("param")
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, D, E, pdt),
        "wg": (jax.random.normal(kg, (E, D, F), jnp.float32) / math.sqrt(D)).astype(pdt),
        "wu": (jax.random.normal(ku, (E, D, F), jnp.float32) / math.sqrt(D)).astype(pdt),
        "wd": (jax.random.normal(kd, (E, F, D), jnp.float32) / math.sqrt(F)).astype(pdt),
    }


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    """Per-expert capacity: top_k * tokens * cf / E, rounded up to a multiple of 8."""
    m = cfg.moe
    cap = int(math.ceil(n_tokens * m.top_k * m.capacity_factor / m.num_experts))
    return max(8, ((cap + 7) // 8) * 8)


def moe_apply(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (y, aux) with aux = {lb_loss, z_loss, router_entropy}."""
    if cfg.moe_dispatch == "grouped":
        return moe_apply_grouped(params, cfg, x)
    B, S, D = x.shape
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    cdt = x.dtype
    T = B * S
    C = expert_capacity(T, cfg)

    xt = x.reshape(T, D)

    # ------------------------------------------------------------- routing
    logits = (xt @ params["router"].astype(cdt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                              # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)              # renormalize

    # aux losses (Switch/GShard style)
    me = jnp.mean(probs, axis=0)                                        # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0
    )                                                                   # (E,)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    entropy = -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1))

    # ----------------------------------------------------- sort-based dispatch
    flat_e = top_e.reshape(T * K)                                       # expert id per slot
    flat_w = top_p.reshape(T * K).astype(cdt)
    flat_tok = jnp.repeat(jnp.arange(T), K)                             # token id per slot

    order = jnp.argsort(flat_e, stable=True)                            # (T*K,)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]

    counts = jnp.bincount(flat_e, length=E)                             # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]                     # rank within expert
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)              # overflow -> dropped

    buf = jnp.zeros((E * C + 1, D), cdt).at[slot].set(xt[sorted_tok])
    hidden = buf[: E * C].reshape(E, C, D)
    # expert-parallel layout: the all-to-all between the token-sharded input
    # and the expert-sharded buffer is inserted here by XLA
    hidden = shard(hidden, "experts", "capacity", "d_model")

    # --------------------------------------------------------- expert compute
    g = jnp.einsum("ecd,edf->ecf", hidden, params["wg"].astype(cdt))
    u = jnp.einsum("ecd,edf->ecf", hidden, params["wu"].astype(cdt))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(cdt) * u
    out = jnp.einsum("ecf,efd->ecd", act, params["wd"].astype(cdt))     # (E, C, D)
    out = shard(out, "experts", "capacity", "d_model")

    # ----------------------------------------------------------- combine back
    out_flat = jnp.concatenate([out.reshape(E * C, D), jnp.zeros((1, D), cdt)])
    gathered = out_flat[slot]                                           # (T*K, D), dropped->0
    gathered = gathered * flat_w[order][:, None]
    y = jnp.zeros((T, D), cdt).at[sorted_tok].add(
        jnp.where(keep[:, None], gathered, 0)
    )

    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "router_entropy": entropy}
    return y.reshape(B, S, D), aux


def moe_apply_grouped(params: dict, cfg: ModelConfig, x: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, dict]:
    """Group-local dispatch: sort/scatter/combine stay *within each batch row*
    (the data-sharded dimension), so the only cross-shard communication is
    the expert einsum's all-to-all.

    The global variant sorts all B*S*top_k slot assignments across the whole
    (data-sharded) token set, which XLA must lower to a distributed sort plus
    cross-shard scatters — measured at ~88 TB/device/step of all-reduce for
    kimi-k2 train_4k.  Grouping makes those ops shard-local at a small
    load-balancing cost (capacity is provisioned per S-token row instead of
    per the global batch).
    """
    B, S, D = x.shape
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    cdt = x.dtype
    N = S * K
    C = expert_capacity(S, cfg)

    # ------------------------------------------------------------- routing
    logits = (x @ params["router"].astype(cdt)).astype(jnp.float32)   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                            # (B,S,K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32),
                          axis=2), axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    entropy = -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1))

    # ----------------------------------------------- group-local dispatch
    flat_e = top_e.reshape(B, N)                                      # (B, N)
    order = jnp.argsort(flat_e, axis=-1, stable=True)                 # local sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)

    # rank within each expert run: i - first_occurrence(sorted_e[i])
    first = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left"))(sorted_e)
    pos_in_e = jnp.arange(N)[None, :] - first
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)            # (B, N)

    # invert the sort: slot_tok[b, s, k] = capacity slot of token s's k-th
    # expert choice (E*C = dropped).  All index math stays (B, N) int32.
    rows = jnp.arange(B)[:, None]
    slot_tok = jnp.zeros((B, N), jnp.int32).at[rows, order].set(
        slot.astype(jnp.int32)).reshape(B, S, K)

    # dispatch: K narrow scatters straight from x — the (B, S*K, D)
    # duplicated-token tensor (240 GB fp32 for kimi-k2, which XLA replicated
    # cross-shard in fwd AND bwd) never exists
    def scatter_k(bufb, xb, sb):
        return bufb.at[sb].set(xb)

    x = shard(x, "batch", None, "d_model")
    buf = shard(jnp.zeros((B, E * C + 1, D), cdt), "batch", None, "d_model")
    for k in range(K):
        buf = jax.vmap(scatter_k)(buf, x, slot_tok[:, :, k])
        buf = shard(buf, "batch", None, "d_model")   # keep the scatter local
    hidden = buf[:, : E * C].reshape(B, E, C, D)
    hidden = shard(hidden, "batch", "experts", None, "d_model")

    # --------------------------------------------------------- expert FFN
    g = jnp.einsum("becd,edf->becf", hidden, params["wg"].astype(cdt))
    u = jnp.einsum("becd,edf->becf", hidden, params["wu"].astype(cdt))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(cdt) * u
    out = jnp.einsum("becf,efd->becd", act, params["wd"].astype(cdt))
    out = shard(out, "batch", "experts", None, "d_model")

    # ------------------------------------------------------------ combine
    # K narrow gathers back to token order, weighted by router probs
    out_flat = jnp.concatenate(
        [out.reshape(B, E * C, D), jnp.zeros((B, 1, D), cdt)], axis=1)
    out_flat = shard(out_flat, "batch", None, "d_model")
    wk = top_p.astype(cdt)                                            # (B,S,K)
    y = jnp.zeros((B, S, D), cdt)
    for k in range(K):
        got = jax.vmap(lambda ob, sb: jnp.take(ob, sb, axis=0))(
            out_flat, slot_tok[:, :, k])                              # (B,S,D)
        y = y + got * wk[:, :, k][..., None]
        y = shard(y, "batch", None, "d_model")

    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "router_entropy": entropy}
    return y, aux


def moe_loss(aux: dict, cfg: ModelConfig) -> jnp.ndarray:
    m = cfg.moe
    return m.router_aux_weight * aux["lb_loss"] + m.router_z_weight * aux["z_loss"]
