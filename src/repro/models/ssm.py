"""Mamba2 (SSD — state-space duality) layer in pure JAX.

Implements the chunked SSD algorithm of arXiv:2405.21060: the sequence is
split into chunks; within a chunk the recurrence is computed as a dense
(quadratic-in-chunk) masked attention-like form that feeds the MXU, while
across chunks a tiny recurrent state ``(B, heads, P, N)`` is carried by a
``lax.scan``.  This is exactly the TPU-friendly formulation (dense tiles +
small carried state) — the Pallas kernel in ``repro.kernels.ssd_scan``
implements the same decomposition with explicit VMEM tiling; this module is
also its oracle ground truth via ``repro.kernels.ref``.

Decode keeps O(1) state: ``(B, H, P, N)`` SSM state + a ``(B, W-1, C)`` causal
conv ring — this is what makes ``long_500k`` tractable for mamba2/jamba.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


class SSMCache(NamedTuple):
    state: jnp.ndarray       # (B, H, P, N) recurrent SSM state
    conv: jnp.ndarray        # (B, W-1, C) last conv inputs


def _conv_channels(cfg: ModelConfig) -> int:
    c = cfg.ssm
    return cfg.d_inner + 2 * c.n_groups * c.state_dim


def ssm_init(key, cfg: ModelConfig) -> dict:
    c = cfg.ssm
    D = cfg.d_model
    d_in = cfg.d_inner
    H = cfg.ssm_heads
    conv_ch = _conv_channels(cfg)
    pdt = cfg.dtype("param")
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj emits [z, x, B, C, dt]
    zxbcdt = 2 * d_in + 2 * c.n_groups * c.state_dim + H
    dt_init = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(k3, (H,), jnp.float32,
                           math.log(0.001), math.log(0.1)))))
    return {
        "in_proj": dense_init(k1, D, zxbcdt, pdt),
        "conv_w": (jax.random.normal(k2, (c.conv_width, conv_ch), jnp.float32)
                   / math.sqrt(c.conv_width)).astype(pdt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": dt_init,
        "D_skip": jnp.ones((H,), jnp.float32),
        "gate_norm": rmsnorm_init(d_in, pdt),
        "out_proj": dense_init(k4, d_in, D, pdt),
    }


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} a[..., k], -inf above diag.

    a: (..., L) -> (..., L, L) lower-triangular cumulative log-decay matrix.
    """
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum over (j, i]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,       # (B, S, H, P)  pre-multiplied by dt
    a: jnp.ndarray,       # (B, S, H)     log-decay per step (A * dt, <= 0)
    Bm: jnp.ndarray,      # (B, S, H, N)  input matrix (already broadcast to heads)
    Cm: jnp.ndarray,      # (B, S, H, N)  output matrix
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,   # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, L, H, P).astype(f32)
    ac = a.reshape(Bsz, nc, L, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, L, H, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, L, H, N).astype(f32)

    a_hl = jnp.moveaxis(ac, -1, -2)                     # (B, nc, H, L)
    a_cum = jnp.cumsum(a_hl, axis=-1)                   # (B, nc, H, L)

    # 1) intra-chunk dense block
    Lmat = jnp.exp(_segsum(a_hl))                       # (B, nc, H, L, L)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)   # (B, nc, H, L, L)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * Lmat, xc)

    # 2) per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)     # (B, nc, H, L)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", Bc, decay_states, xc)

    # 3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])               # (B, nc, H)
    s0 = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st, dec = inp                                   # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                               # emit the *incoming* state

    states_t = jnp.moveaxis(states, 1, 0)               # (nc, B, H, P, N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)           # (nc, B, H)
    final, prev_states = jax.lax.scan(step, s0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B, nc, H, P, N)

    # 4) state -> output contribution
    state_decay = jnp.exp(a_cum)                        # (B, nc, H, L)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final


# ---------------------------------------------------------------------------
# full layer
# ---------------------------------------------------------------------------


def _causal_conv(seq: jnp.ndarray, w: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv. seq: (B, S, C); w: (W, C); history: (B, W-1, C)."""
    W = w.shape[0]
    if history is None:
        history = jnp.zeros((seq.shape[0], W - 1, seq.shape[2]), seq.dtype)
    padded = jnp.concatenate([history, seq], axis=1)     # (B, S+W-1, C)
    out = sum(padded[:, i : i + seq.shape[1]] * w[i][None, None, :] for i in range(W))
    return jax.nn.silu(out.astype(jnp.float32)).astype(seq.dtype)


def ssm_apply(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,                       # (B, S, D)
    cache: Optional[SSMCache] = None,
    *,
    use_kernel: bool = False,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[SSMCache]]:
    """Train/prefill when S > 1 (returns updated cache if one was passed);
    single-token decode when S == 1 and cache is given."""
    c = cfg.ssm
    B_, S, D = x.shape
    d_in, H, P, N, G = cfg.d_inner, cfg.ssm_heads, c.head_dim, c.state_dim, c.n_groups
    cdt = cfg.dtype("compute")
    x = x.astype(cdt)

    zxbcdt = x @ params["in_proj"].astype(cdt)           # (B, S, ...)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1
    )

    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)     # (B, S, conv_ch)
    w = params["conv_w"].astype(cdt)
    Wd = w.shape[0]

    if cache is not None and S == 1:
        conv_hist = cache.conv.astype(cdt)
        conv_out = _causal_conv(conv_in, w, conv_hist)
        new_conv = jnp.concatenate([conv_hist, conv_in], axis=1)[:, 1:]
    else:
        conv_out = _causal_conv(conv_in, w)
        if cache is not None:
            tail = conv_in[:, -(Wd - 1):]
            pad = Wd - 1 - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_conv = tail.astype(cache.conv.dtype)
        else:
            new_conv = None

    xs, Bc, Cc = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B_, S, H, P)
    Bc = Bc.reshape(B_, S, G, N)
    Cc = Cc.reshape(B_, S, G, N)
    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=2)                     # (B, S, H, N)
    Ch = jnp.repeat(Cc, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B, S, H)
    A = -jnp.exp(params["A_log"])                        # (H,) negative
    a = A[None, None, :] * dt                            # log decay, (B, S, H)
    x_dt = xs.astype(jnp.float32) * dt[..., None]        # (B, S, H, P)

    init_state = cache.state if cache is not None else None

    if S == 1 and cache is not None:
        # recurrent decode: state = state*exp(a) + B ⊗ x_dt ; y = C · state
        st = cache.state.astype(jnp.float32)             # (B, H, P, N)
        st = st * jnp.exp(a[:, 0, :, None, None]) + jnp.einsum(
            "bhn,bhp->bhpn", Bh[:, 0], x_dt[:, 0]
        )
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, 0], st)[:, None]   # (B, 1, H, P)
        final_state = st
    elif use_kernel:
        from repro.kernels import ops as kops
        y, final_state = kops.ssd_scan(
            x_dt, a, Bh, Ch, chunk=c.chunk_size,
            init_state=init_state, interpret=interpret)
    else:
        y, final_state = ssd_chunked(x_dt, a, Bh, Ch, chunk=min(c.chunk_size, S),
                                     init_state=init_state)

    y = y + xs.astype(jnp.float32) * params["D_skip"][None, None, :, None]
    y = y.reshape(B_, S, d_in).astype(cdt)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cdt)
    y = rmsnorm(params["gate_norm"], y, cfg.norm_eps)
    out = y @ params["out_proj"].astype(cdt)

    new_cache = None
    if cache is not None:
        new_cache = SSMCache(state=final_state.astype(cache.state.dtype),
                             conv=new_conv.astype(cache.conv.dtype))
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=None) -> SSMCache:
    c = cfg.ssm
    sdt = jnp.float32
    cdt = dtype or cfg.dtype("compute")
    return SSMCache(
        state=jnp.zeros((batch, cfg.ssm_heads, c.head_dim, c.state_dim), sdt),
        conv=jnp.zeros((batch, c.conv_width - 1, _conv_channels(cfg)), cdt),
    )
