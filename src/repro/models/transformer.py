"""Composable decoder-only transformer executed as a scan over layer groups.

``init_params`` builds a parameter pytree whose repeated-block leaves are
stacked over ``cfg.n_groups`` (leading ``layers`` axis); ``forward`` runs
``jax.lax.scan`` over that axis so the lowered HLO contains the group body
exactly once regardless of depth.  Sliding-window / global attention, MoE,
and SSM positions are all expressed through ``cfg.pattern``.

Decode (``decode_step``) carries a cache pytree with the same leading group
axis; each pattern position owns its cache kind (ring-buffer KV for windowed
attention, full KV for global attention, O(1) recurrent state for SSM).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ATTN, SSM, LayerSpec, ModelConfig
from repro.sharding.rules import LA, shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_position(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    D = cfg.d_model
    pdt = cfg.dtype("param")
    k1, k2 = jax.random.split(key)
    p: Params = {"ln1": L.rmsnorm_init(D, pdt)}
    if spec.kind == ATTN:
        p["attn"] = L.attention_init(k1, cfg)
    else:
        p["ssm"] = S.ssm_init(k1, cfg)
    if spec.mlp:
        p["ln2"] = L.rmsnorm_init(D, pdt)
        p["moe" if spec.moe else "mlp"] = (
            M.moe_init(k2, cfg) if spec.moe else L.mlp_init(k2, cfg)
        )
    return p


def _init_group(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.period)
    return {f"pos{i}": _init_position(keys[i], cfg, spec)
            for i, spec in enumerate(cfg.pattern)}


def init_params(key, cfg: ModelConfig) -> Params:
    ke, kb = jax.random.split(key)
    group_keys = jax.random.split(kb, cfg.n_groups)
    blocks = jax.vmap(lambda k: _init_group(k, cfg))(group_keys)
    return {
        "embed": L.embed_init(ke, cfg),
        "blocks": blocks,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype("param")),
    }


def abstract_params(cfg: ModelConfig):
    """Shape/dtype pytree without allocating (for dry-runs)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# logical sharding axes for every parameter
# ---------------------------------------------------------------------------


def _position_axes(cfg: ModelConfig, spec: LayerSpec) -> Params:
    g = lambda *names: LA(("layers",) + names)  # noqa: E731  (stacked leading dim)
    p: Params = {"ln1": {"scale": g(None)}}
    if spec.kind == ATTN:
        p["attn"] = {
            "wq": g("fsdp", "heads"),
            "wk": g("fsdp", "kv_heads"),
            "wv": g("fsdp", "kv_heads"),
            "wo": g("heads", "fsdp"),
        }
    else:
        p["ssm"] = {
            "in_proj": g("fsdp", None),
            "conv_w": g(None, "conv_ch"),
            "A_log": g(None),
            "dt_bias": g(None),
            "D_skip": g(None),
            "gate_norm": {"scale": g(None)},
            "out_proj": g(None, "fsdp"),
        }
    if spec.mlp:
        p["ln2"] = {"scale": g(None)}
        if spec.moe:
            if cfg.moe_param_shard == "ff":
                # shard the expert FFN hidden dim over the data axis:
                # weights never gather; the F-contraction psums activations
                p["moe"] = {
                    "router": g("fsdp", "experts"),
                    "wg": g("experts", None, "expert_ff"),
                    "wu": g("experts", None, "expert_ff"),
                    "wd": g("experts", "expert_ff", None),
                }
            else:
                p["moe"] = {
                    "router": g("fsdp", "experts"),
                    "wg": g("experts", "fsdp", None),
                    "wu": g("experts", "fsdp", None),
                    "wd": g("experts", None, "fsdp"),
                }
        else:
            p["mlp"] = {
                "wg": g("fsdp", "d_ff"),
                "wu": g("fsdp", "d_ff"),
                "wd": g("d_ff", "fsdp"),
            }
    return p


def param_logical_axes(cfg: ModelConfig) -> Params:
    embed: Params = {"tokens": LA(("vocab", "fsdp"))}
    if not cfg.tie_embeddings:
        embed["lm_head"] = LA(("fsdp", "vocab"))
    return {
        "embed": embed,
        "blocks": {f"pos{i}": _position_axes(cfg, spec)
                   for i, spec in enumerate(cfg.pattern)},
        "final_norm": {"scale": LA((None,))},
    }


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _positions_for(cfg: ModelConfig, tokens: jnp.ndarray,
                   positions: Optional[jnp.ndarray]) -> jnp.ndarray:
    if positions is not None:
        return positions
    B, Sq = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    if cfg.pos_embed == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, Sq))
    return pos


def _apply_position(p: Params, cfg: ModelConfig, spec: LayerSpec, h, positions,
                    cache=None, cache_pos=None, use_ssm_kernel=False):
    """One pattern position: (attn|ssm) + optional (mlp|moe), pre-norm residual."""
    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32),
           "router_entropy": jnp.zeros((), jnp.float32)}
    hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
    if spec.kind == ATTN:
        out, new_cache = L.attention_apply(
            p["attn"], cfg, spec, hn, positions,
            cache=cache, cache_pos=cache_pos)
    else:
        out, new_cache = S.ssm_apply(
            p["ssm"], cfg, hn, cache=cache,
            use_kernel=use_ssm_kernel,
            interpret=cfg.attention_impl == "pallas_interpret")
    h = h + out
    if spec.mlp:
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        if spec.moe:
            out, aux = M.moe_apply(p["moe"], cfg, hn)
        else:
            out = L.mlp_apply(p["mlp"], hn)
        h = h + out
    h = shard(h, "batch", "seq", "d_model")
    return h, new_cache, aux


def _group_body(cfg: ModelConfig, use_ssm_kernel: bool):
    def body(h, group_params, positions, caches=None, cache_pos=None):
        new_caches = {} if caches is not None else None
        aux_sum = None
        for i, spec in enumerate(cfg.pattern):
            key = f"pos{i}"
            c = caches.get(key) if caches is not None else None
            h, nc, aux = _apply_position(
                group_params[key], cfg, spec, h, positions,
                cache=c, cache_pos=cache_pos, use_ssm_kernel=use_ssm_kernel)
            if new_caches is not None:
                new_caches[key] = nc
            aux_sum = aux if aux_sum is None else jax.tree.map(
                jnp.add, aux_sum, aux)
        return h, new_caches, aux_sum

    return body


def scan_groups(fn, carry, xs, *, length: int, use_scan: bool):
    """``lax.scan`` or an exact python unroll (for cost-analysis dry-runs —
    XLA-CPU cost_analysis counts while-loop bodies once)."""
    if use_scan:
        return jax.lax.scan(fn, carry, xs)
    ys = []
    for g in range(length):
        xg = jax.tree.map(lambda x: x[g], xs)
        carry, y = fn(carry, xg)
        ys.append(y)
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,                        # (B, S) int32
    *,
    positions: Optional[jnp.ndarray] = None,    # (B,S) or (3,B,S)
    patch_emb: Optional[jnp.ndarray] = None,    # VLM stub: (B, Np, D)
    use_ssm_kernel: bool = False,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence forward. Returns (logits fp32, aux)."""
    positions = _positions_for(cfg, tokens, positions)
    h = L.embed_apply(params["embed"], cfg, tokens)
    if patch_emb is not None and cfg.vision_patches:
        # the first `vision_patches` positions are image placeholders whose
        # embeddings come from the (stubbed) vision encoder
        h = jax.lax.dynamic_update_slice(
            h, patch_emb.astype(h.dtype), (0, 0, 0))
    h = shard(h, "batch", "seq", "d_model")

    body = _group_body(cfg, use_ssm_kernel)

    def scan_fn(carry, group_params):
        h = carry
        h, _, aux = body(h, group_params, positions)
        return h, aux

    h, aux_stack = scan_groups(_maybe_remat(cfg, scan_fn), h,
                               params["blocks"], length=cfg.n_groups,
                               use_scan=cfg.scan_layers)
    aux = jax.tree.map(lambda a: jnp.sum(a, axis=0), aux_stack)

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, aux
    logits = L.unembed_apply(params["embed"], cfg, h)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-mean CE. logits (B,S,V) fp32, labels (B,S) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(params: Params, cfg: ModelConfig, batch: dict,
            use_ssm_kernel: bool = False) -> Tuple[jnp.ndarray, dict]:
    """Next-token LM loss + MoE auxiliaries. batch: {tokens, labels[, mask,
    positions, patch_emb]}."""
    logits, aux = forward(
        params, cfg, batch["tokens"],
        positions=batch.get("positions"),
        patch_emb=batch.get("patch_emb"),
        use_ssm_kernel=use_ssm_kernel)
    ce = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    total = ce + (M.moe_loss(aux, cfg) if cfg.has_moe else 0.0)
    metrics = {"loss": total, "ce": ce, **aux}
    return total, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Params:
    """Cache pytree: per pattern position, stacked over groups (leading axis)."""

    def one_group(_):
        caches = {}
        for i, spec in enumerate(cfg.pattern):
            if spec.kind == ATTN:
                caches[f"pos{i}"] = L.init_kv_cache(cfg, spec, batch, seq_len)
            else:
                caches[f"pos{i}"] = S.init_ssm_cache(cfg, batch)
        return caches

    return jax.vmap(one_group)(jnp.arange(cfg.n_groups))


def cache_logical_axes(cfg: ModelConfig, seq_len: int) -> Params:
    """Logical axes for the cache pytree.  Full (global-attention) caches get
    a shardable ``cache_seq`` axis — the decode rule set maps it onto the
    ``"model"`` axis (flash-decoding-style sequence sharding), which is what
    keeps 32k/500k caches within HBM even when ``kv_heads`` doesn't divide
    the model axis.  Ring-buffer (windowed) caches stay unsharded on seq."""
    axes = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.kind == ATTN:
            full = spec.window is None
            axes[f"pos{i}"] = L.KVCache(
                k=LA(("layers", "batch", "cache_seq" if full else None,
                      "kv_heads", None)),
                v=LA(("layers", "batch", "cache_seq" if full else None,
                      "kv_heads", None)),
            )
        else:
            axes[f"pos{i}"] = S.SSMCache(
                state=LA(("layers", "batch", "ssm_heads", None, None)),
                conv=LA(("layers", "batch", None, "conv_ch")),
            )
    return axes


def decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jnp.ndarray,         # (B, 1) int32 — the newest token
    cache: Params,
    cache_pos: jnp.ndarray,     # scalar int32 — #tokens already in the cache
) -> Tuple[jnp.ndarray, Params]:
    """One-token decode. Returns (logits (B,1,V) fp32, new_cache)."""
    B = token.shape[0]
    pos = jnp.broadcast_to(cache_pos.astype(jnp.int32), (B, 1))
    if cfg.pos_embed == "mrope":
        positions = jnp.broadcast_to(pos[None], (3, B, 1))
    else:
        positions = pos

    h = L.embed_apply(params["embed"], cfg, token)
    body = _group_body(cfg, use_ssm_kernel=False)

    def scan_fn(carry, xs):
        group_params, caches = xs
        h = carry
        h, new_caches, _ = body(h, group_params, positions,
                                caches=caches, cache_pos=cache_pos)
        return h, new_caches

    h, new_cache = scan_groups(scan_fn, h, (params["blocks"], cache),
                               length=cfg.n_groups, use_scan=cfg.scan_layers)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], cfg, h)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill (forward + cache construction)
# ---------------------------------------------------------------------------


def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,        # (B, S)
    cache_len: int,
    *,
    positions: Optional[jnp.ndarray] = None,
    patch_emb: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Params]:
    """Run the full prompt, building the decode cache.

    For simplicity and HLO compactness the prompt K/V are recomputed per layer
    inside the same scan that runs the forward pass; attention positions write
    their prompt K/V into the allocated cache, SSM positions write their final
    state.  Returns (last-token logits (B, V) fp32, cache).
    """
    B, Sq = tokens.shape
    positions = _positions_for(cfg, tokens, positions)
    cache = init_cache(cfg, B, cache_len)

    h = L.embed_apply(params["embed"], cfg, tokens)
    if patch_emb is not None and cfg.vision_patches:
        h = jax.lax.dynamic_update_slice(h, patch_emb.astype(h.dtype), (0, 0, 0))
    h = shard(h, "batch", "seq", "d_model")

    cdt = cfg.dtype("compute")

    def scan_fn(h, xs):
        group_params, caches = xs
        new_caches = {}
        for i, spec in enumerate(cfg.pattern):
            p = group_params[f"pos{i}"]
            hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
            if spec.kind == ATTN:
                out, _ = L.attention_apply(p["attn"], cfg, spec, hn, positions)
                # recompute prompt K/V into the cache
                K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
                k = (hn @ p["attn"]["wk"].astype(cdt)).reshape(B, Sq, K, Dh)
                v = (hn @ p["attn"]["wv"].astype(cdt)).reshape(B, Sq, K, Dh)
                k = L.position_embed(cfg, k, positions)
                c = caches[f"pos{i}"]
                C = c.k.shape[1]
                if C >= Sq:
                    nk = jax.lax.dynamic_update_slice(
                        c.k, k.astype(c.k.dtype), (0, 0, 0, 0))
                    nv = jax.lax.dynamic_update_slice(
                        c.v, v.astype(c.v.dtype), (0, 0, 0, 0))
                else:  # ring buffer smaller than the prompt: keep the tail,
                    # rolled so that slot j holds position p ≡ j (mod C)
                    tail_k, tail_v = k[:, -C:], v[:, -C:]
                    shift = Sq % C
                    nk = jnp.roll(tail_k, shift, axis=1).astype(c.k.dtype)
                    nv = jnp.roll(tail_v, shift, axis=1).astype(c.v.dtype)
                new_caches[f"pos{i}"] = L.KVCache(k=nk, v=nv)
            else:
                out, nc = S.ssm_apply(p["ssm"], cfg, hn,
                                      cache=caches[f"pos{i}"])
                new_caches[f"pos{i}"] = nc
            h = h + out
            if spec.mlp:
                hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
                out = (M.moe_apply(p["moe"], cfg, hn)[0] if spec.moe
                       else L.mlp_apply(p["mlp"], hn))
                h = h + out
            h = shard(h, "batch", "seq", "d_model")
        return h, new_caches

    h, cache = scan_groups(_maybe_remat(cfg, scan_fn), h,
                           (params["blocks"], cache),
                           length=cfg.n_groups, use_scan=cfg.scan_layers)
    h = L.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], cfg, h)[:, 0]
    return logits, cache
