"""The paper's own evaluation models (Table III), reimplemented in JAX.

Cloudless-Training evaluates LeNet (MNIST), a filters/4 ResNet18 variant
(CIFAR-10) and DeepFM (Frappe).  These are used by the paper-reproduction
experiments: usability/convergence parity (Fig 7), elastic scheduling
(Figs 8-9) and the synchronization-strategy studies (Figs 10-11), both in
the real multi-device CPU emulation tests and in the WAN simulator (where
their measured gradient sizes — 0.4 / 0.6 / 2.4 MB — set the sync traffic).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _dense_init(key, i, o):
    return jax.random.normal(key, (i, o), jnp.float32) / math.sqrt(i)


def _conv_init(key, h, w, i, o):
    return jax.random.normal(key, (h, w, i, o), jnp.float32) / math.sqrt(h * w * i)


# ---------------------------------------------------------------------------
# LeNet  (paper: MNIST, gradient size ~0.4 MB)
# ---------------------------------------------------------------------------


def lenet_init(key):
    ks = jax.random.split(key, 5)
    return {
        "c1": _conv_init(ks[0], 5, 5, 1, 6),
        "c2": _conv_init(ks[1], 5, 5, 6, 16),
        "f1": _dense_init(ks[2], 7 * 7 * 16, 120),
        "f2": _dense_init(ks[3], 120, 84),
        "f3": _dense_init(ks[4], 84, 10),
    }


def lenet_apply(p, x):
    """x: (B, 28, 28, 1) -> logits (B, 10)."""
    h = jax.nn.relu(_conv(x, p["c1"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.nn.relu(_conv(h, p["c2"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["f1"])
    h = jax.nn.relu(h @ p["f2"])
    return h @ p["f3"]


# ---------------------------------------------------------------------------
# ResNet18 / filters cut by 4  (paper: CIFAR-10, gradient size ~0.6 MB)
# ---------------------------------------------------------------------------

_RESNET_STAGES = (16, 32, 64, 128)  # 64..512 cut by 4


def _block_init(key, cin, cout):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"c1": _conv_init(k1, 3, 3, cin, cout), "c2": _conv_init(k2, 3, 3, cout, cout)}
    if cin != cout:
        p["proj"] = _conv_init(k3, 1, 1, cin, cout)
    return p


def resnet_init(key):
    ks = jax.random.split(key, 10)
    p = {"stem": _conv_init(ks[0], 3, 3, 3, _RESNET_STAGES[0])}
    cin = _RESNET_STAGES[0]
    i = 1
    for s, cout in enumerate(_RESNET_STAGES):
        for b in range(2):
            p[f"s{s}b{b}"] = _block_init(ks[i], cin, cout)
            cin = cout
            i += 1
    p["head"] = _dense_init(ks[i], cin, 10)
    return p


def _resblock(p, x, stride):
    h = jax.nn.relu(_conv(x, p["c1"], stride))
    h = _conv(h, p["c2"])
    if "proj" in p:
        x = _conv(x, p["proj"], stride)
    return jax.nn.relu(h + x)


def resnet_apply(p, x):
    """x: (B, 32, 32, 3) -> logits (B, 10)."""
    h = jax.nn.relu(_conv(x, p["stem"]))
    for s in range(len(_RESNET_STAGES)):
        for b in range(2):
            h = _resblock(p[f"s{s}b{b}"], h, 2 if (b == 0 and s > 0) else 1)
    h = jnp.mean(h, axis=(1, 2))
    return h @ p["head"]


# ---------------------------------------------------------------------------
# DeepFM  (paper: Frappe CTR, gradient size ~2.4 MB)
# ---------------------------------------------------------------------------

N_FIELDS = 10
N_FEATURES = 5400   # Frappe-scale feature space
EMB_DIM = 16


def deepfm_init(key):
    ks = jax.random.split(key, 5)
    return {
        "emb": jax.random.normal(ks[0], (N_FEATURES, EMB_DIM), jnp.float32) * 0.01,
        "lin": jax.random.normal(ks[1], (N_FEATURES,), jnp.float32) * 0.01,
        "f1": _dense_init(ks[2], N_FIELDS * EMB_DIM, 400),
        "f2": _dense_init(ks[3], 400, 400),
        "f3": _dense_init(ks[4], 400, 1),
    }


def deepfm_apply(p, feats):
    """feats: (B, N_FIELDS) int32 feature ids -> logit (B,)."""
    emb = p["emb"][feats]                         # (B, F, E)
    linear = jnp.sum(p["lin"][feats], axis=-1)    # (B,)
    # FM second-order: 0.5 * ((sum e)^2 - sum e^2)
    s = jnp.sum(emb, axis=1)
    fm = 0.5 * jnp.sum(jnp.square(s) - jnp.sum(jnp.square(emb), axis=1), axis=-1)
    h = emb.reshape(emb.shape[0], -1)
    h = jax.nn.relu(h @ p["f1"])
    h = jax.nn.relu(h @ p["f2"])
    deep = (h @ p["f3"])[:, 0]
    return linear + fm + deep


# ---------------------------------------------------------------------------
# uniform train-task interface used by sync/scheduler experiments
# ---------------------------------------------------------------------------


def ce_loss(apply_fn):
    def loss(params, batch):
        logits = apply_fn(params, batch["x"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)
    return loss


def bce_loss(apply_fn):
    def loss(params, batch):
        logit = apply_fn(params, batch["x"])
        y = batch["y"].astype(jnp.float32)
        return jnp.mean(jnp.maximum(logit, 0) - logit * y +
                        jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return loss


PAPER_MODELS = {
    "lenet": dict(init=lenet_init, apply=lenet_apply, loss=ce_loss(lenet_apply),
                  input_shape=(28, 28, 1), n_classes=10, grad_mb=0.4),
    "resnet": dict(init=resnet_init, apply=resnet_apply, loss=ce_loss(resnet_apply),
                   input_shape=(32, 32, 3), n_classes=10, grad_mb=0.6),
    "deepfm": dict(init=deepfm_init, apply=deepfm_apply, loss=bce_loss(deepfm_apply),
                   input_shape=(N_FIELDS,), n_classes=2, grad_mb=2.4),
}


def param_mb(params) -> float:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)) / 1e6
