"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code annotates activations/params with *logical* axis names via
``shard(x, "batch", "seq", "d_model")``.  The launcher installs a rule table
mapping logical names to physical mesh axes; ``shard`` builds a
``PartitionSpec``, dropping any mesh axis that does not divide the concrete
dimension (e.g. 6 attention heads cannot shard over a 16-way ``"model"``
axis — whisper-tiny — so the dim is replicated instead of erroring).  When no
rules/mesh are installed (plain CPU unit tests) ``shard`` is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


class LA(tuple):
    """Marker leaf: logical axis names of one parameter (kept opaque to
    jax.tree by being checked via ``is_leaf`` everywhere it is mapped)."""

    def __new__(cls, names):
        return super().__new__(cls, tuple(names))

    @property
    def names(self):
        return tuple(self)


def is_la(x) -> bool:
    return isinstance(x, LA)

# default logical -> physical mapping (single- and multi-pod)
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,
    "cache_seq": "data",        # sequence-sharded KV cache (long_500k decode)
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "experts": "model",
    "expert_ff": "data",
    "capacity": None,
    "vocab": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_ch": "model",
    "fsdp": "data",             # parameter sharding axis (ZeRO-3 style)
    "pattern": None,
    "layers": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.rules: Optional[Dict[str, Axis]] = None
        self.mesh: Optional[Mesh] = None


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Axis], mesh: Optional[Mesh] = None):
    """Install logical sharding rules (and optionally the mesh) for a scope."""
    old = (_CTX.rules, _CTX.mesh)
    _CTX.rules = dict(rules)
    _CTX.mesh = mesh if mesh is not None else _CTX.mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = old


def current_mesh() -> Optional[Mesh]:
    if _CTX.mesh is not None:
        return _CTX.mesh
    # fall back to the ambient mesh installed by `with mesh:`
    try:
        from jax._src import mesh as _mesh_lib
        env_mesh = _mesh_lib.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:
        pass
    return None


def _mesh_axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    names = (axis,) if isinstance(axis, str) else axis
    size = 1
    for n in names:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(n, 1)
    return size


def logical_to_spec(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    rules: Optional[Dict[str, Axis]] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names.

    Mesh axes that don't exist in the mesh or don't divide the dimension are
    dropped (replicated).  A multi-axis rule like ``("pod", "data")`` keeps
    the longest divisible prefix.
    """
    rules = rules if rules is not None else (_CTX.rules or DEFAULT_RULES)
    mesh = mesh if mesh is not None else current_mesh()
    parts = []
    used: set = set()   # a mesh axis may appear at most once per spec
    for dim, name in zip(shape, logical):
        axis = rules.get(name) if name else None
        if axis is None or mesh is None:
            parts.append(None)
            continue
        names = (axis,) if isinstance(axis, str) else tuple(axis)
        mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        kept = []
        size = 1
        for n in names:
            s = mesh_sizes.get(n, 1)
            if n not in used and s > 1 and dim % (size * s) == 0:
                kept.append(n)
                used.add(n)
                size *= s
        parts.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*parts)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint described by logical axis names (no-op
    without rules+mesh)."""
    mesh = current_mesh()
    if mesh is None or (_CTX.rules is None):
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"shard: {len(logical)} names for rank-{x.ndim} array")
    spec = logical_to_spec(x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None) -> NamedSharding:
    mesh = mesh if mesh is not None else current_mesh()
    return NamedSharding(mesh, logical_to_spec(shape, logical, mesh=mesh))


def spec_tree_for_params(logical_tree, abstract_params,
                         rules: Optional[Dict[str, Axis]] = None,
                         mesh: Optional[Mesh] = None):
    """Map a pytree of ``LA`` leaves (+ matching abstract params) to
    PartitionSpecs, dropping non-divisible axes per leaf shape."""
    return jax.tree.map(
        lambda names, leaf: logical_to_spec(leaf.shape, names.names, rules, mesh),
        logical_tree, abstract_params, is_leaf=is_la)


def sharding_tree_for_params(logical_tree, abstract_params, mesh: Mesh,
                             rules: Optional[Dict[str, Axis]] = None):
    specs = spec_tree_for_params(logical_tree, abstract_params, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
