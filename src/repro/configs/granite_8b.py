"""granite-8b [dense] — llama-arch, code [arXiv:2405.04324].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import dense, shrink

CONFIG = dense(
    "granite-8b", arch_type="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=49152, rope_theta=10_000_000.0,
)


def smoke_config():
    return shrink(CONFIG, repeats=2)
