"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  The ViT vision
encoder + projector are a stub: ``input_specs`` provides patch embeddings
(B, vision_patches, D) written over the leading placeholder positions, plus
(3, B, S) t/h/w position ids for M-RoPE.
"""
from repro.configs.base import dense, shrink

CONFIG = dense(
    "qwen2-vl-2b", arch_type="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936,
    pos_embed="mrope", mrope_sections=(16, 24, 24),
    vision_patches=256,
)


def smoke_config():
    return shrink(CONFIG, repeats=2, head_dim=64, n_heads=4, n_kv_heads=2,
                  mrope_sections=(8, 12, 12))
