"""whisper-tiny [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536 vocab=51865, encoder
context 1500 frames.  The mel+conv frontend is a stub: ``input_specs``
provides precomputed frame embeddings (B, 1500, 384).
"""
from repro.configs.base import dense, shrink

CONFIG = dense(
    "whisper-tiny", arch_type="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865,
    encoder_layers=4, encoder_ctx=1500,
)


def smoke_config():
    return shrink(CONFIG, repeats=2, n_heads=2, n_kv_heads=2)
