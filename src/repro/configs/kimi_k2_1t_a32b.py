"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8, head_dim=128) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8.  Adaptation: the K2 model card uses MLA; the
assignment specifies GQA kv=8, which is what we implement.
"""
from repro.configs.base import dense, shrink
from repro.models.config import LayerSpec, MoEConfig

CONFIG = dense(
    "kimi-k2-1t-a32b", arch_type="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab=163840,
    pattern=[LayerSpec(moe=True)],
    moe=MoEConfig(num_experts=384, top_k=8, capacity_factor=1.0),
    rope_theta=1_000_000.0,
)


def smoke_config():
    return shrink(CONFIG, repeats=2)
