"""gemma2-27b [dense] — local+global alternating attention, logit softcap
[arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16, head_dim=128) d_ff=36864 vocab=256000.
Pattern period 2: sliding-window (4096) then global; attention softcap 50,
final logit softcap 30.
"""
from repro.configs.base import dense, shrink
from repro.models.config import LayerSpec

_PATTERN = [LayerSpec(window=4096), LayerSpec()]

CONFIG = dense(
    "gemma2-27b", arch_type="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000,
    pattern=_PATTERN, tie_embeddings=True,
    attn_softcap=50.0, final_softcap=30.0,
)


def smoke_config():
    return shrink(CONFIG, repeats=1)
