"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4, head_dim=128) expert d_ff=768 vocab=151936.
"""
from repro.configs.base import dense, shrink
from repro.models.config import LayerSpec, MoEConfig

CONFIG = dense(
    "qwen3-moe-30b-a3b", arch_type="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936,
    pattern=[LayerSpec(moe=True)],
    moe=MoEConfig(num_experts=128, top_k=8),
    rope_theta=1_000_000.0,
)


def smoke_config():
    return shrink(CONFIG, repeats=2)
