"""minitron-8b [dense] — pruned nemotron [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.configs.base import dense, shrink

CONFIG = dense(
    "minitron-8b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab=256000,
)


def smoke_config():
    return shrink(CONFIG, repeats=2)
