"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Pattern period 8: one attention layer per 8 (position 3), the rest Mamba;
MoE FFN on every other position (Jamba's e=2 spacing), dense FFN elsewhere.
Adaptation: Mamba blocks use the Mamba2/SSD formulation (TPU-friendly dense
chunks) rather than Mamba1's selective scan.
"""
from repro.configs.base import ATTN, SSM, dense, shrink
from repro.models.config import LayerSpec, MoEConfig, SSMConfig

_PATTERN = [
    LayerSpec(kind=SSM, moe=False),
    LayerSpec(kind=SSM, moe=True),
    LayerSpec(kind=SSM, moe=False),
    LayerSpec(kind=ATTN, moe=True),
    LayerSpec(kind=SSM, moe=False),
    LayerSpec(kind=SSM, moe=True),
    LayerSpec(kind=SSM, moe=False),
    LayerSpec(kind=SSM, moe=True),
]

CONFIG = dense(
    "jamba-1.5-large-398b", arch_type="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    pattern=_PATTERN,
    moe=MoEConfig(num_experts=16, top_k=2),
    ssm=SSMConfig(state_dim=128, head_dim=64, n_groups=8, chunk_size=256),
)


def smoke_config():
    return shrink(CONFIG, repeats=1)
