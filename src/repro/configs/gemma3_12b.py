"""gemma3-12b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt scaled per assignment].

48L d_model=3840 16H (GQA kv=8, head_dim=256) d_ff=15360 vocab=262144.
Pattern period 6: 5 sliding-window (1024) layers then 1 global layer.
"""
from repro.configs.base import dense, shrink
from repro.models.config import LayerSpec

_PATTERN = [LayerSpec(window=1024)] * 5 + [LayerSpec()]

CONFIG = dense(
    "gemma3-12b", arch_type="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    pattern=_PATTERN, tie_embeddings=True,
    rope_theta=1_000_000.0,
)


def smoke_config():
    return shrink(CONFIG, repeats=1)
