"""Architecture registry: ``--arch <id>`` resolves here.

Each entry carries the exact assigned full-scale config, its reduced smoke
variant, and which model module executes it (decoder-only ``transformer`` or
``encdec``).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.models.config import ModelConfig

_MODULES = {
    "qwen3-moe-30b-a3b": ("qwen3_moe_30b_a3b", "transformer"),
    "jamba-1.5-large-398b": ("jamba_1_5_large_398b", "transformer"),
    "mamba2-1.3b": ("mamba2_1_3b", "transformer"),
    "whisper-tiny": ("whisper_tiny", "encdec"),
    "granite-8b": ("granite_8b", "transformer"),
    "kimi-k2-1t-a32b": ("kimi_k2_1t_a32b", "transformer"),
    "gemma3-12b": ("gemma3_12b", "transformer"),
    "minitron-8b": ("minitron_8b", "transformer"),
    "qwen2-vl-2b": ("qwen2_vl_2b", "transformer"),
    "gemma2-27b": ("gemma2_27b", "transformer"),
}

ARCH_IDS = tuple(_MODULES)


@dataclass(frozen=True)
class Arch:
    name: str
    config: ModelConfig
    smoke: ModelConfig
    module: str  # "transformer" | "encdec"


def get_arch(name: str) -> Arch:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    modname, kind = _MODULES[name]
    mod = importlib.import_module(f"repro.configs.{modname}")
    return Arch(name=name, config=mod.CONFIG, smoke=mod.smoke_config(),
                module=kind)


def all_archs():
    return [get_arch(n) for n in ARCH_IDS]
