"""Helpers shared by architecture configs.

Every assigned architecture file exports:
  CONFIG          — the exact full-scale configuration from the assignment
  smoke_config()  — reduced same-family variant (<=2 pattern repeats,
                    d_model<=512, <=4 experts) for CPU smoke tests
"""
from __future__ import annotations

from repro.models.config import LayerSpec, ModelConfig, MoEConfig, SSMConfig

ATTN = "attn"
SSM = "ssm"


def dense(name: str, *, n_layers: int, d_model: int, n_heads: int,
          n_kv_heads: int, d_ff: int, vocab: int, head_dim=None,
          pattern=None, **kw) -> ModelConfig:
    return ModelConfig(
        name=name, arch_type=kw.pop("arch_type", "dense"),
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_kv_heads, d_ff=d_ff, vocab_size=vocab,
        head_dim=head_dim,
        pattern=tuple(pattern) if pattern else (LayerSpec(),), **kw)


def shrink(cfg: ModelConfig, *, d_model: int = 256, n_heads: int = 4,
           n_kv_heads: int = 2, d_ff: int = 512, vocab: int = 512,
           repeats: int = 1, experts: int = 4, top_k: int = 2,
           head_dim: int = 64, **kw) -> ModelConfig:
    """Reduced same-family variant: keeps the layer pattern (so local/global,
    MoE and SSM positions are all exercised) but tiny dims."""
    moe = cfg.moe
    if cfg.has_moe:
        moe = MoEConfig(num_experts=experts, top_k=min(top_k, experts),
                        capacity_factor=cfg.moe.capacity_factor)
    ssm = SSMConfig(state_dim=32, head_dim=16, n_groups=1, conv_width=4,
                    chunk_size=32, expand=2) if cfg.has_ssm else cfg.ssm
    # shrink windows so smoke seqs exercise the ring-buffer path
    pattern = tuple(
        LayerSpec(kind=s.kind,
                  window=(16 if s.window is not None else None),
                  moe=s.moe, mlp=s.mlp)
        for s in cfg.pattern)
    return cfg.replace(
        name=cfg.name + "-smoke",
        n_layers=cfg.period * repeats, d_model=d_model,
        n_heads=n_heads, n_kv_heads=min(n_kv_heads, n_heads),
        d_ff=d_ff, vocab_size=vocab, head_dim=head_dim,
        pattern=pattern, moe=moe, ssm=ssm,
        encoder_layers=(2 if cfg.encoder_layers else 0),
        encoder_ctx=(24 if cfg.encoder_ctx else 0),
        vision_patches=(8 if cfg.vision_patches else 0),
        vocab_multiple=64,
        param_dtype="float32", compute_dtype="float32",
        remat="none", **kw)
