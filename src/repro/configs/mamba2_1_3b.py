"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, attention-free, vocab=50280, ssm_state=128.
"""
from repro.configs.base import SSM, dense, shrink
from repro.models.config import LayerSpec, SSMConfig

CONFIG = dense(
    "mamba2-1.3b", arch_type="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    pattern=[LayerSpec(kind=SSM, mlp=False)],
    ssm=SSMConfig(state_dim=128, head_dim=64, n_groups=1, chunk_size=256),
    pos_embed="none", tie_embeddings=True,
)


def smoke_config():
    return shrink(CONFIG, repeats=2, d_ff=0)
