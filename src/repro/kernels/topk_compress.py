"""Block-balanced top-k gradient compression Pallas TPU kernel (LEGACY).

Superseded on the production sync path by the fused single-pass codec in
``wan_codec.py`` (threshold-refinement selection + int8 quantization — no
O(k) serialization).  Kept as (a) the uncompressed-payload fallback of
``SyncConfig.compress_topk`` without ``quantize_int8`` and (b) the baseline
the ``benchmarks/wan_codec.py`` microbenchmark measures the speedup against.

Beyond-paper WAN optimization: the paper cites DGC / top-K sparsification as
the complementary family of synchronization optimizations (it only implements
frequency reduction).  This kernel selects, *per contiguous block*, the
largest-magnitude entries of an accumulated-gradient vector, producing a
(values, indices) payload whose size is ``k`` — shipped over the inter-pod
ring instead of the dense gradient.

TPU adaptation: exact global top-k is a poor fit for the VPU (it serializes
on a single sorted sequence).  Real distributed compressors (DGC included)
use sampled-threshold or block-local selection; we use **block-local top-k**
(each VMEM-resident block of the flat gradient contributes ``k_block``
winners via iterative argmax on the 8x128 vector lanes), which additionally
load-balances the scatter on the receiving pod.  ``ref.py`` provides the
exact same block-local semantics as the oracle, plus an exact global top-k
for compression-quality comparison tests.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, vals_ref, idx_ref, *, k_block: int, block: int):
    bi = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)            # (block,)
    mag = jnp.abs(x)
    base = bi * block

    def body(i, carry):
        mag, = carry
        j = jnp.argmax(mag)
        vals_ref[i] = x[j]
        idx_ref[i] = (base + j).astype(jnp.int32)
        mag = mag.at[j].set(-1.0)
        return (mag,)

    jax.lax.fori_loop(0, k_block, body, (mag,))


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def topk_compress_pallas(
    x: jnp.ndarray, k: int, *, block: int = 1024, interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: flat (n,) -> (values (k,), indices (k,) int32), block-balanced."""
    n = x.shape[0]
    block = min(block, n)
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad))
    nb = xp.shape[0] // block
    k_block = max(1, k // nb)

    vals, idx = pl.pallas_call(
        functools.partial(_kernel, k_block=k_block, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda b: (b,))],
        out_specs=[pl.BlockSpec((k_block,), lambda b: (b,)),
                   pl.BlockSpec((k_block,), lambda b: (b,))],
        out_shape=[jax.ShapeDtypeStruct((nb * k_block,), x.dtype),
                   jax.ShapeDtypeStruct((nb * k_block,), jnp.int32)],
        interpret=interpret,
    )(xp)
    # clamp indices of padded region (their values are exact zeros anyway)
    idx = jnp.minimum(idx, n - 1)
    return vals[:k] if vals.shape[0] >= k else vals, \
        idx[:k] if idx.shape[0] >= k else idx
