"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose tests).

- ``sdpa`` — scaled-dot-product attention with causal/window/softcap masks
  (delegates to ``repro.models.layers.sdpa_reference``).
- ``ssd`` — chunked SSD scan (delegates to ``repro.models.ssm.ssd_chunked``,
  which is itself validated against a naive O(S^2) recurrence in tests).
- ``ssd_naive`` — the literal per-step recurrence (slowest, most obviously
  correct; anchors the whole SSD stack).
- ``topk_block`` / ``topk_exact`` — block-balanced and exact global top-k.
- ``wan_encode`` / ``wan_decode`` — the fused WAN payload codec (block-local
  top-k by 16-bit-truncated magnitude key + per-block value quantization on
  the int8 / fp8-e4m3 / nibble-packed-int4 precision ladder), bit-identical
  to the Pallas kernels in ``wan_codec.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import attn_bias, sdpa_reference
from repro.models.ssm import ssd_chunked


def sdpa(q, k, v, *, causal: bool = True, window: Optional[int] = None,
         softcap: float = 0.0) -> jnp.ndarray:
    B, Sq = q.shape[0], q.shape[1]
    Sk = k.shape[1]
    qp = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    bias = attn_bias(qp, kp, None, causal, window)
    return sdpa_reference(q, k, v, bias, softcap)


def ssd(x, a, Bm, Cm, *, chunk: int = 256, init_state=None):
    return ssd_chunked(x, a, Bm, Cm, chunk=min(chunk, x.shape[1]),
                       init_state=init_state)


def ssd_naive(x, a, Bm, Cm, init_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Literal recurrence: s_t = exp(a_t) s_{t-1} + B_t ⊗ x_t; y_t = C_t · s_t."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    s = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
         else init_state.astype(jnp.float32))

    def step(s, t):
        xt, at, bt, ct = t
        s = s * jnp.exp(at)[..., None, None] + jnp.einsum("bhn,bhp->bhpn", bt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, s)
        return s, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(a.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    s, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s


def topk_block(x: jnp.ndarray, k: int, block: int = 1024
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-balanced top-k: per contiguous block, keep k/nb largest |x|."""
    n = x.shape[0]
    block = min(block, n)
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad))
    nb = xp.shape[0] // block
    k_block = max(1, k // nb)
    xb = xp.reshape(nb, block)
    _, loc = jax.lax.top_k(jnp.abs(xb), k_block)            # (nb, k_block)
    idx = (loc + jnp.arange(nb)[:, None] * block).reshape(-1)
    vals = xp[idx]
    idx = jnp.minimum(idx, n - 1)
    return vals[:k], idx[:k].astype(jnp.int32)


def topk_exact(x: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    return x[idx], idx.astype(jnp.int32)


def topk_decompress(vals: jnp.ndarray, idx: jnp.ndarray, n: int) -> jnp.ndarray:
    return jnp.zeros((n,), vals.dtype).at[idx].set(vals)


# ------------------------------------------------------- fused WAN codec


def wan_encode(x: jnp.ndarray, k_block: int, block: int = 4096,
               value_dtype: str = "int8"
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for ``wan_codec.wan_encode_pallas`` — identical semantics.

    Per contiguous block: select the ``k_block`` largest elements by
    magnitude *truncated to the top 16 bits* (``wan_codec.KEY_MASK``; ties by
    lowest index — ``lax.top_k`` is stable), order winners by ascending
    index, and encode them against the block's ``max|x|`` scale on the
    requested tier: int8 (``max|x|/127`` step), fp8-e4m3 (block max mapped
    to 448, bit pattern shipped), or int4 (``max|x|/7`` step, nibble-packed
    two codes per byte).  Returns (payload, block-local idx int32, per-block
    scales f32); the payload dtype/shape per tier matches the kernel
    wrapper's wire format exactly.
    """
    from repro.kernels.wan_codec import (FP8_MAX, INV_7, INV_127,
                                         INV_FP8_MAX, KEY_MASK, VALUE_DTYPES,
                                         pack_nibbles)

    if value_dtype not in VALUE_DTYPES:
        raise ValueError(f"unknown value_dtype {value_dtype!r} "
                         f"(expected one of {VALUE_DTYPES})")
    n = x.shape[0]
    block = min(block, n)
    k_block = min(k_block, block)
    pad = (-n) % block
    xb = jnp.pad(x, (0, pad)).reshape(-1, block).astype(jnp.float32)
    mag = jnp.abs(xb)
    keys = jax.lax.bitcast_convert_type(mag, jnp.int32) & KEY_MASK
    _, loc = jax.lax.top_k(keys, k_block)               # ties -> lowest index
    loc = jnp.sort(loc, axis=1)                         # ascending-index order
    vals = jnp.take_along_axis(xb, loc, axis=1)
    maxabs = jnp.max(mag, axis=1)
    if value_dtype == "int8":
        scales = jnp.where(maxabs > 0, maxabs * jnp.float32(INV_127), 1.0)
        q = jnp.clip(jnp.round(vals / scales[:, None]), -127.0, 127.0
                     ).astype(jnp.int8)
    elif value_dtype == "int4":
        scales = jnp.where(maxabs > 0, maxabs * jnp.float32(INV_7), 1.0)
        q = pack_nibbles(jnp.clip(jnp.round(vals / scales[:, None]),
                                  -7.0, 7.0).astype(jnp.int8))
    else:                                               # fp8-e4m3
        scales = jnp.where(maxabs > 0, maxabs * jnp.float32(INV_FP8_MAX), 1.0)
        f8 = jnp.clip(vals / scales[:, None], -FP8_MAX, FP8_MAX
                      ).astype(jnp.float8_e4m3fn)
        q = jax.lax.bitcast_convert_type(f8, jnp.int8)
    return (q.reshape(-1), loc.astype(jnp.int32).reshape(-1), scales)


def wan_decode(q: jnp.ndarray, idx: jnp.ndarray, scales: jnp.ndarray,
               n: int, block: int = 4096,
               value_dtype: str = "int8") -> jnp.ndarray:
    """Oracle for ``wan_codec.wan_decode_pallas`` -> dense (n,) fp32."""
    from repro.kernels.wan_codec import unpack_nibbles

    block = min(block, n)
    nb = scales.shape[0]
    k_block = idx.shape[0] // nb
    if value_dtype == "int4":
        codes = unpack_nibbles(q.reshape(nb, -1), k_block
                               ).astype(jnp.float32)
    elif value_dtype == "fp8":
        codes = jax.lax.bitcast_convert_type(
            q.reshape(nb, -1), jnp.float8_e4m3fn).astype(jnp.float32)
    else:
        codes = q.reshape(nb, -1).astype(jnp.float32)
    v = codes * scales[:, None]
    il = idx.reshape(nb, -1)
    rows = jnp.arange(nb)[:, None]
    dense = jnp.zeros((nb, block), jnp.float32).at[rows, il].set(v)
    return dense.reshape(-1)[:n]
