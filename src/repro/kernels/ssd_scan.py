"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Implements the state-space-duality decomposition (arXiv:2405.21060) with
explicit VMEM tiling: grid ``(B, H, n_chunks)`` where the chunk axis is the
sequential (innermost) TPU grid dimension.  Per (batch, head) the recurrent
state ``(P, N)`` lives in a fp32 VMEM scratch that persists across the chunk
sweep — the TPU-native replacement for the GPU kernel's warp-parallel
associative scan: on TPU the cross-chunk recurrence is cheap (one (P,N)
FMA per chunk) while all heavy lifting is dense (L,N)x(N,L)/(L,L)x(L,P)
matmuls that map straight onto the MXU.

Per chunk (all fp32 in VMEM):
    a_cum   = cumsum(a)                     # (L,)  log-decay prefix
    Lmat    = tril(exp(segsum(a)))          # (L, L) intra-chunk decay
    y_diag  = ((C B^T) * Lmat) x            # dense intra-chunk term
    y_off   = (C state^T) * exp(a_cum)      # contribution of carried state
    state   = state * exp(a_cum[-1]) + x^T (B * exp(a_cum[-1] - a_cum))
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(x_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sf_ref, state_ref, *,
            n_chunks: int, L: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)   # (P, N)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (L, P)
    a = a_ref[0, :, 0].astype(jnp.float32)       # (L,)
    B = b_ref[0, :, 0, :].astype(jnp.float32)    # (L, N)
    C = c_ref[0, :, 0, :].astype(jnp.float32)    # (L, N)

    a_cum = jnp.cumsum(a)                        # (L,)
    # segsum: seg[i, j] = a_cum[i] - a_cum[j], valid for j <= i
    seg = a_cum[:, None] - a_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # segsum over (j, i] excludes a[j] itself (inclusive-cumsum difference);
    # diagonal = exp(0) = 1.
    Lmat = jnp.where(jj <= ii, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (L, L)
    y_diag = jax.lax.dot_general(scores * Lmat, x, (((1,), (0,)), ((), ())))

    state = state_ref[...]                       # (P, N)
    y_off = jax.lax.dot_general(C, state, (((1,), (1,)), ((), ()))) \
        * jnp.exp(a_cum)[:, None]                # (L, P)

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    decay_in = jnp.exp(a_cum[-1] - a_cum)        # (L,)
    new_state = state * jnp.exp(a_cum[-1]) + jax.lax.dot_general(
        x, B * decay_in[:, None], (((0,), (0,)), ((), ())))        # (P, N)
    state_ref[...] = new_state

    @pl.when(ci == n_chunks - 1)
    def _final():
        sf_ref[0, 0] = new_state.astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,       # (B, S, H, P) — inputs pre-multiplied by dt
    a: jnp.ndarray,       # (B, S, H)    — per-step log decay (A*dt <= 0)
    Bm: jnp.ndarray,      # (B, S, H, N)
    Cm: jnp.ndarray,      # (B, S, H, N)
    *,
    chunk: int = 256,
    init_state: Optional[jnp.ndarray] = None,   # (B, H, P, N)
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y: (B,S,H,P) in x.dtype, final_state: (B,H,P,N) fp32)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    kernel = functools.partial(_kernel, n_chunks=nc, L=L)
    y, sf = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a, Bm, Cm, init_state.astype(jnp.float32))
    return y, sf
