"""Fused WAN payload codec: single-pass block-local top-k + int8 quantization.

This is the production encode/decode pair for compressed inter-pod gradient
shipping (``repro.core.sync``).  It supersedes the iterative-argmax kernel in
``topk_compress.py`` (kept there as the benchmark baseline), whose inner
``fori_loop`` serializes O(k_block) argmax+scatter rounds per block — the
exact anti-pattern for the 8x128 VPU once k grows with the block.

Selection algorithm (threshold refinement, no O(k) serialization):

1. Bitcast ``|x|`` to int32.  Non-negative IEEE-754 floats order identically
   to their bit patterns, so magnitude rank == integer rank.
2. Truncate to the top 16 of the 31 magnitude bits (8 exponent + 8 mantissa).
   Under int8 payload quantization a finer sort key is pure waste: the
   truncation perturbs selection only among elements whose magnitudes agree
   to ~2^-8 relative — far below the quantizer's own resolution of 1/127 —
   and error feedback re-injects whatever the coarser boundary drops.
3. Build the k-th-largest key threshold bit-by-bit: 16 branch-free rounds of
   ``count(keys >= candidate)``, each a fully vectorized compare+reduce over
   the whole tile.  Work is O(16 * block) independent of k.
4. Select ``keys > T`` plus the first (by index) ties at ``T``; exact ranks
   come from a cumulative sum — again vectorized, never serialized.
5. Compact the winners with a one-hot dot product (the TPU-native scatter:
   MXU contraction instead of unsupported vector scatters).  Each one-hot
   column has exactly one nonzero, so fp32 accumulation is exact; local
   indices stay < block <= 2^16, exactly representable in fp32.
6. Quantize the selected values to int8 against a per-block scale
   ``max|x| / 127`` — fused into the same kernel, so the fp32 payload never
   round-trips through HBM.

Tile geometry: each grid step processes ``rows_per_step`` independent blocks
as a 2D (rows, block) tile — the VPU-natural sublane x lane layout.  All of
the selection math above batches trivially over the row dimension, so one
kernel dispatch selects/quantizes several blocks (amortizing grid overhead
the same way the sync layer's bucketing amortizes per-leaf dispatch).

Wire format per block of ``block`` elements: ``k_block`` encoded values +
``k_block`` block-local indices (< 2^16, i.e. u16 on the wire; int32 in
device memory) + one fp32 scale.  The **value encoding** is a precision
ladder (``value_dtype``):

- ``"int8"``  — 1 byte/value, ``q = clip(round(x / (max|x|/127)))``.
- ``"fp8"``   — 1 byte/value, IEEE fp8-e4m3 (4 exponent + 3 mantissa bits,
  finite-only, max 448): the block is scaled so ``max|x|`` lands on 448,
  then cast to ``float8_e4m3fn`` and shipped as the raw bit pattern.  Same
  bytes as int8 but relative (not absolute) rounding error — robust to
  heavy-tailed blocks where one outlier crushes int8's uniform step.
- ``"int4"``  — 0.5 byte/value, ``q = clip(round(x / (max|x|/7)))`` packed
  two to a byte (low nibble first, two's complement).  Odd ``k_block``
  pads one zero nibble per block.

At k/n = 1% and block 4096 int8 is ~0.77% of the dense fp32 bytes and int4
~0.65% — the ``SyncConfig.payload_mb`` math.

``ref.wan_encode`` / ``ref.wan_decode`` are the pure-jnp oracles with
bit-identical semantics (same truncated sort key, same tie-breaking, same
quantizers), so round-trip tests assert exact equality, not allclose.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# keep the top 16 of the 31 magnitude bits (sign bit of |x| is always 0):
# bits 30..23 exponent, 22..15 top mantissa byte
KEY_MASK = ~((1 << 15) - 1)
_N_KEY_BITS = 16                       # threshold-refinement rounds (bits 30..15)

# scale = maxabs * fl32(1/Q), NOT maxabs / Q: XLA rewrites constant
# divides to reciprocal multiplies in some fusion contexts but not others,
# which costs 1 ulp of kernel-vs-oracle exactness; an explicit multiply is
# never transformed, so both sides round identically
INV_127 = 1.0 / 127.0                  # int8 tier: q in [-127, 127]
INV_7 = 1.0 / 7.0                      # int4 tier: q in [-7, 7]
FP8_MAX = 448.0                        # fp8-e4m3 largest finite value
INV_FP8_MAX = 1.0 / 448.0

VALUE_DTYPES = ("int8", "fp8", "int4")  # the codec's precision ladder

DEFAULT_BLOCK = 4096
DEFAULT_ROWS = 8                       # blocks per grid step (VMEM-bounded)

# the (rows, block, k_block) fp32 one-hot tile is the kernels' VMEM
# high-water mark; cap it so the compiled TPU path fits comfortably under
# the ~16 MB/core budget at ANY compress fraction (rows degrades toward 1
# as k_block grows — the selection math is per-row, so tiling is free)
_ONEHOT_BUDGET_BYTES = 8 << 20


def k_per_block(block: int, frac: float) -> int:
    """Per-block winner count for a target compression fraction."""
    return max(1, min(block, int(round(block * frac))))


def _cap_rows(rows: int, block: int, k_block: int) -> int:
    return max(1, min(rows, _ONEHOT_BUDGET_BYTES // (4 * block * k_block)))


def _select_mask(x: jnp.ndarray, k_block: int):
    """Exact block-local top-k selection over a (rows, block) tile.

    Returns (mask bool, pos int32) both (rows, block), and maxabs (rows,).
    Selection key: |x| truncated to KEY_MASK bits; ties broken by lowest
    index (matching ``jax.lax.top_k``'s stable ordering in the oracle).
    """
    mag = jnp.abs(x)
    bits = jax.lax.bitcast_convert_type(mag, jnp.int32) & KEY_MASK

    # threshold refinement: per row, largest T with count(bits >= T) >=
    # k_block, built bit-by-bit over the 16 key bits — branch-free
    # compare+reduce on the full tile each round
    def refine(i, t):
        cand = t | (jnp.int32(1) << (30 - i))
        cnt = jnp.sum((bits >= cand[:, None]).astype(jnp.int32), axis=1)
        return jnp.where(cnt >= k_block, cand, t)

    thresh = jax.lax.fori_loop(
        0, _N_KEY_BITS, refine, jnp.zeros((x.shape[0],), jnp.int32))

    above = bits > thresh[:, None]
    n_above = jnp.sum(above.astype(jnp.int32), axis=1)
    at = bits == thresh[:, None]
    # first (k_block - n_above) ties by index, exactly filling k_block
    tie_rank = jnp.cumsum(at.astype(jnp.int32), axis=1) - 1
    mask = above | (at & (tie_rank < (k_block - n_above)[:, None]))
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1   # slot, by index
    return mask, pos, jnp.max(mag, axis=1)


def _quantize(vals: jnp.ndarray, maxabs: jnp.ndarray, value_dtype: str
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tier value encoding of a (rows, k_block) tile of selected values.

    Returns (q int8, scale f32 (rows,)).  ``q`` is always an int8 *container*:
    the int4 tier's [-7, 7] codes are nibble-packed by the wrapper (packing is
    a pure bit shuffle, not kernel work), the fp8 tier ships the e4m3 bit
    pattern bitcast to int8.  All three run identically in the oracle — the
    expressions below are the bit-level spec.
    """
    if value_dtype == "int8":
        scale = jnp.where(maxabs > 0, maxabs * jnp.float32(INV_127), 1.0)
        q = jnp.clip(jnp.round(vals / scale[:, None]), -127.0, 127.0)
        return q.astype(jnp.int8), scale
    if value_dtype == "int4":
        scale = jnp.where(maxabs > 0, maxabs * jnp.float32(INV_7), 1.0)
        q = jnp.clip(jnp.round(vals / scale[:, None]), -7.0, 7.0)
        return q.astype(jnp.int8), scale
    if value_dtype == "fp8":
        # map the block max onto e4m3's largest finite value, clip the 1-ulp
        # overshoot the fp32 reciprocal can introduce, ship the bit pattern
        scale = jnp.where(maxabs > 0, maxabs * jnp.float32(INV_FP8_MAX), 1.0)
        f8 = jnp.clip(vals / scale[:, None], -FP8_MAX, FP8_MAX
                      ).astype(jnp.float8_e4m3fn)
        return jax.lax.bitcast_convert_type(f8, jnp.int8), scale
    raise ValueError(f"unknown value_dtype {value_dtype!r} "
                     f"(expected one of {VALUE_DTYPES})")


def _dequantize(q: jnp.ndarray, scales: jnp.ndarray, value_dtype: str
                ) -> jnp.ndarray:
    """Inverse of :func:`_quantize` ((rows, k) int8 container -> f32)."""
    if value_dtype == "fp8":
        v = jax.lax.bitcast_convert_type(q, jnp.float8_e4m3fn
                                         ).astype(jnp.float32)
    else:                                   # int8 / (unpacked) int4 codes
        v = q.astype(jnp.float32)
    return v * scales[..., None]


def pack_nibbles(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int4 codes (.., k) int8 in [-7, 7] -> (.., ceil(k/2)) uint8.

    Low nibble first, two's complement; odd ``k`` pads one zero nibble."""
    k = q.shape[-1]
    if k % 2:
        q = jnp.concatenate(
            [q, jnp.zeros(q.shape[:-1] + (1,), q.dtype)], axis=-1)
    lo = q[..., 0::2].astype(jnp.uint8) & 0xF
    hi = q[..., 1::2].astype(jnp.uint8) & 0xF
    return lo | (hi << 4)


def unpack_nibbles(p: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of :func:`pack_nibbles`: (.., ceil(k/2)) uint8 -> (.., k) int8."""
    lo = (p & 0xF).astype(jnp.int32)
    hi = ((p >> 4) & 0xF).astype(jnp.int32)
    pairs = jnp.stack([lo, hi], axis=-1).reshape(p.shape[:-1] + (-1,))
    signed = jnp.where(pairs < 8, pairs, pairs - 16)
    return signed[..., :k].astype(jnp.int8)


def _encode_kernel(x_ref, q_ref, idx_ref, scale_ref, *, k_block: int,
                   block: int, rows: int, value_dtype: str):
    x = x_ref[...].astype(jnp.float32)                  # (rows, block)
    mask, pos, maxabs = _select_mask(x, k_block)

    # one-hot compaction: (rows, block, k_block) with exactly one 1 per
    # output column -> the batched dot is an exact gather on the MXU
    slots = jax.lax.broadcasted_iota(jnp.int32, (rows, block, k_block), 2)
    onehot = (mask[..., None] & (pos[..., None] == slots)).astype(jnp.float32)
    dims = (((1,), (1,)), ((0,), (0,)))                 # contract block, batch rows
    vals = jax.lax.dot_general(onehot, x, dims,
                               preferred_element_type=jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.float32, (rows, block), 1)
    idxf = jax.lax.dot_general(onehot, iota, dims,      # exact: < 2^16 < 2^24
                               preferred_element_type=jnp.float32)

    q, scale = _quantize(vals, maxabs, value_dtype)

    q_ref[...] = q
    idx_ref[...] = idxf.astype(jnp.int32)
    scale_ref[...] = scale


def _decode_kernel(q_ref, idx_ref, scale_ref, out_ref, *, block: int,
                   rows: int, value_dtype: str):
    v = _dequantize(q_ref[...], scale_ref[...], value_dtype)
    idx = idx_ref[...]                                  # (rows, k_block)
    # transpose of the encode compaction: one nonzero per column -> exact
    cols = jax.lax.broadcasted_iota(jnp.int32, (rows, block, idx.shape[1]), 1)
    onehot = (cols == idx[:, None, :]).astype(jnp.float32)
    out_ref[...] = jax.lax.dot_general(
        onehot, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def _geometry(n: int, block: int, rows: int, k_block: int
              ) -> Tuple[int, int, int, int]:
    """(block, rows, nb_real, nb_padded): pad n up to whole (rows x block)
    tiles; padded blocks are all-zero and sliced off the outputs.  ``rows``
    is capped by the one-hot VMEM budget (tiling never changes results)."""
    block = min(block, n)
    nb = -(-n // block)
    rows = min(_cap_rows(rows, block, min(k_block, block)), nb)
    nb_pad = -(-nb // rows) * rows
    return block, rows, nb, nb_pad


@functools.partial(jax.jit,
                   static_argnames=("k_block", "block", "rows", "value_dtype",
                                    "interpret"))
def wan_encode_pallas(
    x: jnp.ndarray, k_block: int, *, block: int = DEFAULT_BLOCK,
    rows: int = DEFAULT_ROWS, value_dtype: str = "int8",
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: flat (n,) -> (payload, local idx int32 (nb*k_block,), scales f32
    (nb,)); nb = ceil(n / block).  Payload: int8 (nb*k_block,) for
    int8/fp8 (fp8 ships its bit pattern), uint8 (nb*ceil(k_block/2),)
    nibble-packed for int4."""
    n = x.shape[0]
    block, rows, nb, nb_pad = _geometry(n, block, rows, k_block)
    k_block = min(k_block, block)
    xp = jnp.pad(x, (0, nb_pad * block - n)).reshape(nb_pad, block)

    q, idx, scales = pl.pallas_call(
        functools.partial(_encode_kernel, k_block=k_block, block=block,
                          rows=rows, value_dtype=value_dtype),
        grid=(nb_pad // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda b: (b, 0))],
        out_specs=[pl.BlockSpec((rows, k_block), lambda b: (b, 0)),
                   pl.BlockSpec((rows, k_block), lambda b: (b, 0)),
                   pl.BlockSpec((rows,), lambda b: (b,))],
        out_shape=[jax.ShapeDtypeStruct((nb_pad, k_block), jnp.int8),
                   jax.ShapeDtypeStruct((nb_pad, k_block), jnp.int32),
                   jax.ShapeDtypeStruct((nb_pad,), jnp.float32)],
        interpret=interpret,
    )(xp)
    q, idx, scales = q[:nb], idx.reshape(-1)[:nb * k_block], scales[:nb]
    if value_dtype == "int4":
        q = pack_nibbles(q)          # per-block rows -> wire bytes
    return q.reshape(-1), idx, scales


@functools.partial(jax.jit,
                   static_argnames=("n", "block", "rows", "value_dtype",
                                    "interpret"))
def wan_decode_pallas(
    q: jnp.ndarray, idx: jnp.ndarray, scales: jnp.ndarray, n: int, *,
    block: int = DEFAULT_BLOCK, rows: int = DEFAULT_ROWS,
    value_dtype: str = "int8", interpret: bool = False,
) -> jnp.ndarray:
    """Inverse of :func:`wan_encode_pallas` -> dense (n,) fp32."""
    # k_block from the index array — the int4 payload is nibble-packed, so
    # q's length is not k_block-shaped for every tier
    k_block = idx.shape[0] // (-(-n // min(block, n)))
    block, rows, nb, nb_pad = _geometry(n, block, rows, k_block)
    if value_dtype == "int4":
        q = unpack_nibbles(q.reshape(nb, -1), k_block)

    def pad_rows(a, fill=0):
        a = a.reshape(nb, -1)
        return jnp.pad(a, ((0, nb_pad - nb), (0, 0)), constant_values=fill)

    dense = pl.pallas_call(
        functools.partial(_decode_kernel, block=block, rows=rows,
                          value_dtype=value_dtype),
        grid=(nb_pad // rows,),
        in_specs=[pl.BlockSpec((rows, k_block), lambda b: (b, 0)),
                  pl.BlockSpec((rows, k_block), lambda b: (b, 0)),
                  pl.BlockSpec((rows,), lambda b: (b,))],
        out_specs=pl.BlockSpec((rows, block), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb_pad, block), jnp.float32),
        interpret=interpret,
    )(pad_rows(q), pad_rows(idx), jnp.pad(scales, (0, nb_pad - nb)))
    return dense.reshape(-1)[:n]
