"""Flash attention Pallas TPU kernel.

Blockwise attention with online softmax, VMEM-tiled via explicit BlockSpecs.
Supports causal masking, sliding windows (gemma2/gemma3 local layers), logit
soft-capping (gemma2) and GQA (kv-head blocks indexed by query-head //
group-size, so K/V are never materialized per query head).

TPU adaptation notes: block shapes default to (128, 128) so the QK^T and PV
matmuls hit the MXU at its native tile; the softmax statistics (m, l) and
the output accumulator live in VMEM scratch in fp32 and persist across the
key-block grid dimension (TPU grids iterate sequentially over the last axis,
which is what replaces the CUDA thread-block loop of the original flash
attention).  Fully-masked key blocks (beyond the causal frontier or outside
the sliding window) are skipped with ``pl.when`` rather than warp-level
early-exit.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: float, block_q: int, block_k: int, n_kb: int,
            seq_q: int, seq_k: int):
    qi = pl.program_id(2)   # query-block index
    ki = pl.program_id(3)   # key-block index

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # block-level skip: is any (q, k) pair in this tile visible?
    lo_vis = True
    if causal:
        lo_vis = (ki * block_k) <= (qi * block_q + block_q - 1)
    hi_vis = True
    if window is not None:
        hi_vis = (ki * block_k + block_k - 1) > (qi * block_q - window)

    @pl.when(jnp.logical_and(lo_vis, hi_vis))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, Dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, Dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)       # (bk, Dh)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap and softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        mask = (k_pos < seq_k) & (q_pos < seq_q)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (all NEG_INF)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)

        p = jnp.exp(s - m_safe)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))

        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention(
    q: jnp.ndarray,                 # (B, Sq, H, Dh)
    k: jnp.ndarray,                 # (B, Sk, K, Dh)
    v: jnp.ndarray,                 # (B, Sk, K, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blockwise flash attention; returns (B, Sq, H, Dh) in q.dtype."""
    B, Sq, H, Dh = q.shape
    Kh = k.shape[2]
    assert H % Kh == 0, (H, Kh)
    group = H // Kh
    scale = 1.0 / math.sqrt(Dh)

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(k.shape[1], 8))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    n_qb = qp.shape[1] // bq
    n_kb = kp.shape[1] // bk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, n_kb=n_kb, seq_q=Sq, seq_k=k.shape[1])

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
            pl.BlockSpec((1, bk, 1, Dh),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, qp.shape[1], H, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),   # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
