"""Jitted public wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled; on CPU
(this container) they run via ``interpret=True`` when explicitly requested,
and otherwise fall back to the pure-jnp oracle (same numerics, fast enough
for tests/examples).  Model code selects the path with
``ModelConfig.attention_impl`` and the ``use_kernel`` flags.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas
from repro.kernels.topk_compress import topk_compress_pallas
from repro.kernels.wan_codec import wan_decode_pallas, wan_encode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, softcap: float = 0.0,
                    bias=None, interpret: bool = False) -> jnp.ndarray:
    """Blockwise flash attention (Pallas on TPU / interpret / jnp oracle)."""
    del bias  # masks are derived from causal/window inside the kernel
    if _on_tpu() or interpret:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             softcap=softcap, interpret=not _on_tpu())
    return _ref.sdpa(q, k, v, causal=causal, window=window, softcap=softcap)


def ssd_scan(x, a, Bm, Cm, *, chunk: int = 256, init_state=None,
             interpret: bool = False):
    if _on_tpu() or interpret:
        return _ssd_pallas(x, a, Bm, Cm, chunk=chunk, init_state=init_state,
                           interpret=not _on_tpu())
    return _ref.ssd(x, a, Bm, Cm, chunk=chunk, init_state=init_state)


def topk_compress(x: jnp.ndarray, k: int, *, block: int = 1024,
                  use_kernel: bool = False, interpret: bool = False
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if use_kernel and (_on_tpu() or interpret):
        return topk_compress_pallas(x, k, block=block,
                                    interpret=not _on_tpu())
    return _ref.topk_block(x, k, block=block)


def topk_decompress(vals: jnp.ndarray, idx: jnp.ndarray, n: int) -> jnp.ndarray:
    return _ref.topk_decompress(vals, idx, n)


def wan_encode(x: jnp.ndarray, k_block: int, *, block: int = 4096,
               value_dtype: str = "int8", use_kernel: bool = True,
               interpret: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused WAN codec encode: block-local top-k + value quantization on the
    int8/fp8/int4 tier ladder (kernel or oracle).

    The kernel and oracle are bit-identical, so the choice is pure dispatch
    policy: compiled Pallas on TPU, oracle on CPU unless ``interpret``."""
    if use_kernel and (_on_tpu() or interpret):
        return wan_encode_pallas(x, k_block, block=block,
                                 value_dtype=value_dtype,
                                 interpret=not _on_tpu())
    return _ref.wan_encode(x, k_block, block=block, value_dtype=value_dtype)


def wan_decode(q: jnp.ndarray, idx: jnp.ndarray, scales: jnp.ndarray,
               n: int, *, block: int = 4096, value_dtype: str = "int8",
               use_kernel: bool = True, interpret: bool = False
               ) -> jnp.ndarray:
    if use_kernel and (_on_tpu() or interpret):
        return wan_decode_pallas(q, idx, scales, n, block=block,
                                 value_dtype=value_dtype,
                                 interpret=not _on_tpu())
    return _ref.wan_decode(q, idx, scales, n, block=block,
                           value_dtype=value_dtype)


def wan_codec_fns(*, block: int = 4096, value_dtype: str = "int8",
                  use_kernel: bool = True, interpret: bool = False):
    """Bind one bucket group's codec knobs; returns ``(encode, decode)``.

    The multi-bucket sync path dispatches each bucket group's contiguous
    segment through its own pair — one dispatch decision per (block, tier)
    combination instead of one per call site, and the single place where a
    backend could swap in tier-specialized kernels per bucket.

    ``encode(x, k_block) -> (q, idx, scales)``;
    ``decode(q, idx, scales, n) -> dense``.
    """
    if value_dtype not in ("int8", "fp8", "int4"):
        raise ValueError(f"unknown codec value_dtype {value_dtype!r}")

    def encode(x: jnp.ndarray, k_block: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        return wan_encode(x, k_block, block=block, value_dtype=value_dtype,
                          use_kernel=use_kernel, interpret=interpret)

    def decode(q: jnp.ndarray, idx: jnp.ndarray, scales: jnp.ndarray,
               n: int) -> jnp.ndarray:
        return wan_decode(q, idx, scales, n, block=block,
                          value_dtype=value_dtype, use_kernel=use_kernel,
                          interpret=interpret)

    return encode, decode
