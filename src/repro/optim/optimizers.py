"""Minimal pytree optimizers (no optax dependency).

The paper trains with SGD (its PS update rule and both sync strategies are
defined over SGD), so ``sgd``/``momentum`` are the paper-faithful choices and
the memory-planning default for the trillion-parameter configs; ``adamw`` is
provided for the modern-LLM training path.  Optimizer states follow the
parameter sharding (the launcher shards them with the same logical axes), and
their dtype is configurable (bf16 momentum halves optimizer HBM — used by the
kimi-k2 plan).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree, jnp.ndarray], Tuple[Pytree, Pytree]]
    # update(grads, opt_state, params, lr) -> (new_params, new_opt_state)


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree.map(
            lambda p, g: _cast_like(
                p.astype(jnp.float32) - lr * g.astype(jnp.float32), p),
            params, grads)
        return new, state

    return Optimizer("sgd", init, update)


def momentum(beta: float = 0.9, state_dtype: str = "float32",
             nesterov: bool = False) -> Optimizer:
    sdt = jnp.dtype(state_dtype)

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params)

    def update(grads, state, params, lr):
        new_m = jax.tree.map(
            lambda m, g: _cast_like(beta * m.astype(jnp.float32)
                                    + g.astype(jnp.float32), m),
            state, grads)
        if nesterov:
            step = jax.tree.map(
                lambda g, m: g.astype(jnp.float32) + beta * m.astype(jnp.float32),
                grads, new_m)
        else:
            step = jax.tree.map(lambda m: m.astype(jnp.float32), new_m)
        new_p = jax.tree.map(
            lambda p, s: _cast_like(p.astype(jnp.float32) - lr * s, p),
            params, step)
        return new_p, new_m

    return Optimizer(f"momentum{beta}", init, update)


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jnp.ndarray


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype: str = "float32") -> Optimizer:
    sdt = jnp.dtype(state_dtype)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, sdt)  # noqa: E731
        return AdamState(mu=jax.tree.map(z, params),
                         nu=jax.tree.map(z, params),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        count = state.count + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        new_mu = jax.tree.map(
            lambda m, g: _cast_like(b1 * m.astype(jnp.float32)
                                    + (1 - b1) * g.astype(jnp.float32), m),
            state.mu, grads)
        new_nu = jax.tree.map(
            lambda v, g: _cast_like(b2 * v.astype(jnp.float32)
                                    + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                                    v),
            state.nu, grads)

        def upd(p, m, v):
            mh = m.astype(jnp.float32) / c1
            vh = v.astype(jnp.float32) / c2
            step = mh / (jnp.sqrt(vh) + eps)
            if weight_decay and p.ndim >= 2:
                step = step + weight_decay * p.astype(jnp.float32)
            return _cast_like(p.astype(jnp.float32) - lr * step, p)

        new_p = jax.tree.map(upd, params, new_mu, new_nu)
        return new_p, AdamState(new_mu, new_nu, count)

    return Optimizer(f"adamw{b1},{b2}", init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](**kw)


# ---------------------------------------------------------------------------
# learning-rate schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup: int, total: int,
                           floor: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return fn


def global_norm(tree: Pytree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Pytree, max_norm: float) -> Pytree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree)
