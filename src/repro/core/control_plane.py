"""Control plane: the serverless orchestration layer (paper §III.A, §IV).

Reimplements the paper's OpenFaaS customizations as an in-process runtime:

- **FunctionRegistry / AddressTable** — the paper extends OpenFaaS with a
  function addressing table storing ``identity, name, namespace, endpoint``
  per replica, with *dynamic* endpoint updates.  Reproduced exactly,
  including re-registration (endpoint churn) semantics.
- **Workflow / WorkflowEngine** — OpenFaaS is extended with DAG workflows;
  the gateway recognizes workflow invocations and invokes internal
  functions.  Reproduced as a topological executor with per-function scale
  (replica) counts and lifecycle hooks (serverless scale-to-zero on finish).
- **SchedulerFunction** — the control-plane cloud function that loads the
  elastic scheduling strategy (Algorithm 1), generates per-cloud training
  plans and invokes the per-cloud sub-workflows.
- **CommunicatorFunction** — the *global communicator*: waits for every
  cloud's PS to register, assigns a unique WAN identity ``<IP, Port>`` per
  PS communicator, and plans the inter-PS communication topology (each PS
  sends to exactly one peer per round — a ring).

On TPU this layer runs at *plan time*: its outputs (resource plans, ring
topology, sync schedule) parameterize the SPMD launcher (`repro.launch`).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.scheduler import CloudResources, ResourcePlan, optimal_matching
from repro.core.sync import SyncConfig

# ---------------------------------------------------------------------------
# function registry + addressing (OpenFaaS customization #2)
# ---------------------------------------------------------------------------


@dataclass
class FunctionReplica:
    identity: str                # unique replica identity
    name: str                    # function name
    namespace: str               # cloud/region namespace
    endpoint: str                # dynamic endpoint (host:port)
    state: str = "ready"         # ready | running | terminated


class AddressTable:
    """identity -> replica record, with real-time endpoint updates."""

    def __init__(self):
        self._by_identity: Dict[str, FunctionReplica] = {}

    def register(self, rep: FunctionReplica) -> None:
        self._by_identity[rep.identity] = rep

    def update_endpoint(self, identity: str, endpoint: str) -> None:
        self._by_identity[identity].endpoint = endpoint

    def resolve(self, identity: str) -> str:
        rep = self._by_identity[identity]
        if rep.state == "terminated":
            raise LookupError(f"replica {identity} terminated")
        return rep.endpoint

    def lookup(self, *, name: Optional[str] = None,
               namespace: Optional[str] = None) -> List[FunctionReplica]:
        out = []
        for rep in self._by_identity.values():
            if name is not None and rep.name != name:
                continue
            if namespace is not None and rep.namespace != namespace:
                continue
            out.append(rep)
        return out

    def terminate(self, identity: str) -> None:
        self._by_identity[identity].state = "terminated"

    def __len__(self):
        return sum(1 for r in self._by_identity.values() if r.state != "terminated")


class FunctionRegistry:
    """Deployable cloud functions (name -> callable) per namespace."""

    def __init__(self):
        self._fns: Dict[Tuple[str, str], Callable] = {}
        self.addresses = AddressTable()
        self._ids = itertools.count()

    def deploy(self, namespace: str, name: str, fn: Callable) -> str:
        self._fns[(namespace, name)] = fn
        identity = f"{namespace}/{name}#{next(self._ids)}"
        self.addresses.register(FunctionReplica(
            identity=identity, name=name, namespace=namespace,
            endpoint=f"{namespace}.faas:{8000 + len(self.addresses)}"))
        return identity

    def invoke(self, namespace: str, name: str, *args, **kw):
        key = (namespace, name)
        if key not in self._fns:
            raise LookupError(f"function {name!r} not deployed in {namespace!r}")
        return self._fns[key](*args, **kw)


# ---------------------------------------------------------------------------
# workflow DAG (OpenFaaS customization #1)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkflowNode:
    name: str                      # function name to invoke
    deps: Tuple[str, ...] = ()     # upstream node names
    terminate_after: bool = False  # serverless scale-to-zero on completion


@dataclass
class Workflow:
    """A DAG of cloud functions within one namespace."""

    namespace: str
    nodes: Dict[str, WorkflowNode] = field(default_factory=dict)

    def add(self, name: str, deps: Sequence[str] = (),
            terminate_after: bool = False) -> "Workflow":
        self.nodes[name] = WorkflowNode(name, tuple(deps), terminate_after)
        return self

    def topo_order(self) -> List[str]:
        order, seen, temp = [], set(), set()

        def visit(n: str):
            if n in seen:
                return
            if n in temp:
                raise ValueError(f"workflow cycle at {n!r}")
            temp.add(n)
            for d in self.nodes[n].deps:
                visit(d)
            temp.discard(n)
            seen.add(n)
            order.append(n)

        for n in self.nodes:
            visit(n)
        return order


class WorkflowEngine:
    """Gateway extension: recognizes workflow invocations and drives the DAG."""

    def __init__(self, registry: FunctionRegistry):
        self.registry = registry
        self.history: List[Tuple[str, str]] = []   # (namespace, fn) invocations

    def run(self, wf: Workflow, context: Optional[dict] = None) -> dict:
        ctx = dict(context or {})
        for name in wf.topo_order():
            node = wf.nodes[name]
            self.history.append((wf.namespace, name))
            result = self.registry.invoke(wf.namespace, name, ctx)
            if result is not None:
                ctx[name] = result
            if node.terminate_after:
                for rep in self.registry.addresses.lookup(
                        name=name, namespace=wf.namespace):
                    self.registry.addresses.terminate(rep.identity)
        return ctx


# ---------------------------------------------------------------------------
# control-plane functions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainingRequest:
    """User submission: model definition + training configuration."""

    model: str
    clouds: Tuple[CloudResources, ...]
    sync: SyncConfig = SyncConfig()
    n_iters: int = 100
    global_batch: int = 64


@dataclass(frozen=True)
class TrainingPlan:
    """Scheduler output: one sub-workflow deployment per cloud."""

    request: TrainingRequest
    resource_plans: Tuple[ResourcePlan, ...]
    batch_split: Tuple[int, ...]
    topology: Tuple[Tuple[int, int], ...]   # PS ring (sender -> receiver)
    ps_identities: Tuple[str, ...]          # assigned <IP, Port> per PS


class SchedulerFunction:
    """Responds first to a training request (paper: 'the scheduler function
    responds first, loads the scheduling strategy, generates training plans
    for each cloud, and invocates sub workflows in each cloud')."""

    def __init__(self, strategy: str = "optimal_matching"):
        self.strategy = strategy

    def __call__(self, request: TrainingRequest) -> List[ResourcePlan]:
        if self.strategy == "optimal_matching":
            return optimal_matching(request.clouds)
        if self.strategy == "greedy":   # paper baseline: consume everything
            return [ResourcePlan(c.region, c.devices,
                                 load_power=0.0) for c in request.clouds]
        raise ValueError(self.strategy)


class CommunicatorFunction:
    """The global communicator: assigns WAN identities and plans the
    one-peer-per-round topology."""

    def __init__(self, base_port: int = 50_051):
        self.base_port = base_port
        self._registered: Dict[str, str] = {}   # region -> ps function identity

    def register_ps(self, region: str, identity: str) -> None:
        self._registered[region] = identity

    def ready(self, regions: Sequence[str]) -> bool:
        return all(r in self._registered for r in regions)

    def assign(self, regions: Sequence[str]) -> Tuple[Tuple[str, ...],
                                                      Tuple[Tuple[int, int], ...]]:
        if not self.ready(regions):
            missing = [r for r in regions if r not in self._registered]
            raise RuntimeError(f"PS not ready in: {missing}")
        identities = tuple(
            f"10.0.{i}.1:{self.base_port + i}" for i, _ in enumerate(regions))
        n = len(regions)
        topology = tuple((i, (i + 1) % n) for i in range(n))
        return identities, topology


def build_training_plan(request: TrainingRequest) -> TrainingPlan:
    """Full control-plane startup phase: scheduler -> PS registration ->
    communicator address + topology assignment."""
    scheduler = SchedulerFunction()
    plans = scheduler(request)

    comm = CommunicatorFunction()
    regions = [c.region for c in request.clouds]
    for region in regions:
        comm.register_ps(region, f"{region}/ps#0")
    identities, topology = comm.assign(regions)

    from repro.core.scheduler import plan_batch_split
    powers = [p.load_power * c.data_size  # LP * S = raw compute power
              for p, c in zip(plans, request.clouds)]
    split = plan_batch_split(request.global_batch, powers)

    return TrainingPlan(
        request=request,
        resource_plans=tuple(plans),
        batch_split=tuple(split),
        topology=topology,
        ps_identities=identities,
    )


def reschedule(plan: TrainingPlan,
               new_clouds: Tuple[CloudResources, ...]) -> TrainingPlan:
    """Rescheduling path (paper: the communicator must 'notify each PS in
    preparation or when rescheduling happens'): re-run Algorithm 1 against
    the new resource picture, re-assign WAN identities and re-plan the ring.
    Training state survives via ``repro.checkpoint`` (restore accepts a
    different sharding layout)."""
    request = TrainingRequest(
        model=plan.request.model, clouds=new_clouds, sync=plan.request.sync,
        n_iters=plan.request.n_iters, global_batch=plan.request.global_batch)
    return build_training_plan(request)


def training_workflow(region: str) -> Workflow:
    """The per-cloud physical-training-plane workflow (paper Fig 4): data
    access -> worker training functions -> PS update -> PS communicator,
    with workers terminated immediately after local training finishes."""
    wf = Workflow(namespace=region)
    wf.add("load_data")
    wf.add("workers", deps=["load_data"], terminate_after=True)
    wf.add("ps_update", deps=["workers"])
    wf.add("ps_communicator", deps=["ps_update"])
    return wf
