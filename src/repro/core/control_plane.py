"""Control plane: the serverless orchestration layer (paper §III.A, §IV).

Reimplements the paper's OpenFaaS customizations as an in-process runtime:

- **FunctionRegistry / AddressTable** — the paper extends OpenFaaS with a
  function addressing table storing ``identity, name, namespace, endpoint``
  per replica, with *dynamic* endpoint updates.  Reproduced exactly,
  including re-registration (endpoint churn) semantics.
- **Workflow / WorkflowEngine** — OpenFaaS is extended with DAG workflows;
  the gateway recognizes workflow invocations and invokes internal
  functions.  Reproduced as a topological executor with per-function scale
  (replica) counts and lifecycle hooks (serverless scale-to-zero on finish).
- **SchedulerFunction** — the control-plane cloud function that loads the
  elastic scheduling strategy (Algorithm 1), generates per-cloud training
  plans and invokes the per-cloud sub-workflows.
- **CommunicatorFunction** — the *global communicator*: waits for every
  cloud's PS to register, assigns a unique WAN identity ``<IP, Port>`` per
  PS communicator, and plans the inter-PS communication topology (each PS
  sends to exactly one peer per round — a ring).

On TPU this layer runs at *plan time*: its outputs (resource plans, ring
topology, sync schedule) parameterize the SPMD launcher (`repro.launch`).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.scheduler import (CloudResources, PlanDiff, ResourcePlan,
                                  diff_plans, incremental_matching,
                                  load_power, optimal_matching,
                                  plan_batch_split)
from repro.core.sync import SyncConfig

# ---------------------------------------------------------------------------
# function registry + addressing (OpenFaaS customization #2)
# ---------------------------------------------------------------------------


@dataclass
class FunctionReplica:
    identity: str                # unique replica identity
    name: str                    # function name
    namespace: str               # cloud/region namespace
    endpoint: str                # dynamic endpoint (host:port)
    state: str = "ready"         # ready | running | terminated


class AddressTable:
    """identity -> replica record, with real-time endpoint updates."""

    def __init__(self):
        self._by_identity: Dict[str, FunctionReplica] = {}

    def register(self, rep: FunctionReplica) -> None:
        self._by_identity[rep.identity] = rep

    def update_endpoint(self, identity: str, endpoint: str) -> None:
        self._by_identity[identity].endpoint = endpoint

    def resolve(self, identity: str) -> str:
        rep = self._by_identity[identity]
        if rep.state == "terminated":
            raise LookupError(f"replica {identity} terminated")
        return rep.endpoint

    def lookup(self, *, name: Optional[str] = None,
               namespace: Optional[str] = None) -> List[FunctionReplica]:
        out = []
        for rep in self._by_identity.values():
            if name is not None and rep.name != name:
                continue
            if namespace is not None and rep.namespace != namespace:
                continue
            out.append(rep)
        return out

    def terminate(self, identity: str) -> None:
        self._by_identity[identity].state = "terminated"

    def __len__(self):
        return sum(1 for r in self._by_identity.values() if r.state != "terminated")


class FunctionRegistry:
    """Deployable cloud functions (name -> callable) per namespace."""

    def __init__(self):
        self._fns: Dict[Tuple[str, str], Callable] = {}
        self.addresses = AddressTable()
        self._ids = itertools.count()

    def deploy(self, namespace: str, name: str, fn: Callable) -> str:
        self._fns[(namespace, name)] = fn
        identity = f"{namespace}/{name}#{next(self._ids)}"
        self.addresses.register(FunctionReplica(
            identity=identity, name=name, namespace=namespace,
            endpoint=f"{namespace}.faas:{8000 + len(self.addresses)}"))
        return identity

    def invoke(self, namespace: str, name: str, *args, **kw):
        key = (namespace, name)
        if key not in self._fns:
            raise LookupError(f"function {name!r} not deployed in {namespace!r}")
        return self._fns[key](*args, **kw)


# ---------------------------------------------------------------------------
# workflow DAG (OpenFaaS customization #1)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkflowNode:
    name: str                      # function name to invoke
    deps: Tuple[str, ...] = ()     # upstream node names
    terminate_after: bool = False  # serverless scale-to-zero on completion


@dataclass
class Workflow:
    """A DAG of cloud functions within one namespace."""

    namespace: str
    nodes: Dict[str, WorkflowNode] = field(default_factory=dict)

    def add(self, name: str, deps: Sequence[str] = (),
            terminate_after: bool = False) -> "Workflow":
        self.nodes[name] = WorkflowNode(name, tuple(deps), terminate_after)
        return self

    def topo_order(self) -> List[str]:
        order, seen, temp = [], set(), set()

        def visit(n: str):
            if n in seen:
                return
            if n in temp:
                raise ValueError(f"workflow cycle at {n!r}")
            temp.add(n)
            for d in self.nodes[n].deps:
                visit(d)
            temp.discard(n)
            seen.add(n)
            order.append(n)

        for n in self.nodes:
            visit(n)
        return order


class WorkflowEngine:
    """Gateway extension: recognizes workflow invocations and drives the DAG."""

    def __init__(self, registry: FunctionRegistry):
        self.registry = registry
        self.history: List[Tuple[str, str]] = []   # (namespace, fn) invocations

    def run(self, wf: Workflow, context: Optional[dict] = None) -> dict:
        ctx = dict(context or {})
        for name in wf.topo_order():
            node = wf.nodes[name]
            self.history.append((wf.namespace, name))
            result = self.registry.invoke(wf.namespace, name, ctx)
            if result is not None:
                ctx[name] = result
            if node.terminate_after:
                for rep in self.registry.addresses.lookup(
                        name=name, namespace=wf.namespace):
                    self.registry.addresses.terminate(rep.identity)
        return ctx


# ---------------------------------------------------------------------------
# control-plane functions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainingRequest:
    """User submission: model definition + training configuration."""

    model: str
    clouds: Tuple[CloudResources, ...]
    sync: SyncConfig = SyncConfig()
    n_iters: int = 100
    global_batch: int = 64


@dataclass(frozen=True)
class TrainingPlan:
    """Scheduler output: one sub-workflow deployment per cloud."""

    request: TrainingRequest
    resource_plans: Tuple[ResourcePlan, ...]
    batch_split: Tuple[int, ...]
    topology: Tuple[Tuple[int, int], ...]   # PS ring (sender -> receiver)
    ps_identities: Tuple[str, ...]          # assigned <IP, Port> per PS


class SchedulerFunction:
    """Responds first to a training request (paper: 'the scheduler function
    responds first, loads the scheduling strategy, generates training plans
    for each cloud, and invocates sub workflows in each cloud')."""

    def __init__(self, strategy: str = "optimal_matching"):
        self.strategy = strategy

    def __call__(self, request: TrainingRequest) -> List[ResourcePlan]:
        if self.strategy == "optimal_matching":
            return optimal_matching(request.clouds)
        if self.strategy == "greedy":   # paper baseline: consume everything
            return [ResourcePlan(c.region, c.devices,
                                 load_power=0.0) for c in request.clouds]
        raise ValueError(self.strategy)


class CommunicatorFunction:
    """The global communicator: assigns WAN identities and plans the
    one-peer-per-round topology."""

    def __init__(self, base_port: int = 50_051):
        self.base_port = base_port
        self._registered: Dict[str, str] = {}   # region -> ps function identity

    def register_ps(self, region: str, identity: str) -> None:
        self._registered[region] = identity

    def ready(self, regions: Sequence[str]) -> bool:
        return all(r in self._registered for r in regions)

    def assign(self, regions: Sequence[str]) -> Tuple[Tuple[str, ...],
                                                      Tuple[Tuple[int, int], ...]]:
        if not self.ready(regions):
            missing = [r for r in regions if r not in self._registered]
            raise RuntimeError(f"PS not ready in: {missing}")
        identities = tuple(
            f"10.0.{i}.1:{self.base_port + i}" for i, _ in enumerate(regions))
        n = len(regions)
        topology = tuple((i, (i + 1) % n) for i in range(n))
        return identities, topology


def build_training_plan(request: TrainingRequest) -> TrainingPlan:
    """Full control-plane startup phase: scheduler -> PS registration ->
    communicator address + topology assignment."""
    scheduler = SchedulerFunction()
    plans = scheduler(request)

    comm = CommunicatorFunction()
    regions = [c.region for c in request.clouds]
    for region in regions:
        comm.register_ps(region, f"{region}/ps#0")
    identities, topology = comm.assign(regions)

    powers = [p.load_power * c.data_size  # LP * S = raw compute power
              for p, c in zip(plans, request.clouds)]
    split = plan_batch_split(request.global_batch, powers)

    return TrainingPlan(
        request=request,
        resource_plans=tuple(plans),
        batch_split=tuple(split),
        topology=topology,
        ps_identities=identities,
    )


def reschedule(plan: TrainingPlan,
               new_clouds: Tuple[CloudResources, ...]) -> TrainingPlan:
    """Rescheduling path (paper: the communicator must 'notify each PS in
    preparation or when rescheduling happens'): re-run Algorithm 1 against
    the new resource picture, re-assign WAN identities and re-plan the ring.
    Training state survives via ``repro.checkpoint`` (restore accepts a
    different sharding layout)."""
    request = TrainingRequest(
        model=plan.request.model, clouds=new_clouds, sync=plan.request.sync,
        n_iters=plan.request.n_iters, global_batch=plan.request.global_batch)
    return build_training_plan(request)


# ---------------------------------------------------------------------------
# elasticity engine (paper §III.B "elastic scheduling" made mid-training)
# ---------------------------------------------------------------------------


# the training-plane kinds drive Algorithm-1 re-matching; "load_changed"
# is the serving plane's kind (request-rate shift) and is consumed by the
# ServingElasticityController only — one bus, one event type, two planes
TRAINING_EVENT_KINDS = ("cloud_joined", "cloud_left", "bandwidth_changed",
                        "straggler_detected", "pod_crashed")
EVENT_KINDS = TRAINING_EVENT_KINDS + ("load_changed",)


@dataclass(frozen=True)
class CloudEvent:
    """A runtime change in the multi-cloud resource picture."""

    kind: str                                   # one of EVENT_KINDS
    region: str = ""                            # subject cloud (where relevant)
    time_s: float = 0.0                         # wall/sim time of the event
    resources: Optional[CloudResources] = None  # cloud_joined payload
    bandwidth_mbps: Optional[float] = None      # bandwidth_changed payload
    slowdown: float = 1.0                       # straggler_detected factor (>1)
    rps: Optional[float] = None                 # load_changed payload (req/s)

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}")


class EventDeliveryError(RuntimeError):
    """One or more subscribers raised during ``EventBus.publish``; every
    subscriber still saw the event first.  ``errors`` holds the
    ``(subscriber, exception)`` pairs in delivery order."""

    def __init__(self, event: "CloudEvent", errors: List[Tuple[Callable,
                                                               Exception]]):
        self.event = event
        self.errors = errors
        super().__init__(
            f"{len(errors)} subscriber(s) failed on {event.kind!r}: "
            + "; ".join(repr(e) for _, e in errors))


class EventBus:
    """Tiny in-process pub/sub: the WAN monitor / health checker side of the
    paper's communicator publishes, the ElasticityController subscribes."""

    def __init__(self):
        self._subs: Dict[str, List[Callable]] = {}
        self.history: List[CloudEvent] = []

    def subscribe(self, kind: str, fn: Callable) -> None:
        if kind != "*" and kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r}")
        self._subs.setdefault(kind, []).append(fn)

    def publish(self, event: CloudEvent) -> List:
        """Deliver ``event`` to every subscriber, then surface errors.

        Delivery is isolated: one raising subscriber no longer aborts
        delivery to every later one (on a ``pod_crashed`` that would mean
        part of the control plane never hears about the crash).  A single
        collected error re-raises as itself after the fan-out completes;
        multiple raise one :class:`EventDeliveryError` carrying them all."""
        self.history.append(event)
        out: List = []
        errors: List[Tuple[Callable, Exception]] = []
        for fn in self._subs.get(event.kind, []) + self._subs.get("*", []):
            try:
                out.append(fn(event))
            except Exception as e:   # noqa: BLE001 — isolation is the point
                errors.append((fn, e))
        if errors:
            if len(errors) == 1:
                raise errors[0][1]
            raise EventDeliveryError(event, errors)
        return out


@dataclass(frozen=True)
class ReconfigPlan:
    """Controller output: the old plan, the re-matched plan, and the diff the
    trainer needs to decide whether (and how) to re-stack pods."""

    event: CloudEvent
    old: TrainingPlan
    new: TrainingPlan
    diff: PlanDiff

    @property
    def is_noop(self) -> bool:
        return (self.diff.is_empty
                and self.new.batch_split == self.old.batch_split
                and self.new.request.sync == self.old.request.sync
                and self.new.topology == self.old.topology)

    def pod_transition(self) -> Tuple[Tuple[int, ...], int]:
        """(keep, n_new): old pod indices that survive, in new pod order, and
        the new pod count — the arguments of the trainer's re-stacking."""
        old_regions = [p.region for p in self.old.resource_plans]
        new_regions = [p.region for p in self.new.resource_plans]
        keep = tuple(old_regions.index(r) for r in new_regions
                     if r in old_regions)
        return keep, len(new_regions)

    def migration_bill(self, model_mb: float,
                       bandwidth_mbps: float) -> Dict[str, float]:
        """Cost of applying this plan as a *live migration* instead of a
        checkpointed full pause (the async snapshot engine's path).

        ``barrier_s`` is the only stall the active regions pay: one
        barrier-aligned payload transfer to reconcile the staged state
        against the live barrier state — at most one sync round; zero when
        the diff is structurally empty (an interval/batch-split move
        re-stacks nothing).  ``migrate_mb`` is the snapshot shipment the
        engine streamed in the background — one full model replica per
        joining or leaving region — billed as overlapped traffic, never
        as pause.  The re-plan itself also overlaps with compute."""
        structural = not self.diff.is_empty
        moved = len(self.diff.added) + len(self.diff.removed)
        return {
            "barrier_s": (model_mb * 8.0 / bandwidth_mbps) if structural
            else 0.0,
            "migrate_mb": float(model_mb * moved),
        }


def adapt_interval(sync: SyncConfig, base_interval: int,
                   ref_bandwidth_mbps: float, bandwidth_mbps: float,
                   max_interval: int = 64) -> SyncConfig:
    """Scale the sync interval inversely with available WAN bandwidth (the
    §III.C sync-frequency knob driven by the §III.B monitor): half the
    bandwidth -> double the interval, so per-step blocking communication time
    stays roughly constant.  ASGD (interval-free baseline) is left alone."""
    if sync.strategy == "asgd" or bandwidth_mbps <= 0:
        return sync
    k = round(base_interval * ref_bandwidth_mbps / bandwidth_mbps)
    k = max(1, min(max_interval, k))
    if k == sync.interval:
        return sync
    return replace(sync, interval=k)


class ElasticityController:
    """Long-lived control-plane loop (tentpole of the elasticity engine).

    Consumes ``CloudEvent``s — from an :class:`EventBus`, the WAN simulator,
    or the launcher's host loop — maintains the current resource picture
    (clouds, per-region straggler factors, WAN bandwidth estimate), re-runs
    Algorithm 1 *incrementally* against it, and emits a
    :class:`ReconfigPlan` whose diff the trainer applies at the next sync
    barrier via checkpointed pod re-stacking."""

    def __init__(self, plan: TrainingPlan, bus: Optional[EventBus] = None,
                 ref_bandwidth_mbps: float = 100.0, max_interval: int = 64,
                 probe_est=None):
        self.plan = plan
        self.clouds: Dict[str, CloudResources] = {
            c.region: c for c in plan.request.clouds}
        self.slowdowns: Dict[str, float] = {}
        self.ref_bandwidth_mbps = ref_bandwidth_mbps
        self.bandwidth_mbps = ref_bandwidth_mbps
        # measured-bandwidth source (duck-typed: anything with a
        # ``bandwidth_mbps`` attribute — a WanProbeEstimator, a
        # MeasuredWanProbe's estimator).  When set, every replan reads the
        # shared measured belief instead of trusting the last trace-driven
        # ``bandwidth_changed`` event — the control plane and the sync
        # controllers then act on ONE bandwidth picture.
        self.probe_est = probe_est
        self.base_interval = plan.request.sync.interval
        self.max_interval = max_interval
        self.history: List[ReconfigPlan] = []
        if bus is not None:
            for kind in TRAINING_EVENT_KINDS:
                bus.subscribe(kind, self.handle)

    # ------------------------------------------------------------ events
    def handle(self, event: CloudEvent) -> ReconfigPlan:
        if event.kind == "cloud_joined":
            if event.resources is None:
                raise ValueError("cloud_joined event needs resources")
            self.clouds[event.resources.region] = event.resources
        elif event.kind in ("cloud_left", "pod_crashed"):
            # a crash is an involuntary departure: same re-matching as a
            # graceful leave — the region's resources are gone either way
            if event.region not in self.clouds:
                raise KeyError(f"unknown region {event.region!r}")
            if len(self.clouds) == 1:
                raise ValueError("cannot remove the last cloud")
            del self.clouds[event.region]
            self.slowdowns.pop(event.region, None)
        elif event.kind == "bandwidth_changed":
            if event.bandwidth_mbps is None:
                raise ValueError("bandwidth_changed event needs bandwidth_mbps")
            self.bandwidth_mbps = event.bandwidth_mbps
        elif event.kind == "straggler_detected":
            self.slowdowns[event.region] = max(1.0, event.slowdown)
        if self.probe_est is not None:
            measured = getattr(self.probe_est, "bandwidth_mbps", None)
            if measured is not None:
                # measured belief wins over the event's claimed figure
                self.bandwidth_mbps = float(measured)
        reconfig = self._replan(event)
        self.history.append(reconfig)
        self.plan = reconfig.new
        return reconfig

    # ------------------------------------------------------------ replan
    def _effective_clouds(self) -> Tuple[CloudResources, ...]:
        """Straggler factors enter Algorithm 1 as inflated effective data
        sizes (same iterations take ``slowdown`` times longer per unit of
        computing power)."""
        out = []
        for c in self.clouds.values():
            f = self.slowdowns.get(c.region, 1.0)
            out.append(replace(c, data_size=c.data_size * f) if f != 1.0 else c)
        return tuple(out)

    def _replan(self, event: CloudEvent) -> ReconfigPlan:
        old = self.plan
        effective = self._effective_clouds()
        plans = incremental_matching(effective, prev=old.resource_plans)

        regions = [c.region for c in effective]
        comm = CommunicatorFunction()
        for region in regions:
            comm.register_ps(region, f"{region}/ps#0")
        identities, topology = comm.assign(regions)

        # slowdown-discounted raw compute power drives the batch re-split
        powers = [load_power(p.allocation, 1.0)
                  / self.slowdowns.get(p.region, 1.0) for p in plans]
        split = plan_batch_split(old.request.global_batch, powers)

        sync = adapt_interval(old.request.sync, self.base_interval,
                              self.ref_bandwidth_mbps, self.bandwidth_mbps,
                              self.max_interval)
        request = TrainingRequest(
            model=old.request.model,
            clouds=tuple(self.clouds.values()),
            sync=sync, n_iters=old.request.n_iters,
            global_batch=old.request.global_batch)
        new = TrainingPlan(request=request, resource_plans=tuple(plans),
                           batch_split=tuple(split), topology=topology,
                           ps_identities=identities)
        return ReconfigPlan(event=event, old=old, new=new,
                            diff=diff_plans(old.resource_plans, plans))


@dataclass(frozen=True)
class ScaleDecision:
    """Serving-plane controller output: the replica-count transition and
    the observation that caused it (the serving analogue of
    :class:`ReconfigPlan`)."""

    event: CloudEvent
    old_replicas: int
    new_replicas: int
    reason: str

    @property
    def is_noop(self) -> bool:
        return self.new_replicas == self.old_replicas


class ServingElasticityController:
    """Replica autoscaler for the serving plane — the same controller
    family as :class:`ElasticityController`, consuming the same
    :class:`CloudEvent` stream off the same bus, but actuating replica
    count instead of Algorithm-1 allocations.

    Policy (mirrors the codec controllers' asymmetric streaks): scale *up*
    immediately when observed load exceeds what the current replicas can
    absorb — under-provisioning costs user latency now — and scale *down*
    only after ``hysteresis`` consecutive low-load observations, so a gap
    between bursts doesn't tear down replicas the next burst needs."""

    def __init__(self, *, replicas: int = 1, min_replicas: int = 1,
                 max_replicas: int = 8, target_rps_per_replica: float = 4.0,
                 hysteresis: int = 2, bus: Optional[EventBus] = None):
        if not (1 <= min_replicas <= replicas <= max_replicas):
            raise ValueError("need 1 <= min_replicas <= replicas "
                             "<= max_replicas")
        if target_rps_per_replica <= 0:
            raise ValueError("target_rps_per_replica must be positive")
        self.replicas = int(replicas)
        self.min_replicas = int(min_replicas)
        self.max_replicas = int(max_replicas)
        self.target_rps_per_replica = float(target_rps_per_replica)
        self.hysteresis = int(hysteresis)
        self._calm_streak = 0
        self.history: List[ScaleDecision] = []
        if bus is not None:
            bus.subscribe("load_changed", self.handle)

    def desired(self, rps: float) -> int:
        import math as _math
        want = _math.ceil(max(0.0, rps) / self.target_rps_per_replica)
        return max(self.min_replicas, min(self.max_replicas, max(1, want)))

    def handle(self, event: CloudEvent) -> ScaleDecision:
        if event.rps is None:
            raise ValueError("load_changed event needs rps")
        old = self.replicas
        want = self.desired(event.rps)
        if want > old:
            self._calm_streak = 0
            self.replicas = want
            reason = (f"scale-up {old}->{want}: rps={event.rps:.2f} > "
                      f"{old}x{self.target_rps_per_replica:g} rps capacity")
        elif want < old:
            self._calm_streak += 1
            if self._calm_streak >= self.hysteresis:
                self._calm_streak = 0
                self.replicas = want
                reason = (f"scale-down {old}->{want}: rps={event.rps:.2f} "
                          f"low for {self.hysteresis} consecutive "
                          f"observations")
            else:
                reason = (f"hold {old}: rps={event.rps:.2f} low "
                          f"({self._calm_streak}/{self.hysteresis} toward "
                          f"scale-down)")
        else:
            self._calm_streak = 0
            reason = f"hold {old}: rps={event.rps:.2f} within capacity"
        d = ScaleDecision(event=event, old_replicas=old,
                          new_replicas=self.replicas, reason=reason)
        self.history.append(d)
        return d


def training_workflow(region: str) -> Workflow:
    """The per-cloud physical-training-plane workflow (paper Fig 4): data
    access -> worker training functions -> PS update -> PS communicator,
    with workers terminated immediately after local training finishes."""
    wf = Workflow(namespace=region)
    wf.add("load_data")
    wf.add("workers", deps=["load_data"], terminate_after=True)
    wf.add("ps_update", deps=["workers"])
    wf.add("ps_communicator", deps=["ps_update"])
    return wf
