"""Deterministic fault injection + tolerance behind the WAN transport seam.

The paper's premise is serverless training over multi-regional clouds,
where preemption, link failure and pod churn are the steady state — yet a
reproduction that assumes every transfer completes can only ever measure
the sunny day.  This module makes failure a first-class, *injectable*,
*recoverable* event at the PR-5 transport seam:

- :class:`FaultEvent` / :class:`FaultPlan` — a seeded, committed schedule
  of faults keyed to sync steps: transfer **timeouts** (a transfer running
  N× slower than the bandwidth belief is declared failed), outright
  transfer **failures**, payload **corruption** (a genuine bit-flip on the
  wire triple, caught — or not — by the per-chunk checksums in
  ``sync.chunk_checksum_rows``), transient link **flaps** (a slowdown
  window), and pod **crashes** (degraded rounds over the surviving
  membership, or a mid-round rollback to the last sync barrier).
- :func:`resolve_round` — the single pure decision/billing law for one
  faulted round.  The chaos transport bills with it live, the fault
  benchmark records its outputs, and ``benchmarks/check_regression.py``
  replays the recorded stream through the same function — exact float
  equality after a JSON round-trip, same discipline as the controller
  decision replays.
- :class:`ChaosTransport` — wraps ANY transport.  With an empty plan it is
  bit-exact passthrough (delegation, not reimplementation — the property
  the test suite locks).  With ``tolerate=False`` it is the no-tolerance
  baseline: no checksums, no retries, no degraded rounds — corruption
  decodes straight into the parameters and a crashed peer hangs the round.

Retry/backoff budgets come from :class:`repro.core.wan.RetryPolicy`, the
law shared with the DES failure events, so ``wan.simulate`` and a chaos-
wrapped transport bill a failed attempt identically.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.sync import (ChunkPayload, PodUnreachableError,
                             TransferFailed)
from repro.core.wan import RetryPolicy, retry_schedule

FAULT_KINDS = ("timeout", "fail", "corrupt", "flap", "crash")
CRASH_MODES = ("degrade", "rollback")

#: no-tolerance crash billing: with nobody timing out the transfer, a
#: round with a dead peer hangs this many expected-transfer-times before
#: an operator intervenes.  Deliberately brutal — it is the cost the
#: fault-tolerant path exists to avoid.
NO_TOLERANCE_HANG = 64.0


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault, keyed to the sync step it first bites at.

    ``pod`` is the sender whose link the fault lives on (for ``corrupt``
    the bit-flip lands on that sender's *receiver* row after the ring
    permute); ``duration`` (rounds) only applies to ``flap``; ``factor``
    is the slowdown multiplier of ``flap`` and ``timeout``; ``attempts``
    is how many attempts fail before one succeeds (``fail`` / ``timeout``
    / ``corrupt``); ``mode`` picks the crash recovery story."""

    kind: str
    step: int
    pod: int = 0
    duration: int = 1
    factor: float = 8.0
    attempts: int = 1
    mode: str = "degrade"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind {self.kind!r} unknown (kinds: "
                f"{', '.join(FAULT_KINDS)})")
        if self.step < 0:
            raise ValueError(f"fault {self.kind}: step must be >= 0, "
                             f"got {self.step}")
        if self.pod < 0:
            raise ValueError(f"fault {self.kind}@{self.step}: pod must be "
                             f">= 0, got {self.pod}")
        if self.duration < 1:
            raise ValueError(f"fault {self.kind}@{self.step}: duration must "
                             f"be >= 1 round, got {self.duration}")
        if self.factor <= 0:
            raise ValueError(f"fault {self.kind}@{self.step}: factor must "
                             f"be > 0, got {self.factor}")
        if self.attempts < 1:
            raise ValueError(f"fault {self.kind}@{self.step}: attempts must "
                             f"be >= 1, got {self.attempts}")
        if self.mode not in CRASH_MODES:
            raise ValueError(
                f"fault crash@{self.step}: mode {self.mode!r} unknown "
                f"(modes: {', '.join(CRASH_MODES)})")

    def active(self, step: int) -> bool:
        if self.kind == "flap":
            return self.step <= step < self.step + self.duration
        if self.kind == "crash":
            return step >= self.step        # dead until recovered/removed
        return step == self.step


@dataclass(frozen=True)
class FaultPlan:
    """A committed, seeded fault schedule — the whole experiment input.

    Determinism contract: the same plan against the same run produces the
    same injected faults, the same retry bills and the same recovery
    decisions, which is what lets ``BENCH_faults.json`` be replayed
    exactly in CI."""

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0

    def at(self, step: int) -> Tuple[FaultEvent, ...]:
        return tuple(ev for ev in self.events if ev.active(step))

    @property
    def needs_host_seam(self) -> bool:
        """Ship-level faults (failed/corrupted transfers, crashes) need the
        trainer's host-seam codec path; billing-only plans (flaps) keep
        the wrapped transport's in-graph fast path."""
        return any(ev.kind in ("fail", "timeout", "corrupt", "crash")
                   for ev in self.events)

    @property
    def has_crashes(self) -> bool:
        return any(ev.kind == "crash" for ev in self.events)


@dataclass(frozen=True)
class RoundOutcome:
    """One faulted round's resolved decision + bill (pure, replayable)."""

    step: int
    kinds: Tuple[str, ...]        # active event kinds this round
    attempts: int                 # failed attempts billed (and retried)
    extra_s: float                # retry/backoff wall-clock added
    slowdown: float               # multiplier on the clean transfer time
    crashed: Tuple[int, ...]      # pods dead as of this round


def resolve_round(plan: FaultPlan, policy: RetryPolicy, step: int,
                  expected_s: float) -> RoundOutcome:
    """Resolve one sync round against the plan: which faults bite, how
    many attempts fail, and what the retry/backoff law bills for them.

    Pure math over its four inputs — shared verbatim by the live
    :class:`ChaosTransport`, the fault benchmark and the regression
    replay gate.  A ``timeout`` below the policy's ``timeout_factor`` is
    merely slow (no retry); at/above it the attempt is declared failed.
    Retryable attempts cap at ``policy.max_retries`` — beyond that the
    sender is unreachable and the round degrades instead (the transport's
    ``round_failed_pods``)."""
    kinds: List[str] = []
    attempts, extra, slow = 0, 0.0, 1.0
    crashed: List[int] = []
    for ev in plan.at(step):
        kinds.append(ev.kind)
        if ev.kind == "timeout" and ev.factor < policy.timeout_factor:
            slow *= ev.factor
        elif ev.kind in ("fail", "timeout", "corrupt"):
            n = min(max(1, ev.attempts), policy.max_retries)
            extra += retry_schedule(expected_s, policy, n)
            attempts += n
        elif ev.kind == "flap":
            slow *= ev.factor
        elif ev.kind == "crash":
            crashed.append(ev.pod)
    return RoundOutcome(step=step, kinds=tuple(kinds), attempts=attempts,
                        extra_s=extra, slowdown=slow,
                        crashed=tuple(crashed))


class ChaosTransport:
    """Wrap any transport with a seeded deterministic :class:`FaultPlan`.

    Contract (locked by ``tests/test_faults.py``):

    - **Empty plan ⇒ bit-exact passthrough.**  Shipping delegates to the
      wrapped transport (the same objects, the same code path), billing is
      the wrapped ``on_sync`` verbatim, ``in_graph`` is inherited.
    - **Faulted rounds bill via** :func:`resolve_round` — every outcome is
      appended to ``outcomes`` (the replayable stream) and the degraded
      time feeds the wrapped probe, so the adaptive controllers see the
      real post-retry bandwidth.
    - **Crashes**: ``mode="degrade"`` marks the pod in
      ``round_failed_pods`` (the trainer completes the round over the
      surviving membership mask); ``mode="rollback"`` raises
      :class:`~repro.core.sync.PodUnreachableError` once (the launcher
      restores the last sync-barrier checkpoint), then degrades until the
      control plane removes the pod and calls :meth:`clear_crash`.
    - ``tolerate=False`` is the **no-tolerance baseline**: no checksums
      (corruption decodes into the parameters), no retries, no degraded
      rounds — a crashed peer hangs every round ``NO_TOLERANCE_HANG``
      expected-transfer-times.
    """

    def __init__(self, inner, plan: FaultPlan,
                 policy: Optional[RetryPolicy] = None,
                 tolerate: bool = True):
        self.inner = inner
        self.plan = plan
        self.retry_policy = policy if policy is not None else RetryPolicy()
        self.tolerate = tolerate
        self._rng = np.random.default_rng(plan.seed)
        self._step: Optional[int] = None
        self._round_events: Tuple[FaultEvent, ...] = ()
        self._round_failed: Tuple[int, ...] = ()
        self._attempts: Dict[int, int] = {}      # event index -> injected
        self._payload_mb: Dict[str, float] = {}  # bucket -> last wire MB
        self._cleared: set = set()               # pods recovered + removed
        self._rolled_back: set = set()           # rollback already taken
        self._reported: set = set()              # crashes sent to the bus
        self.retries = 0
        self.degraded_rounds = 0
        self.crash_recoveries = 0
        self.retried_mb = 0.0
        self.outcomes: List[dict] = []           # replayable decision stream

    # ------------------------------------------------------------- plumbing
    def __getattr__(self, name):
        # delegate everything the wrapper does not own (probe, records,
        # tick, wan_transfers_per_round, ...) to the wrapped transport
        inner = self.__dict__.get("inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)

    @property
    def in_graph(self) -> bool:
        return (not self.plan.needs_host_seam
                and getattr(self.inner, "in_graph", True))

    @property
    def verify_checksums(self) -> bool:
        """Checksum verification is the tolerance switch the host-seam
        ship loop reads — the no-tolerance baseline ships unverified."""
        return self.tolerate

    @property
    def clock_s(self) -> float:
        return self.inner.clock_s

    @clock_s.setter
    def clock_s(self, value: float) -> None:
        self.inner.clock_s = value

    # -------------------------------------------------------- round control
    def begin_round(self, step: int) -> None:
        """Arm the plan for one sync round (the trainer calls this before
        shipping).  Computes which pods this round must treat as dead:
        crashed pods not yet removed, and senders whose scheduled failed
        attempts exceed the retry budget (retries would exhaust — the
        round degrades instead of erroring)."""
        self._step = step
        self._round_events = self.plan.at(step)
        self._attempts = {}
        failed: List[int] = []
        if self.tolerate:
            for ev in self._round_events:
                if ev.kind == "crash" and ev.pod not in self._cleared:
                    if ev.mode == "degrade" or ev.pod in self._rolled_back:
                        failed.append(ev.pod)
                elif ev.kind in ("fail", "timeout", "corrupt"):
                    slow_only = (ev.kind == "timeout" and
                                 ev.factor < self.retry_policy.timeout_factor)
                    if (not slow_only
                            and ev.attempts > self.retry_policy.max_retries):
                        failed.append(ev.pod)
        self._round_failed = tuple(dict.fromkeys(failed))

    def begin_stream_round(self, wire_mb, step=None):
        """Streaming rounds and fault injection compose by *exclusion*: a
        round the plan touches declines streaming (returns False), so the
        trainer falls back to the classic ship+on_sync path where
        :func:`resolve_round` owns the billing, retries, and degraded
        membership.  Clean rounds delegate to the wrapped transport —
        chunk-granular feedback whenever no fault is scheduled."""
        if self.plan.at(step if step is not None else self._step):
            return False
        return self.inner.begin_stream_round(wire_mb, step=step)

    @property
    def round_failed_pods(self) -> Tuple[int, ...]:
        """Pods the current round completes without (degraded membership);
        always empty for the no-tolerance baseline."""
        return self._round_failed if self.tolerate else ()

    def take_new_crashes(self) -> Tuple[int, ...]:
        """Crashed pods not yet reported to the control plane (the launcher
        publishes a ``pod_crashed`` event per pod, exactly once)."""
        new = []
        for ev in self._round_events:
            if (ev.kind == "crash" and ev.pod not in self._cleared
                    and ev.pod not in self._reported
                    and (ev.mode == "degrade"
                         or ev.pod in self._rolled_back)):
                self._reported.add(ev.pod)
                new.append(ev.pod)
        return tuple(new)

    def clear_crash(self, pod: int) -> None:
        """The control plane removed the crashed pod (reconfig applied):
        stop degrading rounds for it and count the recovery."""
        if pod not in self._cleared:
            self._cleared.add(pod)
            self.crash_recoveries += 1
        self._round_failed = tuple(p for p in self._round_failed
                                   if p != pod)

    def note_retry(self, bucket: str, attempt: int, err) -> None:
        """Ship-loop hook: one failed attempt was retried — count it and
        bill the retried bytes at full cost."""
        del attempt, err
        self.retries += 1
        self.retried_mb += self._payload_mb.get(bucket, 0.0)

    # ------------------------------------------------------------- shipping
    def ship_bucket(self, name: str, chunks: Sequence[ChunkPayload],
                    shift: int, payload_mb: float = 0.0
                    ) -> Tuple[ChunkPayload, ...]:
        if self.in_graph:
            # no ship-level faults in the plan: pure delegation, safe at
            # jit-trace time (the empty-plan bit-exactness contract)
            return self.inner.ship_bucket(name, chunks, shift, payload_mb)
        self._payload_mb[name] = payload_mb
        # scheduled failed attempts: the transfer never delivers — raise
        # before shipping, capped at the retry budget (beyond it the pod
        # is in round_failed_pods and the round degrades instead)
        if self.tolerate:
            for i, ev in enumerate(self._round_events):
                if ev.kind == "fail" or (
                        ev.kind == "timeout"
                        and ev.factor >= self.retry_policy.timeout_factor):
                    limit = min(ev.attempts, self.retry_policy.max_retries)
                    done = self._attempts.get(i, 0)
                    if done < limit:
                        self._attempts[i] = done + 1
                        raise TransferFailed(name, done + 1, ev.kind,
                                             pod=ev.pod)
        shipped = self.inner.ship_bucket(name, chunks, shift, payload_mb)
        for i, ev in enumerate(self._round_events):
            if ev.kind != "corrupt":
                continue
            limit = (min(ev.attempts, self.retry_policy.max_retries)
                     if self.tolerate else ev.attempts)
            done = self._attempts.get(i, 0)
            if done < limit:
                self._attempts[i] = done + 1
                return self._corrupt(shipped, ev, shift)
        return shipped

    def _corrupt(self, shipped: Sequence[ChunkPayload], ev: FaultEvent,
                 shift: int) -> Tuple[ChunkPayload, ...]:
        """A genuine wire bit-flip: XOR the exponent MSB of every fp32
        scale on the corrupted receiver row of the first chunk (1.0f
        ``0x3F800000`` becomes +inf ``0x7F800000``) — exactly the kind of
        silent payload damage the per-chunk checksums exist to catch."""
        first = shipped[0]
        scales = np.asarray(first.scales).copy()
        row = (ev.pod + shift) % scales.shape[0]
        view = scales.view(np.uint32)
        view[row] ^= np.uint32(0x40000000)
        corrupted = first._replace(scales=jnp.asarray(scales))
        return (corrupted,) + tuple(shipped[1:])

    # -------------------------------------------------------------- billing
    def _expected_s(self, total_mb: float) -> float:
        """Expected round transfer time at the current bandwidth belief —
        the base of every timeout budget and retry bill."""
        est = None
        probe = getattr(self.inner, "probe", None)
        if probe is not None:
            est = probe.estimator.bandwidth_mbps
        if est is None or est <= 0.0:
            est = self.retry_policy.assume_mbps
        return total_mb * 8.0 / est

    def on_sync(self, wire_mb: Mapping[str, float],
                step: Optional[int] = None) -> float:
        if step is not None and step != self._step:
            self.begin_round(step)
        events = self._round_events
        if not events:
            # clean round: the wrapped transport's billing, verbatim
            return self.inner.on_sync(wire_mb, step=step)
        if self.tolerate:
            # a rollback-mode crash preempts the round once: state since
            # the barrier includes the dead pod and cannot be re-stacked —
            # the launcher restores the barrier checkpoint (pod_resize
            # path) and the crash then degrades until removal
            for ev in events:
                if (ev.kind == "crash" and ev.mode == "rollback"
                        and ev.pod not in self._rolled_back
                        and ev.pod not in self._cleared):
                    self._rolled_back.add(ev.pod)
                    raise PodUnreachableError(pod=ev.pod, step=self._step)
        total = float(sum(wire_mb.values()))
        expected_s = self._expected_s(total)
        outcome = resolve_round(self.plan, self.retry_policy,
                                self._step if self._step is not None else -1,
                                expected_s)
        # bill the wrapped transport's clean draw with its probe detached —
        # the probe must see the DEGRADED time, fed once below
        probe = getattr(self.inner, "probe", None)
        if probe is not None:
            self.inner.probe = None
        try:
            t_clean = self.inner.on_sync(wire_mb, step=step)
        finally:
            if probe is not None:
                self.inner.probe = probe
        crashed = tuple(p for p in outcome.crashed
                        if p not in self._cleared)
        t = t_clean * outcome.slowdown + outcome.extra_s
        if self.tolerate:
            if crashed:
                self.degraded_rounds += 1
        elif crashed:
            t += expected_s * NO_TOLERANCE_HANG * len(crashed)
        self.outcomes.append({
            "step": int(self._step) if self._step is not None else None,
            "expected_s": expected_s,
            "kinds": list(outcome.kinds),
            "attempts": outcome.attempts,
            "extra_s": outcome.extra_s,
            "slowdown": outcome.slowdown,
            "crashed": list(outcome.crashed),
            "t_s": t,
        })
        if probe is not None and total > 0.0 and t > 0.0:
            probe.observe_transfer(total, t)
        return t
