"""Pluggable WAN transport layer — one seam from sim to real mesh.

The sync layer (``repro.core.sync``) produces *wire payloads* (per-bucket
:class:`~repro.core.sync.ChunkPayload` triples) and consumes them back; who
actually moves the bytes to the ring peer — and how long that took — is
this module's job.  One protocol, three implementations:

- the **inline ring** (``transport=None`` /
  :class:`~repro.core.sync.InlineRingShip`): the ring permute traced
  straight into the jitted sync step, exactly the pre-seam behaviour —
  bit-exact legacy path, no timing.
- :class:`SimTransport`: the same in-graph shipping, but every sync round
  is *billed* against a :class:`~repro.core.wan.BandwidthTrace` +
  :class:`~repro.core.wan.WANConfig` with the discrete-event simulator's
  own ``transfer_time`` law (lognormal fluctuation, latency, seeded rng).
  The billed transfer times feed a :class:`MeasuredWanProbe` — so the
  adaptive controllers can be driven by *measured* transfer times on an
  emulated link, with **no trace wired to the controller**.
- :class:`MeshTransport`: real jitted collectives on a device mesh (on
  CPU: ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` virtual
  devices).  Each bucket's transfer is executed as its own dispatch and
  timed **on-host** (``block_until_ready`` around the permute), and
  :meth:`MeshTransport.measure_overlap` measures what
  ``SyncConfig.overlap_chunks`` pipelining actually buys on the mesh —
  the two oldest ROADMAP items ("feed the WAN probe from measured
  transfer times", "measure overlap_chunks on a real mesh") both live
  here.

The measured-feedback data path::

    transport.ship_bucket -> TransferRecord (wire MB, seconds)
        -> transport.on_sync -> MeasuredWanProbe.observe_transfer
        -> WanProbeEstimator (EMA + fluctuation + cliff-snap)
        -> Adaptive/BucketedSyncController(probe_est=...)

Layering: ``sync`` does not import this module (transports are duck-typed
at the seam); this module sits above ``sync``/``wan``/``autotune`` and
below ``training``/``launch``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import WanProbe, WanProbeEstimator
from repro.core.sync import (_INLINE_RING, ChunkPayload, SyncConfig,
                             _chunk_widths, _decode_bucket, _encode_bucket)
from repro.core.wan import (BandwidthTrace, WANConfig, stream_chunk_time,
                            transfer_time)

_EPS = 1e-9


@dataclass
class TransferRecord:
    """One bucket's shipped transfer: wire bytes and how long they took.

    ``seconds`` is measured wall-clock for :class:`MeshTransport` and the
    simulator-billed time for :class:`SimTransport` — downstream consumers
    (the probe, telemetry, benchmarks) cannot tell the difference, which
    is the point of the seam."""

    bucket: str
    payload_mb: float
    seconds: float
    step: Optional[int] = None

    @property
    def mbps(self) -> float:
        """Achieved bandwidth of this transfer (megabits/second)."""
        return self.payload_mb * 8.0 / max(self.seconds, _EPS)


class MeasuredWanProbe:
    """Feeds a :class:`~repro.core.autotune.WanProbeEstimator` from
    transport-reported transfer times instead of declared trace events.

    One observation per sync round (the round's total wire MB over its
    total seconds): achieved bandwidth = ``payload_mb * 8 / seconds``.
    The estimator's cliff-snap still applies — one observation of a
    collapsed link reprices the belief before the next transfer is paid.
    Hand ``estimator`` to a controller's ``probe_est`` to close the loop
    with no trace wired to the controller."""

    def __init__(self, alpha: float = 0.5, cliff_snap: float = 4.0,
                 estimator: Optional[WanProbeEstimator] = None):
        self.estimator = (estimator if estimator is not None
                          else WanProbeEstimator(alpha=alpha,
                                                 cliff_snap=cliff_snap))
        self.n_observations = 0
        self.last_mbps: Optional[float] = None
        # chunk-granular observations (the streaming seam): each chunk's
        # (wire MB, seconds, mbps) lands here AS IT LANDS, mid-round — the
        # StreamingShipController reads these to retune before the round
        # finishes.  The shared estimator still folds exactly once per
        # round (at the round barrier), so round-level controllers see the
        # identical belief stream whether streaming is on or off.
        self.n_chunk_observations = 0
        self.last_chunk_mbps: Optional[float] = None
        self.chunk_log: List[Tuple[float, float, float]] = []

    def observe_transfer(self, payload_mb: float, seconds: float) -> WanProbe:
        """Fold one (wire MB, seconds) sample into the bandwidth belief.

        Degenerate samples — no bytes moved (an empty, skipped or fully
        degraded round) or a non-positive duration — are dropped, not
        folded: ``mbps -> ~0`` on them, and the estimator's cliff-snap
        would read that as a collapsed link and wedge the belief (and the
        autotuner with it) at the floor over a round that never touched
        the network."""
        if payload_mb <= 0.0 or seconds <= 0.0:
            return self.probe
        mbps = payload_mb * 8.0 / max(seconds, _EPS)
        self.last_mbps = mbps
        self.n_observations += 1
        return self.estimator.observe(mbps)

    def observe_chunk(self, payload_mb: float, seconds: float) -> None:
        """Record one landed chunk's measured transfer, mid-round.

        Deliberately does NOT touch the estimator: the round-level belief
        folds once per round via :meth:`observe_transfer` (bit-identical
        to the non-streaming path), while the chunk log gives the
        streaming controller its first-chunk feedback."""
        if payload_mb <= 0.0 or seconds <= 0.0:
            return
        mbps = payload_mb * 8.0 / max(seconds, _EPS)
        self.last_chunk_mbps = mbps
        self.n_chunk_observations += 1
        self.chunk_log.append((payload_mb, seconds, mbps))

    @property
    def probe(self) -> WanProbe:
        return self.estimator.probe


class _StreamRound:
    """Mutable per-round state of a streaming ship (transport-internal).

    ``t_round`` is the round's ONE clean transfer draw — the same draw the
    non-streaming ``on_sync`` would make, consumed in the same rng order —
    and every pre-retune chunk bills its pro-rata share of it
    (``wan.stream_chunk_time``), so the first chunk's achieved bandwidth
    IS the round's achieved bandwidth.  A mid-round retune re-prices only
    the re-encoded tail with a second draw (``t_tail`` over ``tail_mb``)."""

    def __init__(self, step: Optional[int], wire_mb: Mapping[str, float],
                 t_round: float):
        self.step = step
        self.wire_mb = dict(wire_mb)
        self.total = float(sum(self.wire_mb.values()))
        self.t_round = t_round
        self.retuned = False
        self.tail_mb = 0.0
        self.t_tail = 0.0
        self.prefix_s = 0.0             # billed seconds before the retune
        self.billed: Dict[str, float] = {}     # bucket -> seconds shipped
        self.shipped: Dict[str, float] = {}    # bucket -> wire MB shipped
        self.chunks: List[Tuple[str, float, float]] = []
        #   (bucket, chunk MB, seconds) in ship order — the replayable
        #   per-chunk observation stream

    def bill(self, name: str, chunk_mb: float) -> float:
        if self.retuned:
            secs = stream_chunk_time(self.t_tail, chunk_mb, self.tail_mb)
        else:
            secs = stream_chunk_time(self.t_round, chunk_mb, self.total)
            self.prefix_s += secs
        self._account(name, chunk_mb, secs)
        return secs

    def bill_measured(self, name: str, chunk_mb: float,
                      secs: float) -> float:
        """Account a chunk whose transfer was wall-clock measured (mesh):
        no billing law, the measurement IS the cost."""
        self._account(name, chunk_mb, secs)
        return secs

    def _account(self, name: str, chunk_mb: float, secs: float) -> None:
        self.billed[name] = self.billed.get(name, 0.0) + secs
        self.shipped[name] = self.shipped.get(name, 0.0) + chunk_mb
        self.chunks.append((name, chunk_mb, secs))

    @property
    def t_total(self) -> float:
        """Round wall-clock: the untouched clean draw when no retune fired
        (NOT a sum of chunk slices — float associativity must not drift
        the zero-retune bill), else prefix slices + the tail draw."""
        if not self.retuned:
            return self.t_round
        return self.prefix_s + self.t_tail

    @property
    def shipped_mb(self) -> float:
        return float(sum(self.shipped.values()))


class WanTransport:
    """The transport protocol ``sync.ship_sync_payloads`` emits payloads to.

    ``in_graph=True`` transports ship with traceable ops (the whole sync
    round stays one jitted dispatch — the trainer's fast path);
    ``in_graph=False`` transports require the trainer's host-seam path
    (jitted prepare -> host-timed ship per bucket -> jitted finish).
    ``on_sync`` is the round barrier: called host-side once per sync round
    with the per-bucket wire MB, it bills (sim) or flushes (mesh) the
    round's transfers into ``records`` and the probe, returning the
    round's transfer seconds."""

    in_graph: bool = True
    probe: Optional[MeasuredWanProbe] = None
    #: transports that implement the chunk-granular streaming round
    #: protocol (begin_stream_round / stream_* / end_stream_round) set
    #: this True; the trainer falls back to the classic
    #: ship_bucket+on_sync path otherwise.
    supports_streaming: bool = False

    def __init__(self):
        self.records: List[TransferRecord] = []
        # replayable per-round streaming summaries (only streaming-capable
        # transports append; kept on the base so consumers can read it
        # unconditionally)
        self.stream_rounds: List[Dict] = []
        self._stream: Optional[_StreamRound] = None

    def ship_bucket(self, name: str, chunks: Sequence[ChunkPayload],
                    shift: int, payload_mb: float = 0.0
                    ) -> Tuple[ChunkPayload, ...]:
        raise NotImplementedError

    def on_sync(self, wire_mb: Mapping[str, float],
                step: Optional[int] = None) -> float:
        return 0.0

    # ------------------------------------------- streaming round protocol
    # The chunk, not the round, as the unit of WAN feedback: a streaming
    # round opens with the full planned per-bucket wire schedule, ships
    # chunk by chunk (each chunk's measured/billed transfer landing in
    # ``probe.observe_chunk`` AS IT LANDS), may retune ONCE mid-round
    # (abort the unsent schedule, re-price a re-encoded tail), and closes
    # with ``end_stream_round`` — which emits the same per-bucket records
    # and the same single probe-estimator fold as ``on_sync`` would.
    # Invariant (property-tested): a streaming round with zero retunes is
    # bit-identical to the classic path — records, probe belief, rng
    # stream and all.

    def begin_stream_round(self, wire_mb: Mapping[str, float],
                           step: Optional[int] = None) -> bool:
        """Arm a streaming round.  Returns False to decline (caller must
        fall back to the classic ship+on_sync path for this round)."""
        del wire_mb, step
        return False

    def stream_chunk(self, name: str, chunk_mb: float) -> float:
        """Billing-only ship of one chunk (no data movement) — the DES /
        bench driver's entry point.  Returns the chunk's seconds."""
        raise NotImplementedError

    def stream_ship_chunk(self, name: str, chunk: ChunkPayload, shift: int,
                          chunk_mb: float) -> Tuple[ChunkPayload, float]:
        """Ship one chunk's payload to the ring peer and bill it.
        Returns (shipped chunk, seconds) — the trainer's entry point."""
        raise NotImplementedError

    def retune_stream(self, tail_mb: float) -> None:
        """Abort the unsent chunk schedule; subsequent chunks are the
        re-encoded tail, priced as one fresh transfer of ``tail_mb``."""
        raise NotImplementedError

    def end_stream_round(self) -> float:
        """Round barrier for a streaming round: emit per-bucket records,
        fold the round's aggregate into the probe estimator exactly once,
        and return the round's transfer seconds."""
        raise NotImplementedError


class SimTransport(WanTransport):
    """The WAN simulator rehosted behind the transport seam.

    Shipping is the same traceable ring permute as the legacy inline path
    (results are bit-exact); *billing* replays the discrete-event
    simulator's transfer law: at each sync round the trace's bandwidth at
    the transport's sim clock prices the round's total wire bytes through
    ``wan.transfer_time`` (latency + lognormal fluctuation, seeded rng —
    deterministic, so benchmark CI can replay the resulting decision
    stream).  The caller owns the clock: ``tick(dt)`` advances it by
    emulated compute time, ``on_sync`` bills at the current clock.
    """

    in_graph = True

    def __init__(self, trace: BandwidthTrace,
                 wan: Optional[WANConfig] = None,
                 probe: Optional[MeasuredWanProbe] = None):
        super().__init__()
        self.trace = trace
        self.wan = wan if wan is not None else WANConfig()
        self.probe = probe
        self.clock_s = 0.0
        self._rng = np.random.default_rng(self.wan.seed)

    def tick(self, dt_s: float) -> None:
        """Advance the sim clock by ``dt_s`` emulated seconds."""
        self.clock_s += dt_s

    def ship_bucket(self, name: str, chunks: Sequence[ChunkPayload],
                    shift: int, payload_mb: float = 0.0
                    ) -> Tuple[ChunkPayload, ...]:
        # traceable (may run at jit-trace time, once per compile) — billing
        # therefore lives in on_sync, where sizes are static host values.
        # Delegating to the inline ring is the bit-exactness guarantee:
        # sim ships THE code path the legacy jit traces, not a copy of it.
        return _INLINE_RING.ship_bucket(name, chunks, shift, payload_mb)

    def on_sync(self, wire_mb: Mapping[str, float],
                step: Optional[int] = None) -> float:
        """Bill one sync round: one ``transfer_time`` draw on the round's
        total payload (exactly the simulator's law), split across buckets
        proportionally for the per-bucket records."""
        bw = self.trace.at(self.clock_s)
        total = sum(wire_mb.values())
        if total <= 0.0:
            return 0.0
        t = transfer_time(total, bw, self.wan, self._rng)
        for name, mb in wire_mb.items():
            self.records.append(TransferRecord(
                bucket=name, payload_mb=mb, seconds=t * mb / total,
                step=step))
        if self.probe is not None:
            self.probe.observe_transfer(total, t)
        return t

    # ------------------------------------------- streaming round protocol
    supports_streaming = True

    def begin_stream_round(self, wire_mb: Mapping[str, float],
                           step: Optional[int] = None) -> bool:
        """Arm a streaming round: draw the round's ONE clean transfer time
        now (same trace lookup, same rng consumption as ``on_sync``), so a
        zero-retune round bills bit-identically to the classic path."""
        total = sum(wire_mb.values())
        if total <= 0.0:
            return False
        bw = self.trace.at(self.clock_s)
        t = transfer_time(total, bw, self.wan, self._rng)
        self._stream = _StreamRound(step, wire_mb, t)
        return True

    def stream_chunk(self, name: str, chunk_mb: float) -> float:
        secs = self._stream.bill(name, chunk_mb)
        if self.probe is not None:
            self.probe.observe_chunk(chunk_mb, secs)
        return secs

    def stream_ship_chunk(self, name: str, chunk: ChunkPayload, shift: int,
                          chunk_mb: float) -> Tuple[ChunkPayload, float]:
        shipped = _INLINE_RING.ship_bucket(name, (chunk,), shift,
                                           chunk_mb)[0]
        return shipped, self.stream_chunk(name, chunk_mb)

    def retune_stream(self, tail_mb: float) -> None:
        """Abort the unsent schedule: the re-encoded tail is priced as one
        fresh ``transfer_time`` draw at the *current* traced bandwidth —
        the whole point of reacting mid-round."""
        st = self._stream
        st.retuned = True
        st.tail_mb = float(tail_mb)
        st.t_tail = (transfer_time(tail_mb, self.trace.at(self.clock_s),
                                   self.wan, self._rng)
                     if tail_mb > 0.0 else 0.0)

    def end_stream_round(self) -> float:
        st = self._stream
        self._stream = None
        if not st.retuned:
            # canonical per-bucket split of the clean draw — NOT a sum of
            # chunk slices, so records match ``on_sync`` bit for bit
            for name, mb in st.wire_mb.items():
                self.records.append(TransferRecord(
                    bucket=name, payload_mb=mb,
                    seconds=st.t_round * mb / st.total, step=st.step))
        else:
            for name, mb in st.shipped.items():
                self.records.append(TransferRecord(
                    bucket=name, payload_mb=mb,
                    seconds=st.billed.get(name, 0.0), step=st.step))
        t = st.t_total
        # at zero retune the observation is (round total, clean draw) —
        # the exact sample on_sync feeds (chunk-sum float order must not
        # leak into the belief); a retuned round observes what actually
        # shipped over what it actually took
        mb_obs = st.total if not st.retuned else st.shipped_mb
        if self.probe is not None:
            self.probe.observe_transfer(mb_obs, t)
        self.stream_rounds.append({
            "step": st.step, "total_mb": st.total, "t_round": st.t_round,
            "chunks": list(st.chunks), "retuned": st.retuned,
            "tail_mb": st.tail_mb, "t_tail": st.t_tail,
            "shipped_mb": st.shipped_mb, "t_s": t,
        })
        return t


class MeshTransport(WanTransport):
    """Real jitted collectives on a device mesh, timed on-host.

    Payload parts are placed sharded over a ``pod`` mesh axis (one pod per
    device when ``jax.device_count() >= n_pods`` — on CPU, force virtual
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    *before* importing jax), so the ring permute lowers to a real
    cross-device collective-permute.  Each bucket's transfer runs as its
    own dispatch with ``block_until_ready`` around it and the wall-clock
    goes into a :class:`TransferRecord` — the measured feedback the
    adaptive controllers consume via :class:`MeasuredWanProbe`.

    ``in_graph=False``: the trainer's host-seam sync path (jitted prepare
    -> this ship -> jitted finish) is required; shipping inside one big
    jit would erase the on-host timing boundary.
    """

    in_graph = False

    def __init__(self, probe: Optional[MeasuredWanProbe] = None,
                 devices: Optional[Sequence] = None,
                 emulate_mbps: Optional[float] = None):
        super().__init__()
        self.probe = probe
        self._devices = devices
        # a local mesh has no WAN between its (virtual) devices — transfers
        # complete at memory-fabric speed.  ``emulate_mbps`` adds a real
        # wall-clock hop (sleep of payload_mb*8/mbps) after each shipped
        # bucket, so measured transfer times — and everything downstream:
        # the probe, the controllers, the overlap measurement — are
        # WAN-scale.  ``None`` reports the raw mesh fabric.
        self.emulate_mbps = emulate_mbps
        self._round: List[TransferRecord] = []
        self._roll = jax.jit(jnp.roll, static_argnames=("shift", "axis"))

    # ------------------------------------------------------------ placement
    def sharding(self, n_pods: int):
        """Pod-sharded placement when the mesh has enough devices, else
        ``None`` (single-device arrays; the permute is then a local roll —
        same numerics, no cross-device traffic to time)."""
        devs = list(self._devices if self._devices is not None
                    else jax.devices())
        if len(devs) < n_pods:
            return None
        mesh = jax.sharding.Mesh(np.array(devs[:n_pods]), ("pod",))
        return jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("pod"))

    @property
    def sharded(self) -> bool:
        return self.sharding(2) is not None

    def _place(self, chunks: Sequence[ChunkPayload]
               ) -> List[ChunkPayload]:
        sh = self.sharding(chunks[0].q.shape[0])
        if sh is None:
            return list(chunks)
        return [ChunkPayload(*(jax.device_put(p, sh) for p in c))
                for c in chunks]

    # -------------------------------------------------------------- shipping
    def ship_bucket(self, name: str, chunks: Sequence[ChunkPayload],
                    shift: int, payload_mb: float = 0.0
                    ) -> Tuple[ChunkPayload, ...]:
        placed = self._place(chunks)
        jax.block_until_ready(placed)   # placement is not transfer time
        t0 = time.perf_counter()
        out = tuple(ChunkPayload(*(self._roll(p, shift=shift, axis=0)
                                   for p in c)) for c in placed)
        jax.block_until_ready(out)
        if self.emulate_mbps:
            time.sleep(payload_mb * 8.0 / self.emulate_mbps)
        rec = TransferRecord(bucket=name, payload_mb=payload_mb,
                             seconds=time.perf_counter() - t0)
        self.records.append(rec)
        self._round.append(rec)
        return out

    def on_sync(self, wire_mb: Mapping[str, float],
                step: Optional[int] = None) -> float:
        """Round barrier: flush this round's measured transfers into the
        probe (one aggregate observation — total wire MB over total
        measured seconds)."""
        del wire_mb
        if not self._round:
            return 0.0
        mb = sum(r.payload_mb for r in self._round)
        secs = sum(r.seconds for r in self._round)
        for r in self._round:
            r.step = step
        self._round = []
        if self.probe is not None and mb > 0.0:
            self.probe.observe_transfer(mb, secs)
        return secs

    # ------------------------------------------- streaming round protocol
    supports_streaming = True

    def begin_stream_round(self, wire_mb: Mapping[str, float],
                           step: Optional[int] = None) -> bool:
        """Arm a streaming round on the mesh.  No billing draw here: every
        chunk's cost is its measured wall-clock, landing as it lands."""
        if sum(wire_mb.values()) <= 0.0:
            return False
        self._stream = _StreamRound(step, wire_mb, 0.0)
        return True

    def stream_ship_chunk(self, name: str, chunk: ChunkPayload, shift: int,
                          chunk_mb: float) -> Tuple[ChunkPayload, float]:
        placed = self._place((chunk,))
        jax.block_until_ready(placed)
        t0 = time.perf_counter()
        out = tuple(ChunkPayload(*(self._roll(p, shift=shift, axis=0)
                                   for p in c)) for c in placed)
        jax.block_until_ready(out)
        if self.emulate_mbps:
            time.sleep(chunk_mb * 8.0 / self.emulate_mbps)
        secs = time.perf_counter() - t0
        self._stream.bill_measured(name, chunk_mb, secs)
        if self.probe is not None:
            self.probe.observe_chunk(chunk_mb, secs)
        return out[0], secs

    def retune_stream(self, tail_mb: float) -> None:
        """Nothing to re-price: the mesh measures every chunk for real, so
        the re-encoded (smaller) tail is automatically cheaper.  Recorded
        for the replayable round summary only."""
        self._stream.retuned = True
        self._stream.tail_mb = float(tail_mb)

    def end_stream_round(self) -> float:
        st = self._stream
        self._stream = None
        secs = float(sum(st.billed.values()))
        for name, mb in st.shipped.items():
            self.records.append(TransferRecord(
                bucket=name, payload_mb=mb,
                seconds=st.billed.get(name, 0.0), step=st.step))
        if self.probe is not None and st.shipped_mb > 0.0:
            self.probe.observe_transfer(st.shipped_mb, secs)
        self.stream_rounds.append({
            "step": st.step, "total_mb": st.total, "t_round": secs,
            "chunks": list(st.chunks), "retuned": st.retuned,
            "tail_mb": st.tail_mb, "t_tail": st.t_tail,
            "shipped_mb": st.shipped_mb, "t_s": secs,
        })
        return secs

    # ------------------------------------------------- overlap measurement
    def measure_overlap(self, cfg: SyncConfig, n_pods: int, n_elems: int,
                        *, seed: int = 0, reps: int = 3) -> Dict:
        """Measure what ``overlap_chunks`` pipelining actually buys on this
        mesh — the realized version of the WAN simulator's
        ``1/overlap_chunks`` blocking model.

        Two schedules over the *same* chunk boundaries and codec knobs,
        each wall-clock timed end to end (best of ``reps`` after a
        compile/warmup pass):

        - **serialized** — encode chunk i on the mesh, then ship it
          (permute + the emulated WAN hop when ``emulate_mbps`` is set)
          to completion, then encode chunk i+1: transfer and compression
          never coexist.  This is what a transport without the chunk seam
          pays.
        - **pipelined** — the permute of chunk i is data-independent of
          the encode of chunk i+1 (``SyncConfig.overlap_chunks``'s whole
          premise), so chunk i's transfer runs on a background thread
          while the mesh encodes chunk i+1; only the final chunk's
          transfer tail stays unhidden.

        Decodes run after all transfers in both schedules (receiver-side
        work, identical cost) and both schedules produce the same decoded
        buffer.  With ``emulate_mbps=None`` the transfer is the raw mesh
        fabric permute — on CPU virtual devices that is microseconds, so
        the speedup degenerates to ~1; set an emulated WAN bandwidth to
        measure the regime the paper's link actually lives in."""
        if not cfg.uses_codec:
            raise ValueError("measure_overlap times the codec path: cfg "
                             "must have the fused codec enabled "
                             "(asgd_ga + compress_topk + quantize_int8)")
        import threading

        rng = np.random.default_rng(seed)
        flat = jnp.asarray(rng.normal(size=(n_pods, n_elems)), jnp.float32)
        sh = self.sharding(n_pods)
        if sh is not None:
            flat = jax.device_put(flat, sh)
        shift = cfg.peer_shift
        widths = _chunk_widths(cfg, n_elems)
        chunk_mb = [cfg.payload_mb(4 * m / 1e6) for m in widths]

        import dataclasses
        one = dataclasses.replace(cfg, overlap_chunks=1)
        # one jitted encode serves every width (jit caches per input
        # shape); decode is genuinely width-specialized (n_total is a
        # static argument of the reconstruction)
        enc = jax.jit(lambda seg: _encode_bucket(one, seg,
                                                 want_local=False)[0])
        dec_fns = {m: jax.jit(
            lambda ch, _m=m: _decode_bucket(one, ch, _m))
            for m in set(widths)}
        offs = [sum(widths[:i]) for i in range(len(widths))]
        # pre-slice the chunk segments OUTSIDE the timed region (identical
        # cost to both schedules; on a sharded buffer an eager slice is
        # itself a collective program)
        segs = [flat[:, off:off + m] for m, off in zip(widths, offs)]
        jax.block_until_ready(segs)

        # CONCURRENCY CONTRACT: every XLA program — encode, permute,
        # decode — is dispatched from THIS thread, so each device's queue
        # sees collectives in one total order (two threads racing
        # collective dispatches can rendezvous-deadlock XLA:CPU).  Worker
        # threads only *wait* for the shipped chunk and pay the emulated
        # WAN hop; that wait+hop is what overlaps the next chunk's encode.
        def run(pipelined: bool
                ) -> Tuple[float, jnp.ndarray, List[float]]:
            shipped: List = [None] * len(widths)
            # per-chunk transfer wall-clock (wait-for-permute + emulated
            # hop), written by whichever thread pays the transfer — the
            # chunk-granular observation stream the streaming seam needs
            hop_s: List[float] = [0.0] * len(widths)
            prev: Optional[threading.Thread] = None
            t0 = time.perf_counter()
            for i, m in enumerate(widths):
                ch = enc(segs[i])
                out = tuple(ChunkPayload(*(self._roll(p, shift=shift,
                                                      axis=0)
                                           for p in c)) for c in ch)
                shipped[i] = out

                def hop(out=out, mb=chunk_mb[i], i=i):
                    h0 = time.perf_counter()
                    jax.block_until_ready(out)
                    if self.emulate_mbps:
                        time.sleep(mb * 8.0 / self.emulate_mbps)
                    hop_s[i] = time.perf_counter() - h0

                if pipelined:
                    if prev is not None:
                        prev.join()  # ONE link: transfers serialize among
                        #   themselves; only encode overlaps them
                    prev = threading.Thread(target=hop)
                    prev.start()     # transfer overlaps the next encode
                else:
                    hop()            # transfer to completion, then encode
            if prev is not None:
                prev.join()
            parts = [dec_fns[m](shipped[i])
                     for i, m in enumerate(widths)]
            out = jnp.concatenate(parts, axis=1)
            jax.block_until_ready(out)
            return time.perf_counter() - t0, out, hop_s

        def timeit(pipelined: bool
                   ) -> Tuple[float, jnp.ndarray, List[float]]:
            _, out, _ = run(pipelined)   # warmup / compile
            best = float("inf")
            best_hops: List[float] = []
            for _ in range(reps):
                dt, out, hops = run(pipelined)
                if dt < best:
                    best, best_hops = dt, hops
            return best, out, best_hops

        t_serial, out_serial, hops_serial = timeit(pipelined=False)
        t_pipe, out_pipe, hops_pipe = timeit(pipelined=True)
        assert np.array_equal(np.asarray(out_serial), np.asarray(out_pipe))
        return {
            "n_devices": jax.device_count(),
            "sharded": sh is not None,
            "n_pods": n_pods,
            "n_elems": n_elems,
            "chunks": len(widths),
            "emulate_mbps": self.emulate_mbps,
            "wire_mb": round(sum(chunk_mb), 4),
            "chunk_mb": [round(mb, 6) for mb in chunk_mb],
            "chunk_transfer_s": {
                "serialized": [round(h, 6) for h in hops_serial],
                "pipelined": [round(h, 6) for h in hops_pipe],
            },
            "t_pipelined_s": round(t_pipe, 6),
            "t_serialized_s": round(t_serial, 6),
            "overlap_speedup": round(t_serial / max(t_pipe, _EPS), 3),
        }
