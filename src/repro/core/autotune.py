"""Adaptive WAN sync autotuner: per-bucket precision/sparsity controller.

The paper's headline speedup comes from *matching* the sync strategy to WAN
conditions — but its WAN exhibits "low bandwidth and high fluctuations", so a
launch-time choice of ``compress_topk`` / payload tier / ``interval`` is
wrong whenever the network moves.  This module closes the loop the ROADMAP
calls for ("per-bucket adaptive compress_topk from gradient statistics"):

  signals                        decision                      reconfig
  ───────                        ────────                      ────────
  BucketStats (EF-residual       AdaptiveSyncController        SyncPlanUpdate
  ratio + top-k energy capture,  walks a payload-aggression    -> Trainer.retune
  from SyncState.msg_norm /      ladder (compress_topk x       at the next sync
  resid_norm)                    value_dtype rungs, sorted     barrier (EF
  WanProbe (achieved bandwidth   by wire bytes) under a        residual carries
  EMA + fluctuation, from the    user-set convergence guard,   over — dense
  simulator / --wan-trace /      and sizes ``interval`` so     bucket coords
  EventBus bandwidth_changed)    per-step blocking comm        are tier-free)
                                 stays on target

Control law (deterministic, hysteresis-damped):

- **Convergence guard** (the hard rule): the EF-residual ratio
  ``||resid|| / ||message||`` is ``sqrt(1 - energy_capture)`` of the last
  sync — structurally in [0, 1), rising toward 1 as the tier drops more
  than error feedback can re-ship per interval.  If it reaches ``ef_guard``
  the controller *immediately* de-escalates one rung, and it never
  escalates unless the ratio is below ``escalate_margin * ef_guard``.
  This is the invariant the property tests pin: under NO input sequence
  does the controller escalate while the guard is tripped.  (Scale note:
  with error feedback a ratio of ~0.85 is *healthy* — the codec benches
  hit 99.9% of dense loss reduction there, because everything dropped is
  re-shipped next interval — so guards live near 1 and the escalation
  margin is deliberately thin.)
- **WAN pressure**: from the bandwidth EMA the controller estimates the
  blocking sync time ``payload * 8 / bw`` and fits the smallest interval
  keeping its per-step share at ``target_comm_frac`` of compute.  The fit
  is bounded by a **staleness budget** (``interval_budget``, default the
  base config's interval x2): when the fitted interval busts the budget for
  ``hysteresis`` consecutive updates — i.e. only *more staleness* could
  absorb the bandwidth drop — the controller escalates, jumping straight
  to the least aggressive rung whose fit respects the budget (transit
  rungs would each pay a transfer on the slow link); when the fit falls
  far below budget for a 4x longer streak it de-escalates one rung to
  buy back fidelity.  Fluctuation (EMA coefficient of variation) inflates
  the pressure estimate the same way the paper observes fluctuations eat
  half the ideal reduction.  Only at the *last* rung may the interval
  exceed the budget (escape valve, capped at ``max_interval``).
- **Interval sizing** is the §III.C frequency knob driven by the same
  probe, so elasticity reconfigs (which also touch the interval via
  ``adapt_interval``) and codec retuning share one control plane: the
  controller subscribes to the PR-1 ``EventBus`` and consumes the exact
  ``bandwidth_changed`` events the :class:`ElasticityController` sees.

HeterPS (arXiv:2111.10635) frames this knob-tuning as feedback scheduling;
TAAR (arXiv:2404.11352) shows network-aware adaptation is where the
remaining WAN wins live.  ``benchmarks/autotune.py`` measures the payoff:
time-to-target-loss on a fluctuating-bandwidth trace vs the best *static*
codec config, guard never violated.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

from repro.core.sync import CODEC_TIERS, SyncConfig

_EPS = 1e-12


@dataclass(frozen=True)
class BucketStats:
    """Per-bucket gradient statistics from the last codec sync round.

    Built from ``SyncState.msg_norm`` / ``resid_norm`` (the sync layer
    computes both inside the jitted sync step; the host just reads them).
    A ``msg_norm`` of 0 means "no reading yet" (first interval, or right
    after a pod resize re-armed the telemetry) — the controller then holds
    its rung and only retunes the interval.
    """

    msg_norm: float
    resid_norm: float

    @property
    def ef_ratio(self) -> float:
        """||residual|| / ||message|| — sqrt(1 - energy captured), in [0, 1)."""
        return self.resid_norm / (self.msg_norm + _EPS)

    @property
    def energy_capture(self) -> float:
        """Fraction of message energy the codec shipped last sync."""
        return max(0.0, 1.0 - self.ef_ratio ** 2)

    @classmethod
    def from_sync_state(cls, sync_state) -> "BucketStats":
        """Worst-pod reading: the pod whose residual ratio is highest
        governs (its model replica is the one compression hurts most)."""
        import numpy as np

        msg = np.asarray(sync_state.msg_norm, dtype=np.float64)
        res = np.asarray(sync_state.resid_norm, dtype=np.float64)
        if msg.size == 0 or float(msg.max()) <= 0.0:
            return cls(msg_norm=0.0, resid_norm=0.0)
        worst = int(np.argmax(res / (msg + _EPS)))
        return cls(msg_norm=float(msg[worst]), resid_norm=float(res[worst]))


@dataclass(frozen=True)
class WanProbe:
    """Smoothed WAN picture: bandwidth EMA + fluctuation (EMA coefficient
    of variation), fed by the simulator, a ``--wan-trace``, or
    ``bandwidth_changed`` events off the control-plane ``EventBus``."""

    bandwidth_mbps: float
    fluctuation: float = 0.0


@dataclass(frozen=True)
class SyncPlanUpdate:
    """Controller output: the retuned config plus why — applied through
    ``Trainer.retune`` at the next sync barrier, mirroring how the
    elasticity engine applies its ``ReconfigPlan``."""

    sync: SyncConfig
    step: int
    rung: int                      # index into the controller's ladder
    tier: int                      # index into sync.CODEC_TIERS
    reason: str
    probe: Optional[WanProbe] = None
    stats: Optional[BucketStats] = None

    def summary(self) -> str:
        s = self.sync
        return (f"rung {self.rung} ({CODEC_TIERS[self.tier]}"
                f"@topk={s.compress_topk}), interval {s.interval} "
                f"[{self.reason}]")


def build_ladder(base: SyncConfig,
                 topk_ladder: Sequence[float],
                 dtype_ladder: Sequence[str]) -> Tuple[SyncConfig, ...]:
    """The aggression ladder: every (compress_topk, value_dtype) combination
    of the candidate lists, sorted by wire bytes descending (rung 0 ships
    the most, the last rung the least).  Payload breaks ties toward the
    higher-precision dtype so equal-byte rungs (int8 vs fp8) still order
    deterministically, int8 first — one rung is always a strict (or
    precision-equivalent) de-escalation from the next."""
    rungs = [replace(base, compress_topk=f, value_dtype=d)
             for f in topk_ladder for d in dtype_ladder]
    return tuple(sorted(
        rungs, key=lambda c: (-c.payload_mb(1.0),
                              CODEC_TIERS.index(c.value_dtype))))


class AdaptiveSyncController:
    """Closed-loop per-bucket codec tuner (see module docstring).

    The controller is host-side and pure-Python: it never touches traced
    values, so a retune is an ordinary re-jit of the sync step (the same
    cost the elasticity engine already pays per reconfig).
    """

    def __init__(self, base_sync: SyncConfig, model_mb: float,
                 compute_step_s: float, *,
                 ef_guard: float = 0.9,
                 escalate_margin: float = 0.95,
                 target_comm_frac: float = 0.25,
                 topk_ladder: Sequence[float] = (0.05, 0.02, 0.01),
                 dtype_ladder: Sequence[str] = ("int8", "fp8", "int4"),
                 min_interval: int = 1, interval_budget: Optional[int] = None,
                 max_interval: int = 64,
                 hysteresis: int = 2, probe_alpha: float = 0.5,
                 bus=None):
        if not base_sync.uses_codec:
            raise ValueError(
                "AdaptiveSyncController tunes the fused codec: base_sync "
                "must have strategy='asgd_ga', 0 < compress_topk < 1 and "
                "quantize_int8=True")
        if not base_sync.error_feedback:
            raise ValueError(
                "AdaptiveSyncController's convergence guard is defined on "
                "the EF residual: base_sync must set error_feedback=True")
        if not 0.0 < ef_guard < 1.0:
            raise ValueError("ef_guard is a bound on ||resid||/||msg|| — "
                             "structurally in (0, 1)")
        if not 0.0 < escalate_margin <= 1.0:
            raise ValueError("escalate_margin must be in (0, 1]")
        self.model_mb = model_mb
        self.compute_step_s = compute_step_s
        self.ef_guard = ef_guard
        self.escalate_margin = escalate_margin
        self.target_comm_frac = target_comm_frac
        self.min_interval = min_interval
        self.interval_budget = (interval_budget if interval_budget is not None
                                else max(1, 2 * base_sync.interval))
        self.max_interval = max(max_interval, self.interval_budget)
        self.hysteresis = hysteresis
        self.probe_alpha = probe_alpha

        self.ladder = build_ladder(base_sync, topk_ladder, dtype_ladder)
        # start at the rung matching the base config (exact knob match if
        # present, else the closest payload), with the base interval
        self.rung = min(
            range(len(self.ladder)),
            key=lambda i: abs(self.ladder[i].payload_mb(1.0)
                              - base_sync.payload_mb(1.0)))
        self.interval = base_sync.interval
        self.current = replace(self.ladder[self.rung],
                               interval=self.interval)

        self._bw_ema: Optional[float] = None
        self._bw_var: float = 0.0      # EMA of squared relative deviation
        self._pressure_streak = 0
        self._calm_streak = 0
        self._last_stats: Optional[Tuple[float, float]] = None
        self.decisions: List[SyncPlanUpdate] = []
        self.max_ef_ratio = 0.0        # worst guard reading ever observed
        if bus is not None:
            bus.subscribe("bandwidth_changed", self.handle)

    # ------------------------------------------------------------- probes
    def observe_wan(self, bandwidth_mbps: float) -> WanProbe:
        """Fold an achieved-bandwidth sample into the EMA + fluctuation."""
        b = float(bandwidth_mbps)
        if self._bw_ema is None:
            self._bw_ema = b
        else:
            rel = (b - self._bw_ema) / (self._bw_ema + _EPS)
            self._bw_var += self.probe_alpha * (rel * rel - self._bw_var)
            self._bw_ema += self.probe_alpha * (b - self._bw_ema)
        return self.probe

    def handle(self, event) -> None:
        """EventBus subscriber — same ``bandwidth_changed`` CloudEvents the
        ElasticityController consumes (one control plane, two actuators:
        it re-plans resources, this retunes the codec)."""
        if getattr(event, "bandwidth_mbps", None) is not None:
            self.observe_wan(event.bandwidth_mbps)

    @property
    def probe(self) -> WanProbe:
        return WanProbe(
            bandwidth_mbps=self._bw_ema if self._bw_ema is not None else 0.0,
            fluctuation=self._bw_var ** 0.5)

    def resync(self, cfg: SyncConfig) -> None:
        """Re-anchor the belief state to an externally applied config.

        The elasticity engine shares the control plane and may rewrite the
        live sync settings (``adapt_interval`` in a reconfig); without
        re-anchoring, the controller would keep reasoning about knobs that
        are no longer the ones running — and emit no update because *its*
        state never changed."""
        self.rung = min(
            range(len(self.ladder)),
            key=lambda i: abs(self.ladder[i].payload_mb(1.0)
                              - cfg.payload_mb(1.0)))
        self.interval = cfg.interval
        self.current = replace(self.ladder[self.rung], interval=cfg.interval)
        self._pressure_streak = self._calm_streak = 0

    # ----------------------------------------------------------- decision
    def _comm_frac(self, cfg: SyncConfig) -> float:
        """Blocking share of one interval's wall clock under the current
        probe; fluctuation inflates it (a fluctuating link needs headroom —
        the paper: half the ideal reduction survives fluctuations)."""
        if self._bw_ema is None or self._bw_ema <= 0:
            return 0.0
        t_sync = cfg.payload_mb(self.model_mb) * 8.0 / self._bw_ema
        t_sync *= 1.0 + self.probe.fluctuation
        t_compute = max(cfg.interval, 1) * self.compute_step_s
        return t_sync / (t_sync + t_compute + _EPS)

    def _fit_interval(self, cfg: SyncConfig) -> int:
        """Smallest interval keeping the blocking share at/below target."""
        if self._bw_ema is None or self._bw_ema <= 0:
            return cfg.interval
        t_sync = (cfg.payload_mb(self.model_mb) * 8.0 / self._bw_ema
                  * (1.0 + self.probe.fluctuation))
        f = self.target_comm_frac
        want = t_sync * (1.0 - f) / (f * self.compute_step_s + _EPS)
        return max(self.min_interval,
                   min(self.max_interval, math.ceil(want)))

    def update(self, step: int, stats: BucketStats
               ) -> Optional[SyncPlanUpdate]:
        """One control step, called at a sync barrier with that round's
        bucket statistics.  Returns a plan update when any knob moved."""
        have_reading = stats.msg_norm > 0.0
        # consume-once: stats only change at sync rounds, but update() runs
        # every step — a reading may only *trigger* the guard the step it
        # arrives, or one bad sync would de-escalate a rung per step until
        # the next sync, punishing rungs that were never measured.  (It
        # still *gates* escalation while stale: absence of fresh evidence
        # is not evidence of calm.)
        fresh = (have_reading
                 and (stats.msg_norm, stats.resid_norm) != self._last_stats)
        if fresh:
            self._last_stats = (stats.msg_norm, stats.resid_norm)
        ratio = stats.ef_ratio if have_reading else 0.0
        if fresh:
            self.max_ef_ratio = max(self.max_ef_ratio, ratio)

        rung, reason = self.rung, ""
        if fresh and ratio >= self.ef_guard:
            # convergence guard tripped: de-escalate NOW, no hysteresis —
            # never trade fidelity away while EF is drowning
            rung, reason = max(0, self.rung - 1), "ef-guard"
            self._pressure_streak = self._calm_streak = 0
        else:
            fit = self._fit_interval(self.ladder[self.rung])
            if fit > self.interval_budget:
                # only more staleness could absorb the link: rung pressure
                self._pressure_streak += 1
                self._calm_streak = 0
            elif fit <= max(self.min_interval, self.interval_budget // 2):
                self._calm_streak += 1
                self._pressure_streak = 0
            else:
                self._pressure_streak = self._calm_streak = 0
            guard_calm = (have_reading
                          and ratio < self.escalate_margin * self.ef_guard)
            if (self._pressure_streak >= self.hysteresis and guard_calm
                    and self.rung + 1 < len(self.ladder)):
                # escalation is urgent (every sync at the stale rung pays
                # the slow link): jump straight to the least aggressive
                # rung whose fitted interval respects the staleness
                # budget, instead of paying a transfer per transit rung
                rung = next(
                    (i for i in range(self.rung + 1, len(self.ladder))
                     if self._fit_interval(self.ladder[i])
                     <= self.interval_budget),
                    len(self.ladder) - 1)
                reason = "wan-pressure"
                self._pressure_streak = 0
            elif (self._calm_streak >= 4 * self.hysteresis and self.rung > 0
                  and self._fit_interval(self.ladder[self.rung - 1])
                  <= self.interval_budget):
                # de-escalation is a luxury (fidelity, not survival): one
                # rung at a time, on a 4x longer streak — cheap insurance
                # against ping-ponging on a link that is merely twitchy
                rung, reason = self.rung - 1, "wan-headroom"
                self._calm_streak = 0

        cfg = self.ladder[rung]
        # the staleness budget caps the interval at every rung but the
        # last, where it is the escape valve for a link no tier can absorb
        cap = (self.max_interval if rung == len(self.ladder) - 1
               else self.interval_budget)
        interval = min(self._fit_interval(cfg), cap)
        if rung == self.rung:
            # deadband: don't churn re-jits on small EMA wiggle — retune
            # the interval alone only when it moves by >= 25%
            if interval == self.interval or (
                    not reason
                    and abs(interval - self.interval)
                    < max(1.0, 0.25 * self.interval)):
                return None
        if not reason:
            reason = "interval-fit"
        self.rung = rung
        self.interval = interval
        self.current = replace(cfg, interval=interval)
        update = SyncPlanUpdate(
            sync=self.current, step=step, rung=rung,
            tier=self.current.tier, reason=reason,
            probe=self.probe, stats=stats if have_reading else None)
        self.decisions.append(update)
        return update
