"""Adaptive WAN sync autotuner: per-bucket precision/sparsity controller.

The paper's headline speedup comes from *matching* the sync strategy to WAN
conditions — but its WAN exhibits "low bandwidth and high fluctuations", so a
launch-time choice of ``compress_topk`` / payload tier / ``interval`` is
wrong whenever the network moves.  This module closes the loop the ROADMAP
calls for ("per-bucket adaptive compress_topk from gradient statistics"):

  signals                        decision                      reconfig
  ───────                        ────────                      ────────
  BucketStats (EF-residual       AdaptiveSyncController        SyncPlanUpdate
  ratio + top-k energy capture,  walks a payload-aggression    -> Trainer.retune
  from SyncState.msg_norm /      ladder (compress_topk x       at the next sync
  resid_norm)                    value_dtype rungs, sorted     barrier (EF
  WanProbe (achieved bandwidth   by wire bytes) under a        residual carries
  EMA + fluctuation, from the    user-set convergence guard,   over — dense
  simulator / --wan-trace /      and sizes ``interval`` so     bucket coords
  EventBus bandwidth_changed —   per-step blocking comm        are tier-free)
  or, in measured mode, from     stays on target
  transport-reported transfer
  times via repro.core.transport
  .MeasuredWanProbe feeding an
  injected ``probe_est``)

Control law (deterministic, hysteresis-damped):

- **Convergence guard** (the hard rule): the EF-residual ratio
  ``||resid|| / ||message||`` is ``sqrt(1 - energy_capture)`` of the last
  sync — structurally in [0, 1), rising toward 1 as the tier drops more
  than error feedback can re-ship per interval.  If it reaches ``ef_guard``
  the controller *immediately* de-escalates one rung, and it never
  escalates unless the ratio is below ``escalate_margin * ef_guard``.
  This is the invariant the property tests pin: under NO input sequence
  does the controller escalate while the guard is tripped.  (Scale note:
  with error feedback a ratio of ~0.85 is *healthy* — the codec benches
  hit 99.9% of dense loss reduction there, because everything dropped is
  re-shipped next interval — so guards live near 1 and the escalation
  margin is deliberately thin.)
- **WAN pressure**: from the bandwidth EMA the controller estimates the
  blocking sync time ``payload * 8 / bw`` and fits the smallest interval
  keeping its per-step share at ``target_comm_frac`` of compute.  The fit
  is bounded by a **staleness budget** (``interval_budget``, default the
  base config's interval x2): when the fitted interval busts the budget for
  ``hysteresis`` consecutive updates — i.e. only *more staleness* could
  absorb the bandwidth drop — the controller escalates, jumping straight
  to the least aggressive rung whose fit respects the budget (transit
  rungs would each pay a transfer on the slow link); when the fit falls
  far below budget for a 4x longer streak it de-escalates one rung to
  buy back fidelity.  Fluctuation (EMA coefficient of variation) inflates
  the pressure estimate the same way the paper observes fluctuations eat
  half the ideal reduction.  Only at the *last* rung may the interval
  exceed the budget (escape valve, capped at ``max_interval``).
- **Interval sizing** is the §III.C frequency knob driven by the same
  probe, so elasticity reconfigs (which also touch the interval via
  ``adapt_interval``) and codec retuning share one control plane: the
  controller subscribes to the PR-1 ``EventBus`` and consumes the exact
  ``bandwidth_changed`` events the :class:`ElasticityController` sees.

HeterPS (arXiv:2111.10635) frames this knob-tuning as feedback scheduling;
TAAR (arXiv:2404.11352) shows network-aware adaptation is where the
remaining WAN wins live.  ``benchmarks/autotune.py`` measures the payoff:
time-to-target-loss on a fluctuating-bandwidth trace vs the best *static*
codec config, guard never violated.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.sync import BucketOverride, CODEC_TIERS, SyncConfig

_EPS = 1e-12


@dataclass(frozen=True)
class BucketStats:
    """Per-bucket gradient statistics from the last codec sync round.

    Built from ``SyncState.msg_norm`` / ``resid_norm`` (the sync layer
    computes both inside the jitted sync step; the host just reads them).
    A ``msg_norm`` of 0 means "no reading yet" (first interval, or right
    after a pod resize re-armed the telemetry) — the controller then holds
    its rung and only retunes the interval.
    """

    msg_norm: float
    resid_norm: float

    @property
    def ef_ratio(self) -> float:
        """||residual|| / ||message|| — sqrt(1 - energy captured), in [0, 1)."""
        return self.resid_norm / (self.msg_norm + _EPS)

    @property
    def energy_capture(self) -> float:
        """Fraction of message energy the codec shipped last sync."""
        return max(0.0, 1.0 - self.ef_ratio ** 2)

    @classmethod
    def from_sync_state(cls, sync_state,
                        bucket: Optional[int] = None) -> "BucketStats":
        """Worst-pod reading: the pod whose residual ratio is highest
        governs (its model replica is the one compression hurts most).

        ``SyncState`` telemetry is (n_pods, n_buckets); ``bucket`` selects
        one column, ``None`` takes the worst entry across all buckets (the
        single-controller view of a possibly-partitioned payload)."""
        import numpy as np

        msg = np.asarray(sync_state.msg_norm, dtype=np.float64)
        res = np.asarray(sync_state.resid_norm, dtype=np.float64)
        if bucket is not None and msg.ndim == 2:
            msg, res = msg[:, bucket], res[:, bucket]
        msg, res = msg.ravel(), res.ravel()
        keep = msg > 0.0          # empty buckets / no reading yet
        if msg.size == 0 or not keep.any():
            return cls(msg_norm=0.0, resid_norm=0.0)
        msg, res = msg[keep], res[keep]
        worst = int(np.argmax(res / (msg + _EPS)))
        return cls(msg_norm=float(msg[worst]), resid_norm=float(res[worst]))


def bucket_stats_from_sync_state(sync_state, names: Sequence[str]
                                 ) -> Dict[str, BucketStats]:
    """One worst-pod :class:`BucketStats` per named bucket group — the
    :class:`BucketedSyncController`'s input (``names`` in segment order,
    i.e. ``SyncConfig.bucket_names``)."""
    return {name: BucketStats.from_sync_state(sync_state, bucket=g)
            for g, name in enumerate(names)}


class WanProbeEstimator:
    """Bandwidth EMA + fluctuation estimator, shareable across controllers.

    The per-bucket controller holds ONE of these for all bucket rungs (the
    WAN does not care which bucket's bytes it carries), and the single-
    bucket controller embeds its own; both consume the same achieved-
    bandwidth samples (simulator, ``--wan-trace``, or ``bandwidth_changed``
    events off the control-plane bus).

    ``cliff_snap`` (off at 0): when a sample comes in more than
    ``cliff_snap``x BELOW the EMA, the belief snaps to the sample instead
    of averaging toward it — smoothing exists for noise, and a bandwidth
    collapse is not noise.  The fluctuation estimate still absorbs the
    full deviation first (a cliff IS fluctuation), and recoveries stay
    smoothed (optimism is what the EMA protects against).  The
    multi-bucket controller enables this by default, so one observation
    of a crashed link reprices every bucket's escalation before the next
    transfer is paid."""

    def __init__(self, alpha: float = 0.5, cliff_snap: float = 0.0):
        self.alpha = alpha
        self.cliff_snap = cliff_snap
        self._ema: Optional[float] = None
        self._var: float = 0.0        # EMA of squared relative deviation

    def observe(self, bandwidth_mbps: float) -> "WanProbe":
        b = float(bandwidth_mbps)
        if self._ema is None:
            self._ema = b
        else:
            rel = (b - self._ema) / (self._ema + _EPS)
            self._var += self.alpha * (rel * rel - self._var)
            if self.cliff_snap > 0 and b * self.cliff_snap < self._ema:
                self._ema = b
            else:
                self._ema += self.alpha * (b - self._ema)
        return self.probe

    @property
    def bandwidth_mbps(self) -> Optional[float]:
        return self._ema

    @property
    def probe(self) -> "WanProbe":
        return WanProbe(
            bandwidth_mbps=self._ema if self._ema is not None else 0.0,
            fluctuation=self._var ** 0.5)


@dataclass(frozen=True)
class WanProbe:
    """Smoothed WAN picture: bandwidth EMA + fluctuation (EMA coefficient
    of variation), fed by the simulator, a ``--wan-trace``, or
    ``bandwidth_changed`` events off the control-plane ``EventBus``."""

    bandwidth_mbps: float
    fluctuation: float = 0.0


def trend_tripped(trend: Sequence[float], window: int, rise: float,
                  guard: float) -> bool:
    """The residual *growth-trend* guard predicate, shared by both
    controllers so the single- and multi-bucket control laws cannot drift:
    a full ``window`` of strictly rising fresh EF-ratio readings whose
    extrapolation (one more window at the observed rise) would cross the
    absolute ``guard``.  Catches a slowly diverging rung *before* the
    bound trips — by which point an interval's worth of gradient mass is
    already stuck in the residual — while staying quiet on noise (any dip
    resets the run) and on benign drift far below the guard (the
    extrapolation test)."""
    if len(trend) < window:
        return False
    win = list(trend[-window:])
    total = win[-1] - win[0]
    return (total >= rise
            and all(y > x for x, y in zip(win, win[1:]))
            and win[-1] + total >= guard)


@dataclass(frozen=True)
class SyncPlanUpdate:
    """Controller output: the retuned config plus why — applied through
    ``Trainer.retune`` at the next sync barrier, mirroring how the
    elasticity engine applies its ``ReconfigPlan``."""

    sync: SyncConfig
    step: int
    rung: int                      # index into the controller's ladder
    tier: int                      # index into sync.CODEC_TIERS
    reason: str
    probe: Optional[WanProbe] = None
    stats: Optional[BucketStats] = None
    topology: Optional[str] = None  # active aggregation shape, when a
    #   TopologyPlanner is wired in (the third actuator)

    def summary(self) -> str:
        s = self.sync
        out = (f"rung {self.rung} ({CODEC_TIERS[self.tier]}"
               f"@topk={s.compress_topk}), interval {s.interval} "
               f"[{self.reason}]")
        if self.topology is not None:
            out += f" topo={self.topology}"
        return out


def build_ladder(base: SyncConfig,
                 topk_ladder: Sequence[float],
                 dtype_ladder: Sequence[str]) -> Tuple[SyncConfig, ...]:
    """The aggression ladder: every (compress_topk, value_dtype) combination
    of the candidate lists, sorted by wire bytes descending (rung 0 ships
    the most, the last rung the least).  Payload breaks ties toward the
    higher-precision dtype so equal-byte rungs (int8 vs fp8) still order
    deterministically, int8 first — one rung is always a strict (or
    precision-equivalent) de-escalation from the next."""
    rungs = [replace(base, compress_topk=f, value_dtype=d)
             for f in topk_ladder for d in dtype_ladder]
    return tuple(sorted(
        rungs, key=lambda c: (-c.payload_mb(1.0),
                              CODEC_TIERS.index(c.value_dtype))))


class AdaptiveSyncController:
    """Closed-loop per-bucket codec tuner (see module docstring).

    The controller is host-side and pure-Python: it never touches traced
    values, so a retune is an ordinary re-jit of the sync step (the same
    cost the elasticity engine already pays per reconfig).
    """

    def __init__(self, base_sync: SyncConfig, model_mb: float,
                 compute_step_s: float, *,
                 ef_guard: float = 0.9,
                 escalate_margin: float = 0.95,
                 target_comm_frac: float = 0.25,
                 topk_ladder: Sequence[float] = (0.05, 0.02, 0.01),
                 dtype_ladder: Sequence[str] = ("int8", "fp8", "int4"),
                 min_interval: int = 1, interval_budget: Optional[int] = None,
                 max_interval: int = 64,
                 hysteresis: int = 2, probe_alpha: float = 0.5,
                 trend_window: int = 4, trend_rise: float = 0.02,
                 probe_est: Optional[WanProbeEstimator] = None,
                 topology=None, bus=None):
        if not base_sync.uses_codec:
            raise ValueError(
                "AdaptiveSyncController tunes the fused codec: base_sync "
                "must have strategy='asgd_ga', 0 < compress_topk < 1 and "
                "quantize_int8=True")
        if not base_sync.error_feedback:
            raise ValueError(
                "AdaptiveSyncController's convergence guard is defined on "
                "the EF residual: base_sync must set error_feedback=True")
        if not 0.0 < ef_guard < 1.0:
            raise ValueError("ef_guard is a bound on ||resid||/||msg|| — "
                             "structurally in (0, 1)")
        if not 0.0 < escalate_margin <= 1.0:
            raise ValueError("escalate_margin must be in (0, 1]")
        if trend_window < 2:
            raise ValueError("trend_window must be >= 2 (a slope needs at "
                             "least two readings)")
        self.model_mb = model_mb
        self.compute_step_s = compute_step_s
        self.ef_guard = ef_guard
        self.escalate_margin = escalate_margin
        self.target_comm_frac = target_comm_frac
        self.min_interval = min_interval
        self.interval_budget = (interval_budget if interval_budget is not None
                                else max(1, 2 * base_sync.interval))
        self.max_interval = max(max_interval, self.interval_budget)
        self.hysteresis = hysteresis
        self.probe_alpha = probe_alpha
        self.trend_window = trend_window
        self.trend_rise = trend_rise

        self.ladder = build_ladder(base_sync, topk_ladder, dtype_ladder)
        # start at the rung matching the base config (exact knob match if
        # present, else the closest payload), with the base interval
        self.rung = min(
            range(len(self.ladder)),
            key=lambda i: abs(self.ladder[i].payload_mb(1.0)
                              - base_sync.payload_mb(1.0)))
        self.interval = base_sync.interval
        self.current = replace(self.ladder[self.rung],
                               interval=self.interval)

        self._probe_est = (probe_est if probe_est is not None
                           else WanProbeEstimator(alpha=probe_alpha))
        # third actuator (duck-typed to avoid a core.topology import
        # cycle): anything with .kind and .decide(step, payload_mb) — in
        # practice a topology.TopologyPlanner sharing the transport's
        # LinkBeliefs
        self.topology = topology
        self._pressure_streak = 0
        self._calm_streak = 0
        self._last_stats: Optional[Tuple[float, float]] = None
        self._trend: List[float] = []  # fresh EF-ratio readings, newest last
        self.decisions: List[SyncPlanUpdate] = []
        self.max_ef_ratio = 0.0        # worst guard reading ever observed
        if bus is not None:
            bus.subscribe("bandwidth_changed", self.handle)

    # ------------------------------------------------------------- probes
    @property
    def _bw_ema(self) -> Optional[float]:
        return self._probe_est.bandwidth_mbps

    def observe_wan(self, bandwidth_mbps: float) -> WanProbe:
        """Fold an achieved-bandwidth sample into the EMA + fluctuation."""
        return self._probe_est.observe(bandwidth_mbps)

    def handle(self, event) -> None:
        """EventBus subscriber — same ``bandwidth_changed`` CloudEvents the
        ElasticityController consumes (one control plane, two actuators:
        it re-plans resources, this retunes the codec)."""
        if getattr(event, "bandwidth_mbps", None) is not None:
            self.observe_wan(event.bandwidth_mbps)

    @property
    def probe(self) -> WanProbe:
        return self._probe_est.probe

    # -------------------------------------------------- growth-trend guard
    def _trend_tripped(self) -> bool:
        """See :func:`trend_tripped` (shared with the bucketed law)."""
        return trend_tripped(self._trend, self.trend_window,
                             self.trend_rise, self.ef_guard)

    def resync(self, cfg: SyncConfig) -> None:
        """Re-anchor the belief state to an externally applied config.

        The elasticity engine shares the control plane and may rewrite the
        live sync settings (``adapt_interval`` in a reconfig); without
        re-anchoring, the controller would keep reasoning about knobs that
        are no longer the ones running — and emit no update because *its*
        state never changed."""
        self.rung = min(
            range(len(self.ladder)),
            key=lambda i: abs(self.ladder[i].payload_mb(1.0)
                              - cfg.payload_mb(1.0)))
        self.interval = cfg.interval
        self.current = replace(self.ladder[self.rung], interval=cfg.interval)
        self._pressure_streak = self._calm_streak = 0
        self._trend.clear()   # readings under the old knobs say nothing
        #   about the drift of the rung now running

    # ----------------------------------------------------------- decision
    def _comm_frac(self, cfg: SyncConfig) -> float:
        """Blocking share of one interval's wall clock under the current
        probe; fluctuation inflates it (a fluctuating link needs headroom —
        the paper: half the ideal reduction survives fluctuations)."""
        if self._bw_ema is None or self._bw_ema <= 0:
            return 0.0
        t_sync = cfg.payload_mb(self.model_mb) * 8.0 / self._bw_ema
        t_sync *= 1.0 + self.probe.fluctuation
        t_compute = max(cfg.interval, 1) * self.compute_step_s
        return t_sync / (t_sync + t_compute + _EPS)

    def _fit_interval(self, cfg: SyncConfig) -> int:
        """Smallest interval keeping the blocking share at/below target."""
        if self._bw_ema is None or self._bw_ema <= 0:
            return cfg.interval
        t_sync = (cfg.payload_mb(self.model_mb) * 8.0 / self._bw_ema
                  * (1.0 + self.probe.fluctuation))
        f = self.target_comm_frac
        want = t_sync * (1.0 - f) / (f * self.compute_step_s + _EPS)
        return max(self.min_interval,
                   min(self.max_interval, math.ceil(want)))

    def update(self, step: int, stats: BucketStats
               ) -> Optional[SyncPlanUpdate]:
        """One control step, called at a sync barrier with that round's
        bucket statistics.  Returns a plan update when any knob moved."""
        have_reading = stats.msg_norm > 0.0
        # consume-once: stats only change at sync rounds, but update() runs
        # every step — a reading may only *trigger* the guard the step it
        # arrives, or one bad sync would de-escalate a rung per step until
        # the next sync, punishing rungs that were never measured.  (It
        # still *gates* escalation while stale: absence of fresh evidence
        # is not evidence of calm.)
        fresh = (have_reading
                 and (stats.msg_norm, stats.resid_norm) != self._last_stats)
        if fresh:
            self._last_stats = (stats.msg_norm, stats.resid_norm)
        ratio = stats.ef_ratio if have_reading else 0.0
        if fresh:
            self.max_ef_ratio = max(self.max_ef_ratio, ratio)
            self._trend.append(ratio)
            if len(self._trend) > self.trend_window:
                del self._trend[0]

        rung, reason = self.rung, ""
        if fresh and ratio >= self.ef_guard:
            # convergence guard tripped: de-escalate NOW, no hysteresis —
            # never trade fidelity away while EF is drowning
            rung, reason = max(0, self.rung - 1), "ef-guard"
            self._pressure_streak = self._calm_streak = 0
        elif fresh and self.rung > 0 and self._trend_tripped():
            # growth-trend guard: the ratio is strictly rising toward the
            # bound — step back one rung while the residual is still
            # recoverable instead of waiting for the absolute trip
            rung, reason = self.rung - 1, "ef-trend"
            self._pressure_streak = self._calm_streak = 0
        else:
            fit = self._fit_interval(self.ladder[self.rung])
            if fit > self.interval_budget:
                # only more staleness could absorb the link: rung pressure
                self._pressure_streak += 1
                self._calm_streak = 0
            elif fit <= max(self.min_interval, self.interval_budget // 2):
                self._calm_streak += 1
                self._pressure_streak = 0
            else:
                self._pressure_streak = self._calm_streak = 0
            guard_calm = (have_reading
                          and ratio < self.escalate_margin * self.ef_guard)
            if (self._pressure_streak >= self.hysteresis and guard_calm
                    and self.rung + 1 < len(self.ladder)):
                # escalation is urgent (every sync at the stale rung pays
                # the slow link): jump straight to the least aggressive
                # rung whose fitted interval respects the staleness
                # budget, instead of paying a transfer per transit rung
                rung = next(
                    (i for i in range(self.rung + 1, len(self.ladder))
                     if self._fit_interval(self.ladder[i])
                     <= self.interval_budget),
                    len(self.ladder) - 1)
                reason = "wan-pressure"
                self._pressure_streak = 0
            elif (self._calm_streak >= 4 * self.hysteresis and self.rung > 0
                  and self._fit_interval(self.ladder[self.rung - 1])
                  <= self.interval_budget):
                # de-escalation is a luxury (fidelity, not survival): one
                # rung at a time, on a 4x longer streak — cheap insurance
                # against ping-ponging on a link that is merely twitchy
                rung, reason = self.rung - 1, "wan-headroom"
                self._calm_streak = 0

        # third actuator: consult the topology planner on fresh readings
        # only, and never while a guard is de-escalating — a tripped EF
        # guard means fidelity is the problem, and reshaping the network
        # in the same breath would blur which actuator fixed it
        topo = None
        if (self.topology is not None and fresh
                and reason not in ("ef-guard", "ef-trend")):
            topo = self.topology.decide(
                step, self.ladder[rung].payload_mb(self.model_mb))

        cfg = self.ladder[rung]
        # the staleness budget caps the interval at every rung but the
        # last, where it is the escape valve for a link no tier can absorb
        cap = (self.max_interval if rung == len(self.ladder) - 1
               else self.interval_budget)
        interval = min(self._fit_interval(cfg), cap)
        if rung == self.rung:
            # deadband: don't churn re-jits on small EMA wiggle — retune
            # the interval alone only when it moves by >= 25%
            if interval == self.interval or (
                    not reason
                    and abs(interval - self.interval)
                    < max(1.0, 0.25 * self.interval)):
                if topo is None:
                    return None
                # topology-only move: the codec knobs stand as they are
                interval = self.interval
        if not reason:
            reason = f"topo-{topo}" if topo is not None else "interval-fit"
        if rung != self.rung:
            self._trend.clear()   # new rung, new drift regime
        self.rung = rung
        self.interval = interval
        self.current = replace(cfg, interval=interval)
        update = SyncPlanUpdate(
            sync=self.current, step=step, rung=rung,
            tier=self.current.tier, reason=reason,
            probe=self.probe, stats=stats if have_reading else None,
            topology=(self.topology.kind if self.topology is not None
                      else None))
        self.decisions.append(update)
        return update


# ---------------------------------------------------------------------------
# per-bucket control: one rung per layer-class bucket group
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BucketPlanUpdate:
    """Multi-bucket controller output: the combined retuned config (per-
    bucket overrides + shared interval) plus which bucket moved and why —
    applied through ``Trainer.retune`` exactly like a single-bucket
    :class:`SyncPlanUpdate`."""

    sync: SyncConfig
    step: int
    rungs: Tuple[Tuple[str, int, int], ...]   # (bucket, rung, tier) each
    reasons: Tuple[str, ...]
    probe: Optional[WanProbe] = None
    topology: Optional[str] = None  # active aggregation shape, when a
    #   TopologyPlanner is wired in (the third actuator)

    def summary(self) -> str:
        knobs = ", ".join(
            f"{name}={CODEC_TIERS[tier]}@r{rung}"
            for name, rung, tier in self.rungs)
        out = (f"[{knobs}], interval {self.sync.interval} "
               f"[{'; '.join(self.reasons)}]")
        if self.topology is not None:
            out += f" topo={self.topology}"
        return out


class _BucketRung:
    """One bucket group's ladder position + guard state (the per-bucket
    slice of what :class:`AdaptiveSyncController` tracks globally)."""

    def __init__(self, name: str, ladder: Tuple[SyncConfig, ...],
                 rung: int, model_mb: float):
        self.name = name
        self.ladder = ladder
        self.rung = rung
        self.model_mb = model_mb
        self.last_stats: Optional[Tuple[float, float]] = None
        self.trend: List[float] = []
        self.ratio = 0.0              # last observed EF ratio
        self.has_reading = False
        self.max_ef_ratio = 0.0

    def payload_mb(self, rung: Optional[int] = None) -> float:
        r = self.rung if rung is None else rung
        return self.ladder[r].payload_mb(self.model_mb)

    @property
    def cfg(self) -> SyncConfig:
        return self.ladder[self.rung]


class BucketedSyncController:
    """Per-bucket adaptive codec control: one aggression-ladder rung per
    layer-class bucket group, one shared WAN picture.

    The split follows the physics: gradient statistics (and therefore how
    much compression a tensor tolerates) are a property of the *layer
    class* — embeddings, norms, dense bulk, MoE experts — while bandwidth
    is a property of the *link*.  So EF statistics, ladders, trend state
    and guards are per bucket, and the bandwidth probe/EMA, pressure
    streaks and the sync interval are shared:

    - **Guards are per bucket and autonomous**: a bucket whose EF ratio
      trips ``ef_guard`` (or whose ratio is trending into it — the
      growth-trend guard) de-escalates *that bucket only*, immediately.
      Other buckets keep their aggression: the whole point is not paying
      embed-grade fidelity for norm-grade sensitivity.
    - **WAN pressure is shared and escalation is greedy-by-bytes**: when
      the fitted interval (from the summed per-bucket payloads) busts the
      staleness budget for ``hysteresis`` updates, the controller
      escalates guard-calm buckets one rung at a time in order of wire
      bytes saved, until the fit respects the budget — the cheapest
      fidelity is traded first, and a guard-stressed bucket is never
      escalated regardless of pressure.
    - **Headroom returns fidelity where it hurts most**: on a long calm
      streak the bucket with the highest observed EF ratio de-escalates
      first.
    - **One interval**: sync rounds are barriers, so the interval is fitted
      once from the total payload and capped by the staleness budget
      (escape valve only when every bucket sits at its last rung).

    The controller is host-side and deterministic; ``benchmarks/autotune``
    records its per-bucket signal stream so ``check_regression`` replays
    the multi-controller trace in CI.
    """

    def __init__(self, base_sync: SyncConfig, bucket_mb: Mapping[str, float],
                 compute_step_s: float, *,
                 ef_guard: float = 0.9,
                 escalate_margin: float = 0.95,
                 target_comm_frac: float = 0.25,
                 topk_ladder: Sequence[float] = (0.05, 0.02, 0.01),
                 dtype_ladder: Sequence[str] = ("int8", "fp8", "int4"),
                 min_interval: int = 1, interval_budget: Optional[int] = None,
                 max_interval: int = 64,
                 hysteresis: int = 2, probe_alpha: float = 0.5,
                 trend_window: int = 4, trend_rise: float = 0.02,
                 cliff_snap: float = 4.0,
                 probe_est: Optional[WanProbeEstimator] = None,
                 topology=None, bus=None):
        if base_sync.bucket_policy != "layer-class":
            raise ValueError(
                "BucketedSyncController drives the layer-class partition: "
                "base_sync must set bucket_policy='layer-class' (for one "
                "flat bucket use AdaptiveSyncController)")
        if not (base_sync.uses_codec and base_sync.error_feedback):
            raise ValueError(
                "BucketedSyncController tunes the fused codec under the EF "
                "guard: base_sync must have strategy='asgd_ga', "
                "0 < compress_topk < 1, quantize_int8=True and "
                "error_feedback=True")
        if not 0.0 < ef_guard < 1.0:
            raise ValueError("ef_guard is a bound on ||resid||/||msg|| — "
                             "structurally in (0, 1)")
        self.compute_step_s = compute_step_s
        self.ef_guard = ef_guard
        self.escalate_margin = escalate_margin
        self.target_comm_frac = target_comm_frac
        self.min_interval = min_interval
        self.interval_budget = (interval_budget if interval_budget is not None
                                else max(1, 2 * base_sync.interval))
        self.max_interval = max(max_interval, self.interval_budget)
        self.hysteresis = hysteresis
        self.trend_window = trend_window
        self.trend_rise = trend_rise
        self.base_sync = base_sync

        # controlled buckets: the groups that actually hold model bytes
        # (a dense-only model has empty embed/moe groups — nothing to tune)
        self.buckets: Dict[str, _BucketRung] = {}
        for name in base_sync.bucket_names:
            mb = float(bucket_mb.get(name, 0.0))
            if mb <= 0.0:
                continue
            ladder = build_ladder(base_sync.for_bucket(name),
                                  topk_ladder, dtype_ladder)
            start = base_sync.for_bucket(name)
            rung = min(range(len(ladder)),
                       key=lambda i: abs(ladder[i].payload_mb(1.0)
                                         - start.payload_mb(1.0)))
            self.buckets[name] = _BucketRung(name, ladder, rung, mb)
        if not self.buckets:
            raise ValueError("bucket_mb holds no positive-size bucket group")

        self.interval = base_sync.interval
        # an injected estimator (probe_est) is how measured-feedback mode
        # works: a transport's MeasuredWanProbe owns the estimator and
        # feeds it achieved-bandwidth samples derived from transfer times,
        # and this controller just reads the shared belief — no trace, no
        # bus events, same control law (mirrors AdaptiveSyncController)
        self._probe_est = (probe_est if probe_est is not None
                           else WanProbeEstimator(alpha=probe_alpha,
                                                  cliff_snap=cliff_snap))
        # third actuator (duck-typed to avoid a core.topology import
        # cycle, same seam as the single-bucket controller): anything with
        # .kind and .decide(step, payload_mb) — a topology.TopologyPlanner
        # sharing the transport's LinkBeliefs
        self.topology = topology
        self._pressure_streak = 0
        self._calm_streak = 0
        self.decisions: List[BucketPlanUpdate] = []
        if bus is not None:
            bus.subscribe("bandwidth_changed", self.handle)

    # ------------------------------------------------------------- probes
    def observe_wan(self, bandwidth_mbps: float) -> WanProbe:
        return self._probe_est.observe(bandwidth_mbps)

    def handle(self, event) -> None:
        if getattr(event, "bandwidth_mbps", None) is not None:
            self.observe_wan(event.bandwidth_mbps)

    @property
    def probe(self) -> WanProbe:
        return self._probe_est.probe

    @property
    def max_ef_ratio(self) -> float:
        """Worst guard reading ever observed across all buckets."""
        return max((b.max_ef_ratio for b in self.buckets.values()),
                   default=0.0)

    @property
    def max_ef_ratio_by_bucket(self) -> Dict[str, float]:
        return {n: b.max_ef_ratio for n, b in self.buckets.items()}

    # ------------------------------------------------------------ assembly
    def _total_payload_mb(self,
                          rungs: Optional[Mapping[str, int]] = None) -> float:
        return sum(b.payload_mb(None if rungs is None else rungs[n])
                   for n, b in self.buckets.items())

    @property
    def current(self) -> SyncConfig:
        """The combined live config: per-bucket overrides on the base."""
        overrides = tuple(
            BucketOverride(name=n,
                           compress_topk=b.cfg.compress_topk,
                           value_dtype=b.cfg.value_dtype)
            for n, b in self.buckets.items())
        return replace(self.base_sync, buckets=overrides,
                       interval=self.interval)

    def resync(self, cfg: SyncConfig) -> None:
        """Re-anchor to an externally applied config (elasticity reconfigs
        rewrite the live sync settings — same contract as the single-bucket
        controller's ``resync``)."""
        for n, b in self.buckets.items():
            eff = cfg.for_bucket(n)
            b.rung = min(range(len(b.ladder)),
                         key=lambda i: abs(b.ladder[i].payload_mb(1.0)
                                           - eff.payload_mb(1.0)))
            b.trend.clear()
        self.interval = cfg.interval
        self._pressure_streak = self._calm_streak = 0

    def _fit_interval(self, payload_mb: float) -> int:
        if self._probe_est.bandwidth_mbps is None \
                or self._probe_est.bandwidth_mbps <= 0:
            return self.interval
        t_sync = (payload_mb * 8.0 / self._probe_est.bandwidth_mbps
                  * (1.0 + self.probe.fluctuation))
        f = self.target_comm_frac
        want = t_sync * (1.0 - f) / (f * self.compute_step_s + _EPS)
        return max(self.min_interval,
                   min(self.max_interval, math.ceil(want)))

    # ----------------------------------------------------------- decision
    def _bucket_guards(self, stats: Mapping[str, BucketStats]) -> List[str]:
        """Per-bucket absolute + growth-trend guards; returns reasons."""
        reasons = []
        self._fresh_any = False   # did ANY bucket deliver a fresh reading
        #   this update — the topology planner's consultation gate
        for n, b in self.buckets.items():
            s = stats.get(n)
            if s is None or s.msg_norm <= 0.0:
                # no CURRENT reading (first interval, or a pod resize just
                # re-armed the telemetry): stale evidence of calm must not
                # license an escalation — same rule as the single-bucket
                # controller, which gates on the reading it was handed
                b.has_reading = False
                continue
            fresh = (s.msg_norm, s.resid_norm) != b.last_stats
            b.ratio, b.has_reading = s.ef_ratio, True
            if not fresh:
                continue
            self._fresh_any = True
            b.last_stats = (s.msg_norm, s.resid_norm)
            b.max_ef_ratio = max(b.max_ef_ratio, s.ef_ratio)
            b.trend.append(s.ef_ratio)
            if len(b.trend) > self.trend_window:
                del b.trend[0]
            if s.ef_ratio >= self.ef_guard:
                if b.rung > 0:
                    b.rung -= 1
                    b.trend.clear()
                reasons.append(f"ef-guard[{n}]")
            elif b.rung > 0 and self._trend_tripped(b):
                b.rung -= 1
                b.trend.clear()
                reasons.append(f"ef-trend[{n}]")
        return reasons

    def _trend_tripped(self, b: _BucketRung) -> bool:
        """See :func:`trend_tripped` (shared with the single-bucket law)."""
        return trend_tripped(b.trend, self.trend_window, self.trend_rise,
                             self.ef_guard)

    def _guard_calm(self, b: _BucketRung) -> bool:
        # absence of a reading gates escalation, exactly like the single-
        # bucket law: no fresh evidence is not evidence of calm
        return (b.has_reading
                and b.ratio < self.escalate_margin * self.ef_guard)

    def _ladder_exhausted(self) -> bool:
        """True when no bucket can shed another byte: each is at its byte
        floor or measured guard-stressed.  A bucket with NO reading and
        cheaper rungs left keeps this False — ignorance opens neither the
        escalation path nor the staleness escape valve."""
        for b in self.buckets.values():
            cur = b.payload_mb(b.rung)
            has_cheaper = any(b.payload_mb(i) < cur
                              for i in range(b.rung + 1, len(b.ladder)))
            if not has_cheaper:
                continue
            if b.has_reading and not self._guard_calm(b):
                continue
            return False
        return True

    def update(self, step: int, stats: Mapping[str, BucketStats]
               ) -> Optional[BucketPlanUpdate]:
        """One control step with this round's per-bucket statistics
        (``bucket_stats_from_sync_state``).  Returns a plan update when any
        bucket's rung or the shared interval moved."""
        before = {n: b.rung for n, b in self.buckets.items()}
        reasons = self._bucket_guards(stats)
        if reasons:
            self._pressure_streak = self._calm_streak = 0
        else:
            fit = self._fit_interval(self._total_payload_mb())
            if fit > self.interval_budget:
                self._pressure_streak += 1
                self._calm_streak = 0
            elif fit <= max(self.min_interval, self.interval_budget // 2):
                self._calm_streak += 1
                self._pressure_streak = 0
            else:
                self._pressure_streak = self._calm_streak = 0
            if self._pressure_streak >= self.hysteresis:
                # greedy escalation: trade the cheapest fidelity first —
                # each candidate bucket jumps to its next *strictly
                # cheaper* rung (byte-equal rungs are no relief on a slow
                # link), largest wire-byte saving wins — until the fit
                # respects the budget.  Guard-stressed buckets never move.
                moved = False
                while (self._fit_interval(self._total_payload_mb())
                       > self.interval_budget):
                    candidates = []
                    for b in self.buckets.values():
                        if not self._guard_calm(b):
                            continue
                        cur = b.payload_mb(b.rung)
                        target = next(
                            (i for i in range(b.rung + 1, len(b.ladder))
                             if b.payload_mb(i) < cur), None)
                        if target is not None:
                            candidates.append(
                                (cur - b.payload_mb(target), b, target))
                    if not candidates:
                        break
                    _, best, target = max(candidates, key=lambda t: t[0])
                    best.rung = target
                    best.trend.clear()
                    reasons.append(f"wan-pressure[{best.name}]")
                    moved = True
                if moved:
                    self._pressure_streak = 0
            elif self._calm_streak >= 4 * self.hysteresis:
                # headroom: one rung of fidelity back, to the bucket the
                # compression is hurting most, if the budget still fits
                candidates = [b for b in self.buckets.values() if b.rung > 0]
                candidates = [
                    b for b in candidates
                    if self._fit_interval(
                        self._total_payload_mb(
                            {n: (bb.rung - 1 if bb is b else bb.rung)
                             for n, bb in self.buckets.items()}))
                    <= self.interval_budget]
                if candidates:
                    worst = max(candidates, key=lambda b: b.ratio)
                    worst.rung -= 1
                    worst.trend.clear()
                    reasons.append(f"wan-headroom[{worst.name}]")
                    self._calm_streak = 0

        # the staleness budget caps the interval while fidelity remains to
        # trade; the escape valve opens when the ladder is EXHAUSTED — every
        # bucket is at its floor *or guard-blocked from escalating* (a
        # stressed bucket cannot compress harder, so only staleness can
        # absorb the link; the single-bucket law's "last rung" generalized)
        # third actuator: consult the topology planner on fresh readings
        # only, and never while an EF guard is de-escalating — the exact
        # consultation rule of the single-bucket controller (a tripped
        # guard means fidelity is the problem; reshaping the network in
        # the same breath would blur which actuator fixed it)
        topo = None
        if (self.topology is not None and self._fresh_any
                and not any(r.startswith("ef-") for r in reasons)):
            topo = self.topology.decide(step, self._total_payload_mb())

        fit = self._fit_interval(self._total_payload_mb())
        exhausted = fit > self.interval_budget and self._ladder_exhausted()
        cap = self.max_interval if exhausted else self.interval_budget
        interval = min(fit, cap)
        rung_moved = any(b.rung != before[n]
                         for n, b in self.buckets.items())
        if not rung_moved:
            if interval == self.interval or (
                    not reasons
                    and abs(interval - self.interval)
                    < max(1.0, 0.25 * self.interval)):
                if topo is None:
                    return None
                # topology-only move: the codec knobs stand as they are
                interval = self.interval
        if not reasons:
            reasons.append(f"topo-{topo}" if topo is not None
                           else "interval-fit")
        self.interval = interval
        update = BucketPlanUpdate(
            sync=self.current, step=step,
            rungs=tuple((n, b.rung, b.cfg.tier)
                        for n, b in self.buckets.items()),
            reasons=tuple(reasons), probe=self.probe,
            topology=(self.topology.kind if self.topology is not None
                      else None))
        self.decisions.append(update)
        return update


# ---------------------------------------------------------------------------
# chunk-level control: mid-round retune on first-chunk feedback
# ---------------------------------------------------------------------------


class StreamingShipController:
    """Mid-round retune law: the chunk, not the round, as the unit of WAN
    feedback.

    The round-level controllers above decide at the TOP of a step from the
    *previous* round's measurements — so a bandwidth cliff that lands
    after that decision costs one full stale transfer at the old
    (topk × dtype) tier.  This controller closes that gap: as each shipped
    chunk's measured transfer lands (``MeasuredWanProbe.observe_chunk``),
    it compares achieved vs believed bandwidth, and on a cliff —
    ``achieved * cliff_ratio < believed`` for ``hysteresis`` consecutive
    chunks (default 1: first-chunk feedback) — it picks a cheaper ladder
    rung for the round's *unsent* segments.  The trainer re-encodes only
    those segments (``sync.reencode_unsent``); the EF residual absorbs the
    fidelity delta exactly, so the convergence guards' contract holds.

    Interaction contract with the round-level controllers (the
    consume-once law, property-tested):

    - **Belief is read-only and pre-round**: ``believed`` is the shared
      ``WanProbeEstimator`` belief as it stood when the round opened; the
      estimator folds only at the round barrier, so the decision stream
      replays exactly from the recorded signals.
    - **At most ONE retune per round**, and the retune is *transient* —
      it re-encodes this round's unsent segments only.  The persistent
      ``SyncConfig`` stays owned by the round-level controllers; the
      retuned round's aggregate (shipped MB, seconds) observation
      cliff-snaps the shared belief, so they see the cliff at the next
      barrier and make the durable move.
    - **Guard-block**: no retune while the last observed EF ratio is at
      or above ``escalate_margin * ef_guard`` — a stressed residual gets
      no additional mid-round fidelity drop (same escalation gate as the
      round-level law).
    """

    def __init__(self, base_sync: SyncConfig, model_mb: float, *,
                 cliff_ratio: float = 4.0, hysteresis: int = 1,
                 ef_guard: float = 0.9, escalate_margin: float = 0.95,
                 topk_ladder: Sequence[float] = (0.05, 0.02, 0.01),
                 dtype_ladder: Sequence[str] = ("int8", "fp8", "int4"),
                 probe_est: Optional[WanProbeEstimator] = None):
        if not base_sync.uses_codec:
            raise ValueError(
                "StreamingShipController re-encodes through the fused "
                "codec: base_sync must have strategy='asgd_ga', "
                "0 < compress_topk < 1 and quantize_int8=True")
        if not base_sync.error_feedback:
            raise ValueError(
                "the mid-round retune's convergence story IS the EF "
                "residual (it absorbs the fidelity delta): base_sync must "
                "set error_feedback=True")
        if cliff_ratio <= 1.0:
            raise ValueError(
                f"cliff_ratio must be > 1 (a chunk at believed speed must "
                f"not read as a cliff), got {cliff_ratio}")
        if hysteresis < 1:
            raise ValueError(f"hysteresis must be >= 1, got {hysteresis}")
        self.model_mb = model_mb
        self.cliff_ratio = cliff_ratio
        self.hysteresis = hysteresis
        self.ef_guard = ef_guard
        self.escalate_margin = escalate_margin
        self.ladder = build_ladder(base_sync, topk_ladder, dtype_ladder)
        self._probe_est = probe_est
        self._last_ratio: Optional[float] = None
        self._round: Optional[Dict] = None
        self.n_retunes = 0
        self.n_rounds = 0
        self.decisions: List[Dict] = []   # one dict per observed chunk —
        #   the replayable decision stream the bench commits and
        #   check_regression re-runs

    # ------------------------------------------------------------- signals
    def note_stats(self, stats: BucketStats) -> None:
        """Feed the latest round's EF telemetry (guard-block input)."""
        if stats.msg_norm > 0.0:
            self._last_ratio = stats.ef_ratio

    @property
    def believed_mbps(self) -> Optional[float]:
        return (self._probe_est.bandwidth_mbps
                if self._probe_est is not None else None)

    # -------------------------------------------------------------- rounds
    def begin_round(self, step: int, cfg: SyncConfig) -> None:
        """Open a streaming round under the live config ``cfg``: snapshot
        the pre-round belief and locate the rung the round ships at."""
        rung = min(range(len(self.ladder)),
                   key=lambda i: abs(self.ladder[i].payload_mb(1.0)
                                     - cfg.payload_mb(1.0)))
        self._round = {"step": step, "cfg": cfg, "rung": rung,
                       "believed": self.believed_mbps, "streak": 0,
                       "retuned": False, "chunk": 0}
        self.n_rounds += 1

    def observe_chunk(self, bucket: str, chunk_mb: float,
                      seconds: float) -> Optional[SyncConfig]:
        """One landed chunk.  Returns the transient retune config for the
        round's unsent segments when the cliff law fires, else None."""
        rd = self._round
        achieved = (chunk_mb * 8.0 / seconds
                    if chunk_mb > 0.0 and seconds > 0.0 else None)
        believed = rd["believed"]
        action, cfg_to, rung_to = "ship", None, rd["rung"]
        if (not rd["retuned"] and achieved is not None
                and believed is not None
                and achieved * self.cliff_ratio < believed):
            rd["streak"] += 1
            if rd["streak"] < self.hysteresis:
                action = "hold"
            elif (self._last_ratio is not None
                  and self._last_ratio
                  >= self.escalate_margin * self.ef_guard):
                # the residual is already near the guard: shipping the
                # planned fidelity is the cheaper risk
                action = "guard-block"
            else:
                rung_to = self._target_rung(rd["rung"],
                                            achieved / believed)
                if rung_to > rd["rung"]:
                    cfg = rd["cfg"]
                    cheap = self.ladder[rung_to]
                    # transplant only the ladder knobs: buckets overrides,
                    # codec_block (chunk alignment!) and interval stay the
                    # round-level controllers' property
                    cfg_to = replace(cfg,
                                     compress_topk=cheap.compress_topk,
                                     value_dtype=cheap.value_dtype)
                    rd["retuned"] = True
                    rd["rung"] = rung_to
                    self.n_retunes += 1
                    action = "retune"
                else:
                    action = "hold"   # already at/below the needed rung
        elif not rd["retuned"] and achieved is not None:
            rd["streak"] = 0
        self.decisions.append({
            "step": rd["step"], "chunk": rd["chunk"], "bucket": bucket,
            "mb": chunk_mb, "s": seconds, "achieved": achieved,
            "believed": believed, "action": action, "rung": rung_to,
        })
        rd["chunk"] += 1
        return cfg_to

    def _target_rung(self, rung: int, ratio: float) -> int:
        """Least-aggressive rung whose wire bytes shrink at least as much
        as the bandwidth did (``payload_j / payload_i <= achieved /
        believed``), else the cheapest rung — mirrors the round-level
        law's jump-straight-to-the-fitting-rung escalation."""
        cur = self.ladder[rung].payload_mb(self.model_mb)
        for j in range(rung + 1, len(self.ladder)):
            if self.ladder[j].payload_mb(self.model_mb) <= cur * ratio:
                return j
        return len(self.ladder) - 1

    def end_round(self) -> bool:
        """Close the round; returns True when it retuned mid-round."""
        rd, self._round = self._round, None
        return bool(rd and rd["retuned"])
