"""Event-driven WAN simulator for geo-distributed training timelines.

SPMD on TPU is bulk-synchronous, so the paper's *asynchronous* wall-clock
behaviour (per-cloud timelines, WAN fluctuation, barrier-vs-no-barrier) is
reproduced here as a discrete-event simulation.  It consumes the same
``SyncConfig`` as the SPMD implementation and the same load model as the
elastic scheduler, and it reproduces the paper's headline measurements
(Fig 3 comm fraction, Fig 8 waiting/cost reduction, Fig 10/11 speedups) from
the paper's own measured inputs (Table I iteration times, Table III gradient
sizes, 100 Mbps WAN).

Per-cloud timeline events per iteration:
  compute(iter) -> [local PS update] -> if sync point: pack + WAN transfer
Synchronous strategies barrier before the transfer; asynchronous strategies
overlap a configurable fraction of the transfer with subsequent compute
(``overlap``): the paper observes roughly half of the ideal reduction is
realized at frequency 4 due to fluctuations, which calibrates the default.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.sync import SyncConfig, traffic_per_step_mb


@dataclass(frozen=True)
class SimCloud:
    """One training partition (cloud region / pod)."""

    region: str
    iter_time_s: float            # local compute time per training iteration
    units: int = 12               # allocated resource units (cores / chips)
    cost_per_unit_hour: float = 1.0
    load_time_s: float = 0.0      # T_load component of T_process


@dataclass(frozen=True)
class WANConfig:
    bandwidth_mbps: float = 100.0     # paper: Tencent Cloud max inter-region
    latency_s: float = 0.05
    fluctuation: float = 0.25         # lognormal sigma on transfer time
    overlap: float = 0.55             # async strategies: fraction overlapped
    baseline_roundtrip: float = 2.0   # PS push+pull per baseline sync round
    traffic_cost_per_gb: float = 0.0  # optional WAN egress pricing
    seed: int = 0


@dataclass(frozen=True)
class BandwidthTrace:
    """Piecewise-constant WAN bandwidth over time — the fluctuating link the
    adaptive sync controller reacts to (paper: "low bandwidth and high
    fluctuations").

    ``times_s`` must be ascending and start at 0; ``mbps[i]`` holds on
    ``[times_s[i], times_s[i+1])``.  Usable three ways: direct lookup
    (:meth:`at`), injection into the discrete-event simulator
    (:meth:`to_events`), and step-indexed lookup for emulated training
    loops (:meth:`at_step`)."""

    times_s: Tuple[float, ...]
    mbps: Tuple[float, ...]

    def __post_init__(self):
        if len(self.times_s) != len(self.mbps) or not self.times_s:
            raise ValueError("times_s and mbps must be equal-length, non-empty")
        if self.times_s[0] != 0.0:
            raise ValueError("trace must start at t=0")
        if any(b <= 0 for b in self.mbps):
            raise ValueError("bandwidth must be positive")
        if any(a >= b for a, b in zip(self.times_s, self.times_s[1:])):
            raise ValueError("times_s must be strictly ascending")

    def at(self, t_s: float) -> float:
        """Bandwidth in effect at absolute time ``t_s``."""
        i = int(np.searchsorted(np.asarray(self.times_s), t_s, side="right"))
        return self.mbps[max(0, i - 1)]

    def at_step(self, step: int, step_time_s: float) -> float:
        """Bandwidth at the wall-clock of training step ``step``."""
        return self.at(step * step_time_s)

    def to_events(self) -> List[SimEvent]:
        """One ``bandwidth_changed`` SimEvent per segment after the first
        (the first segment is the simulator's starting bandwidth)."""
        return [SimEvent(time_s=t, kind="bandwidth_changed",
                         bandwidth_mbps=b)
                for t, b in zip(self.times_s[1:], self.mbps[1:])]

    @classmethod
    def fluctuating(cls, *, base_mbps: float = 100.0, duration_s: float = 600.0,
                    period_s: float = 30.0, sigma: float = 0.6,
                    floor_mbps: float = 2.0, seed: int = 0
                    ) -> "BandwidthTrace":
        """Lognormal random-walk trace: every ``period_s`` the bandwidth is
        re-drawn as ``base * lognormal(0, sigma)`` mean-reverted halfway to
        the base — fluctuation statistics matching the simulator's per-
        transfer lognormal model, but persistent enough (30 s segments)
        that a controller can react."""
        rng = np.random.default_rng(seed)
        times, vals = [0.0], [base_mbps]
        t = period_s
        while t < duration_s:
            drawn = base_mbps * float(rng.lognormal(0.0, sigma))
            # mean-revert halfway: geometric midpoint of last and drawn
            level = max(floor_mbps, float(np.sqrt(vals[-1] * drawn)))
            times.append(t)
            vals.append(round(level, 2))
            t += period_s
        return cls(times_s=tuple(times), mbps=tuple(vals))


@dataclass(frozen=True)
class SimEvent:
    """External event injected into the discrete-event timeline.

    Kinds: ``bandwidth_changed`` (new WAN bandwidth), ``cloud_left`` (region
    departs, resources released), ``cloud_joined`` (``cloud`` payload comes
    online), ``slowdown`` (region's iter time scaled by ``factor``),
    ``reconfig`` (elasticity engine output: swap in a new cloud set /
    ``SyncConfig`` after a ``pause_s`` reconfiguration stall — checkpoint
    re-stack + re-plan cost — charged to every active region; with
    ``migration=True`` the re-stack is a *live migration* staged from the
    async snapshot engine, so active regions pay only the barrier-aligned
    ``barrier_s`` reconcile and the staged ``migrate_mb`` snapshot bytes
    bill as overlapped background traffic, never as stall),
    ``link_failed`` (the WAN link drops transfers for ``duration_s``: each
    sync round inside the window pays ``n_failures`` failed attempts of
    retry/backoff wall-clock per :func:`retry_schedule`, and the retried
    bytes bill at full cost), and ``pod_crashed`` (region dies mid-run:
    departs like ``cloud_left``, and every survivor stalls ``pause_s`` for
    the barrier rollback + re-stack — billed as reconfig time)."""

    time_s: float
    kind: str                               # see docstring
    region: str = ""
    bandwidth_mbps: Optional[float] = None
    factor: float = 1.0
    cloud: Optional[SimCloud] = None
    clouds: Optional[Sequence[SimCloud]] = None   # reconfig payload
    sync: Optional[SyncConfig] = None             # reconfig payload
    pause_s: float = 0.0
    duration_s: float = 0.0                 # link_failed: outage window
    n_failures: int = 1                     # link_failed: attempts per round
    migration: bool = False                 # reconfig: live-migrated re-stack
    barrier_s: float = 0.0                  # migration: reconcile stall
    migrate_mb: float = 0.0                 # migration: staged snapshot bytes

    _KINDS = ("bandwidth_changed", "cloud_left", "cloud_joined",
              "slowdown", "reconfig", "link_failed", "pod_crashed")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown sim event kind {self.kind!r}")


@dataclass
class CloudTimeline:
    region: str
    compute_s: float = 0.0
    wait_s: float = 0.0               # barrier waiting (sync strategies / BSP)
    comm_s: float = 0.0               # WAN transfer time attributable to training
    comm_blocking_s: float = 0.0      # portion that blocked the critical path
    traffic_mb: float = 0.0
    reconfig_s: float = 0.0           # stall paying for re-plan + re-stacking
    total_s: float = 0.0
    cost: float = 0.0

    @property
    def comm_fraction(self) -> float:
        return self.comm_s / self.total_s if self.total_s else 0.0

    @property
    def wait_fraction(self) -> float:
        return self.wait_s / self.total_s if self.total_s else 0.0


@dataclass
class SimResult:
    clouds: List[CloudTimeline]
    sync_cfg: SyncConfig
    n_reconfigs: int = 0

    @property
    def makespan_s(self) -> float:
        return max(c.total_s for c in self.clouds)

    @property
    def total_cost(self) -> float:
        return sum(c.cost for c in self.clouds)

    @property
    def total_traffic_mb(self) -> float:
        return sum(c.traffic_mb for c in self.clouds)

    def speedup_over(self, other: "SimResult") -> float:
        return other.makespan_s / self.makespan_s


def _transfer_time(size_mb: float, bandwidth_mbps: float, wan: WANConfig,
                   rng: np.random.Generator) -> float:
    """One WAN transfer's wall-clock: bytes/bandwidth + latency, inflated
    by a lognormal fluctuation draw.  This is the simulator's *only*
    notion of transfer physics, shared verbatim by
    ``repro.core.transport.SimTransport`` (the simulator rehosted behind
    the transport seam) so sim-billed and DES-billed times agree."""
    base = size_mb * 8.0 / bandwidth_mbps + wan.latency_s
    if wan.fluctuation > 0:
        base *= float(rng.lognormal(mean=0.0, sigma=wan.fluctuation))
    return base


#: public alias — the transport layer bills with the simulator's law
transfer_time = _transfer_time


def stream_chunk_time(t_total: float, chunk_mb: float,
                      total_mb: float) -> float:
    """One chunk's share of a round transfer: the pro-rata slice of the
    round's single ``transfer_time`` draw.

    This is the streaming seam's *only* chunk-billing law, shared by
    ``transport.SimTransport`` (per-chunk streaming bills), the
    mid-round-cliff benchmark and the regression replay gate — so a
    recorded chunk-observation stream re-bills exactly from the recorded
    round draw.  Slicing the one draw (instead of drawing per chunk)
    keeps a zero-retune streaming round's wall-clock bit-identical to the
    classic once-per-round bill, and makes the first chunk's achieved
    bandwidth equal the round's achieved bandwidth — the signal the
    streaming controller compares against the belief."""
    if total_mb <= 0.0:
        return 0.0
    return t_total * chunk_mb / total_mb


def stream_chunk_plan(payload_mb: float, n_chunks: int) -> List[float]:
    """Equal-split chunk schedule for billing-only streaming (the DES /
    bench driver, which moves no real payloads): ``n_chunks`` chunks of
    ``payload_mb / n_chunks`` MB each.  The real trainer path derives its
    schedule from the codec's block-aligned ``_chunk_widths`` instead."""
    n = max(1, int(n_chunks))
    return [payload_mb / n] * n


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff for one WAN transfer.

    The shared law between the fault-tolerant transports
    (``repro.core.faults.ChaosTransport``), the host-seam ship loop
    (``sync.ship_sync_payloads``) and the DES failure events, so every
    layer bills a failed attempt identically.  A transfer running
    ``timeout_factor``× slower than the current bandwidth belief is
    declared failed and retried after an exponentially growing backoff;
    after ``max_retries`` failed retries the peer is declared
    unreachable and the round degrades to the surviving membership."""

    max_retries: int = 3
    timeout_factor: float = 4.0       # belief-relative per-link timeout
    backoff_s: float = 0.5            # first backoff pause
    backoff_base: float = 2.0         # growth per failed attempt
    assume_mbps: float = 100.0        # belief fallback before any sample

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.timeout_factor <= 1.0:
            raise ValueError(
                f"timeout_factor must be > 1 (a transfer at belief speed must "
                f"not time out), got {self.timeout_factor}")
        if self.backoff_s < 0 or self.backoff_base < 1.0:
            raise ValueError(
                f"backoff_s must be >= 0 and backoff_base >= 1, got "
                f"backoff_s={self.backoff_s}, backoff_base={self.backoff_base}")
        if self.assume_mbps <= 0:
            raise ValueError(f"assume_mbps must be > 0, got {self.assume_mbps}")

    def timeout_s(self, expected_s: float) -> float:
        """Per-link timeout budget for a transfer expected to take
        ``expected_s`` at the current belief."""
        return expected_s * self.timeout_factor


def retry_schedule(expected_s: float, policy: RetryPolicy,
                   n_failures: int) -> float:
    """Wall-clock burned by ``n_failures`` failed attempts of one transfer:
    each attempt hangs to its timeout budget
    (``expected_s * timeout_factor``) and then backs off exponentially
    before the next try.  Pure math over its inputs — the DES, the chaos
    transport and the regression replay all call this one function, so a
    recorded retry bill replays exactly after a JSON round-trip."""
    total = 0.0
    for attempt in range(max(0, int(n_failures))):
        total += policy.timeout_s(expected_s)
        total += policy.backoff_s * policy.backoff_base ** attempt
    return total


def _schedule(sync: SyncConfig, model_mb: float, wan: WANConfig):
    payload = sync.payload_mb(model_mb)
    if sync.strategy == "asgd":
        payload *= wan.baseline_roundtrip   # PS push + pull every iteration
    sync_every = 1 if sync.strategy == "asgd" else sync.interval
    # codec chunk-pipelining factor, capped at the number of codec blocks
    # exactly like the real path (sync._chunk_widths): a model smaller
    # than overlap_chunks blocks cannot pipeline more than nb ways
    chunks = 1
    if sync.uses_codec:
        nb = max(1, -(-int(model_mb * 1e6 / 4) // sync.codec_block))
        chunks = max(1, min(sync.overlap_chunks, nb))
    return payload, sync_every, sync.strategy == "sma", chunks


def simulate(
    clouds: Sequence[SimCloud],
    sync: SyncConfig,
    *,
    n_iters: int,
    model_mb: float,
    wan: WANConfig = WANConfig(),
    events: Sequence[SimEvent] = (),
    trace: Optional[BandwidthTrace] = None,
    topology=None,
    topology_links: Optional[Mapping[Tuple[str, str], float]] = None,
    retry: RetryPolicy = RetryPolicy(),
) -> SimResult:
    """Run the discrete-event timeline and return per-cloud accounting.

    ``events`` are external :class:`SimEvent`s (sorted internally) applied at
    iteration boundaries once the lagging active cloud's clock passes their
    ``time_s`` — this is how the elasticity engine's reconfigurations get a
    simulated wall-clock and cost.  With no events the timeline is identical
    to the static simulator.  ``trace`` is sugar for a fluctuating link: its
    segments merge into ``events`` as ``bandwidth_changed`` (its t=0 segment
    overrides ``wan.bandwidth_mbps`` as the starting bandwidth).

    ``topology`` (a ``repro.core.topology.TopologySpec``, duck-typed to
    avoid an import cycle) replaces the flat per-cloud billing with the
    compiled hierarchical schedule: each sync round costs the schedule's
    phases — intra legs at ``topology.intra_mbps`` fabric speed, every WAN
    hop one :func:`transfer_time` draw at that link's bandwidth (the global
    ``bandwidth``, scaled per link by ``topology_links`` — a mapping from
    sorted ``(region_a, region_b)`` pairs to multipliers, links absent
    defaulting to 1.0; asymmetric inter-region networks in one dict).
    Traffic bills ``payload`` per WAN hop to the originating region — the
    exact accounting ``cost.adaptive_traffic_mb(wan_legs=...)`` mirrors.

    Failure events bill through ``retry`` (the same :class:`RetryPolicy`
    law the real fault-tolerant transports use): during a ``link_failed``
    window every flat sync round pays :func:`retry_schedule` of extra
    wall-clock per cloud and bills the retried bytes at full cost; a
    ``pod_crashed`` region departs like ``cloud_left`` and every survivor
    stalls ``pause_s`` (barrier rollback + re-stack), billed as reconfig
    time.  Failure billing models the flat ring only — hierarchical
    rounds reroute around dead links via the topology planner instead.
    """
    rng = np.random.default_rng(wan.seed)
    if trace is not None:
        events = list(events) + trace.to_events()
        wan = replace(wan, bandwidth_mbps=trace.mbps[0])
    active = list(clouds)
    iter_time = {c.region: c.iter_time_s for c in active}
    units = {c.region: c.units for c in active}
    rate = {c.region: c.cost_per_unit_hour for c in active}
    tl = {c.region: CloudTimeline(region=c.region) for c in active}
    clock = {c.region: c.load_time_s for c in active}   # absolute time per cloud
    born = {c.region: 0.0 for c in active}              # start of current life
    ended: Dict[str, float] = {}                        # region -> departure time
    life_s = {c.region: 0.0 for c in active}            # summed lifetimes
    cost_acc = {c.region: 0.0 for c in active}          # summed per-life cost
    for c in active:
        tl[c.region].compute_s += c.load_time_s  # model load counts as local work

    bandwidth = wan.bandwidth_mbps
    payload, sync_every, barrier, chunks = _schedule(sync, model_mb, wan)

    topo_links = {tuple(sorted(k)): float(v)
                  for k, v in (topology_links or {}).items()}

    def _link_bw(a: str, b: str) -> float:
        key = (a, b) if a < b else (b, a)
        return bandwidth * topo_links.get(key, 1.0)

    class _LinkView:
        """Duck-typed LinkBeliefs over the DES link state, so
        ``topology.compile`` sees the simulated network (recompiled each
        sync round — bandwidth events reroute the schedule here exactly
        like measured beliefs do in HierarchicalTransport)."""
        mbps = staticmethod(_link_bw)

    link_view = _LinkView()
    pending = sorted(events, key=lambda e: e.time_s)
    ev_i = 0
    n_reconfigs = 0
    fail_until = 0.0          # link_failed outage window end (absolute time)
    fail_n = 0                # failed attempts each round inside the window

    def _register(c: SimCloud) -> None:
        iter_time[c.region] = c.iter_time_s
        units[c.region] = c.units
        rate[c.region] = c.cost_per_unit_hour
        life_s.setdefault(c.region, 0.0)
        cost_acc.setdefault(c.region, 0.0)

    def _close_life(region: str, end: float) -> None:
        """A region departs (or the job ends): bill its current life."""
        life_s[region] += end - born[region]
        cost_acc[region] += units[region] * rate[region] \
            * (end - born[region]) / 3600.0

    for it in range(n_iters):
        # ---- external events due at this iteration boundary
        while (ev_i < len(pending) and active
               and pending[ev_i].time_s
               <= min(clock[c.region] for c in active)):
            e = pending[ev_i]
            ev_i += 1
            if e.kind == "bandwidth_changed":
                bandwidth = float(e.bandwidth_mbps)
            elif e.kind == "slowdown":
                if e.region in iter_time:
                    iter_time[e.region] *= e.factor
            elif e.kind == "cloud_left":
                for i, c in enumerate(active):
                    if c.region == e.region:
                        _close_life(c.region, clock[c.region])
                        ended[c.region] = clock[c.region]
                        del active[i]
                        break
            elif e.kind == "link_failed":
                fail_until = e.time_s + e.duration_s
                fail_n = max(1, int(e.n_failures))
            elif e.kind == "pod_crashed":
                for i, c in enumerate(active):
                    if c.region == e.region:
                        _close_life(c.region, clock[c.region])
                        ended[c.region] = clock[c.region]
                        del active[i]
                        break
                # survivors stall for the barrier rollback + re-stack
                for c in active:
                    tl[c.region].reconfig_s += e.pause_s
                    clock[c.region] += e.pause_s
            elif e.kind == "cloud_joined":
                c = e.cloud
                if any(x.region == c.region for x in active):
                    continue   # already running
                t_now = min(clock[x.region] for x in active)
                _register(c)
                ended.pop(c.region, None)
                if c.region not in tl:
                    tl[c.region] = CloudTimeline(region=c.region,
                                                 compute_s=c.load_time_s)
                else:   # rejoin: keep the earlier life's accounting
                    tl[c.region].compute_s += c.load_time_s
                born[c.region] = t_now
                clock[c.region] = t_now + c.load_time_s
                active.append(c)
            elif e.kind == "reconfig":
                n_reconfigs += 1
                # barrier to the slowest, then everyone stalls for the
                # re-stack: the full checkpointed pause (legacy), or — for
                # a live migration — only the barrier-aligned reconcile
                # (the snapshot staging and the re-plan overlapped with
                # compute, so their bytes bill as background traffic and
                # their time never reaches the clock)
                stall = e.barrier_s if e.migration else e.pause_s
                t_bar = max(clock[c.region] for c in active)
                for c in active:
                    tl[c.region].wait_s += t_bar - clock[c.region]
                    tl[c.region].reconfig_s += stall
                    clock[c.region] = t_bar + stall
                t_bar += stall
                if e.migration and e.migrate_mb > 0.0 and active:
                    # staged snapshot shipment: billed once, to the
                    # coordinating (first active) region's meter
                    tl[active[0].region].traffic_mb += e.migrate_mb
                if e.sync is not None:
                    sync = e.sync
                    payload, sync_every, barrier, chunks = \
                        _schedule(sync, model_mb, wan)
                if e.clouds is not None:
                    new = list(e.clouds)
                    keep = {c.region for c in new}
                    for c in active:
                        if c.region not in keep:
                            _close_life(c.region, t_bar)
                            ended[c.region] = t_bar
                    for c in new:
                        _register(c)
                        if c.region in ended:   # rejoin: a new billed life
                            ended.pop(c.region)
                            born[c.region] = t_bar
                            clock[c.region] = t_bar + c.load_time_s
                            tl[c.region].compute_s += c.load_time_s
                        elif c.region not in tl:
                            tl[c.region] = CloudTimeline(
                                region=c.region, compute_s=c.load_time_s)
                            born[c.region] = t_bar
                            clock[c.region] = t_bar + c.load_time_s
                        else:   # continuing region, life uninterrupted
                            clock[c.region] = t_bar
                    active = new
        if not active:
            break

        # local compute
        for c in active:
            clock[c.region] += iter_time[c.region]
            tl[c.region].compute_s += iter_time[c.region]

        if (it + 1) % sync_every:
            continue

        # ---- synchronization point
        if barrier:
            # all partitions align to the slowest before exchanging
            t_bar = max(clock[c.region] for c in active)
            for c in active:
                tl[c.region].wait_s += t_bar - clock[c.region]
                clock[c.region] = t_bar

        if topology is not None:
            # hierarchical round: the compiled schedule is the billing —
            # phases in sequence, legs within a phase in parallel (the
            # phase costs its slowest leg), every WAN hop one transfer
            # draw at its own link's bandwidth
            sched = topology.compile(link_view)
            t_round = 0.0
            for phase in sched.phases:
                if not phase.legs:
                    continue
                if not phase.wan:
                    t_round += payload * 8.0 / topology.intra_mbps
                    continue
                t_round += max(
                    sum(_transfer_time(payload, _link_bw(a, b), wan, rng)
                        for a, b in leg.hops)
                    for leg in phase.legs)
            # traffic: one payload per WAN hop, billed to the leg's
            # originating region (aux routes pay both hops); legs from
            # topology regions with no simulated cloud spread evenly
            share = {c.region: 0.0 for c in active}
            for ph in sched.phases:
                if not ph.wan:
                    continue
                for leg in ph.legs:
                    mb = payload * len(leg.hops)
                    if leg.src in share:
                        share[leg.src] += mb
                    else:
                        for c in active:
                            share[c.region] += mb / len(active)
            for c in active:
                tl[c.region].comm_s += t_round
                tl[c.region].traffic_mb += share[c.region]
                blocking = t_round if (barrier or sync.strategy == "asgd") \
                    else t_round * max(0.0, 1.0 - wan.overlap) / chunks
                tl[c.region].comm_blocking_s += blocking
                clock[c.region] += blocking
            continue

        for c in active:
            t = _transfer_time(payload, bandwidth, wan, rng)
            if clock[c.region] < fail_until and fail_n > 0:
                # failed attempts hang to the timeout budget and back off;
                # every retried transfer bills its bytes at full cost
                expected = payload * 8.0 / bandwidth + wan.latency_s
                t += retry_schedule(expected, retry, fail_n)
                tl[c.region].traffic_mb += payload * fail_n
            tl[c.region].comm_s += t
            tl[c.region].traffic_mb += payload
            # asynchronous strategies hide ``overlap`` of the transfer
            # behind subsequent compute; chunk-pipelining the codec
            # (SyncConfig.overlap_chunks, active only on the codec path,
            # capped at the block count in _schedule) additionally hides
            # the *unhidden* tail behind the next chunk's encode — only
            # ~1/C of it stays on the critical path (TAAR-style
            # transfer/compute overlap)
            blocking = t if (barrier or sync.strategy == "asgd") else \
                t * max(0.0, 1.0 - wan.overlap) / chunks
            tl[c.region].comm_blocking_s += blocking
            clock[c.region] += blocking

    # straggler wait at job end: resources stay allocated until every
    # partition finishes (the paper's waiting-time / cost-waste term);
    # departed clouds released their resources at their departure time
    t_end = max([*(clock[c.region] for c in active), *ended.values()]) \
        if (active or ended) else 0.0
    for region, timeline in tl.items():
        if region not in ended:
            if not barrier:
                timeline.wait_s += t_end - clock[region]
            _close_life(region, t_end)
        timeline.total_s = life_s[region]
        timeline.cost = (cost_acc[region]
                         + timeline.traffic_mb / 1024.0
                         * wan.traffic_cost_per_gb)
    return SimResult(clouds=list(tl.values()), sync_cfg=sync,
                     n_reconfigs=n_reconfigs)


# ---------------------------------------------------------------------------
# composed experiments (used by benchmarks)
# ---------------------------------------------------------------------------


def compare_strategies(
    clouds: Sequence[SimCloud],
    *,
    n_iters: int,
    model_mb: float,
    intervals: Sequence[int] = (4, 8),
    wan: WANConfig = WANConfig(),
) -> Dict[str, SimResult]:
    """Reproduce the Fig 10/11 grid: baseline ASGD vs ASGD-GA / AMA / SMA."""
    out: Dict[str, SimResult] = {
        "asgd": simulate(clouds, SyncConfig("asgd", 1), n_iters=n_iters,
                         model_mb=model_mb, wan=wan)}
    for k in intervals:
        for strat in ("asgd_ga", "ama", "sma"):
            cfgk = SyncConfig(strat, k)
            out[f"{strat}@{k}"] = simulate(
                clouds, cfgk, n_iters=n_iters, model_mb=model_mb, wan=wan)
    # Gaia-style ASP comparator (per-iteration sync of the significant ~30%)
    out["asp"] = simulate(clouds, SyncConfig("asp", 1), n_iters=n_iters,
                          model_mb=model_mb, wan=wan)
    return out
