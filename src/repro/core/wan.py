"""Event-driven WAN simulator for geo-distributed training timelines.

SPMD on TPU is bulk-synchronous, so the paper's *asynchronous* wall-clock
behaviour (per-cloud timelines, WAN fluctuation, barrier-vs-no-barrier) is
reproduced here as a discrete-event simulation.  It consumes the same
``SyncConfig`` as the SPMD implementation and the same load model as the
elastic scheduler, and it reproduces the paper's headline measurements
(Fig 3 comm fraction, Fig 8 waiting/cost reduction, Fig 10/11 speedups) from
the paper's own measured inputs (Table I iteration times, Table III gradient
sizes, 100 Mbps WAN).

Per-cloud timeline events per iteration:
  compute(iter) -> [local PS update] -> if sync point: pack + WAN transfer
Synchronous strategies barrier before the transfer; asynchronous strategies
overlap a configurable fraction of the transfer with subsequent compute
(``overlap``): the paper observes roughly half of the ideal reduction is
realized at frequency 4 due to fluctuations, which calibrates the default.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.sync import SyncConfig, traffic_per_step_mb


@dataclass(frozen=True)
class SimCloud:
    """One training partition (cloud region / pod)."""

    region: str
    iter_time_s: float            # local compute time per training iteration
    units: int = 12               # allocated resource units (cores / chips)
    cost_per_unit_hour: float = 1.0
    load_time_s: float = 0.0      # T_load component of T_process


@dataclass(frozen=True)
class WANConfig:
    bandwidth_mbps: float = 100.0     # paper: Tencent Cloud max inter-region
    latency_s: float = 0.05
    fluctuation: float = 0.25         # lognormal sigma on transfer time
    overlap: float = 0.55             # async strategies: fraction overlapped
    baseline_roundtrip: float = 2.0   # PS push+pull per baseline sync round
    traffic_cost_per_gb: float = 0.0  # optional WAN egress pricing
    seed: int = 0


@dataclass
class CloudTimeline:
    region: str
    compute_s: float = 0.0
    wait_s: float = 0.0               # barrier waiting (sync strategies / BSP)
    comm_s: float = 0.0               # WAN transfer time attributable to training
    comm_blocking_s: float = 0.0      # portion that blocked the critical path
    traffic_mb: float = 0.0
    total_s: float = 0.0
    cost: float = 0.0

    @property
    def comm_fraction(self) -> float:
        return self.comm_s / self.total_s if self.total_s else 0.0

    @property
    def wait_fraction(self) -> float:
        return self.wait_s / self.total_s if self.total_s else 0.0


@dataclass
class SimResult:
    clouds: List[CloudTimeline]
    sync_cfg: SyncConfig

    @property
    def makespan_s(self) -> float:
        return max(c.total_s for c in self.clouds)

    @property
    def total_cost(self) -> float:
        return sum(c.cost for c in self.clouds)

    @property
    def total_traffic_mb(self) -> float:
        return sum(c.traffic_mb for c in self.clouds)

    def speedup_over(self, other: "SimResult") -> float:
        return other.makespan_s / self.makespan_s


def _transfer_time(size_mb: float, wan: WANConfig, rng: np.random.Generator) -> float:
    base = size_mb * 8.0 / wan.bandwidth_mbps + wan.latency_s
    if wan.fluctuation > 0:
        base *= float(rng.lognormal(mean=0.0, sigma=wan.fluctuation))
    return base


def simulate(
    clouds: Sequence[SimCloud],
    sync: SyncConfig,
    *,
    n_iters: int,
    model_mb: float,
    wan: WANConfig = WANConfig(),
) -> SimResult:
    """Run the discrete-event timeline and return per-cloud accounting."""
    rng = np.random.default_rng(wan.seed)
    tl = {c.region: CloudTimeline(region=c.region) for c in clouds}
    clock = {c.region: c.load_time_s for c in clouds}   # absolute time per cloud
    for c in clouds:
        tl[c.region].compute_s += c.load_time_s  # model load counts as local work

    payload = sync.payload_mb(model_mb)
    if sync.strategy == "asgd":
        payload *= wan.baseline_roundtrip   # PS push + pull every iteration
    sync_every = 1 if sync.strategy == "asgd" else sync.interval
    barrier = sync.strategy == "sma"

    for it in range(n_iters):
        # local compute
        for c in clouds:
            clock[c.region] += c.iter_time_s
            tl[c.region].compute_s += c.iter_time_s

        if (it + 1) % sync_every:
            continue

        # ---- synchronization point
        if barrier:
            # all partitions align to the slowest before exchanging
            t_bar = max(clock.values())
            for c in clouds:
                tl[c.region].wait_s += t_bar - clock[c.region]
                clock[c.region] = t_bar

        for c in clouds:
            t = _transfer_time(payload, wan, rng)
            tl[c.region].comm_s += t
            tl[c.region].traffic_mb += payload
            blocking = t if (barrier or sync.strategy == "asgd") else \
                t * max(0.0, 1.0 - wan.overlap)
            tl[c.region].comm_blocking_s += blocking
            clock[c.region] += blocking

    # straggler wait at job end: resources stay allocated until every
    # partition finishes (the paper's waiting-time / cost-waste term)
    t_end = max(clock.values())
    for c in clouds:
        if not barrier:
            tl[c.region].wait_s += t_end - clock[c.region]
        tl[c.region].total_s = t_end
        tl[c.region].cost = (
            c.units * c.cost_per_unit_hour * t_end / 3600.0
            + tl[c.region].traffic_mb / 1024.0 * wan.traffic_cost_per_gb)
    return SimResult(clouds=list(tl.values()), sync_cfg=sync)


# ---------------------------------------------------------------------------
# composed experiments (used by benchmarks)
# ---------------------------------------------------------------------------


def compare_strategies(
    clouds: Sequence[SimCloud],
    *,
    n_iters: int,
    model_mb: float,
    intervals: Sequence[int] = (4, 8),
    wan: WANConfig = WANConfig(),
) -> Dict[str, SimResult]:
    """Reproduce the Fig 10/11 grid: baseline ASGD vs ASGD-GA / AMA / SMA."""
    out: Dict[str, SimResult] = {
        "asgd": simulate(clouds, SyncConfig("asgd", 1), n_iters=n_iters,
                         model_mb=model_mb, wan=wan)}
    for k in intervals:
        for strat in ("asgd_ga", "ama", "sma"):
            cfgk = SyncConfig(strat, k)
            out[f"{strat}@{k}"] = simulate(
                clouds, cfgk, n_iters=n_iters, model_mb=model_mb, wan=wan)
    # Gaia-style ASP comparator (per-iteration sync of the significant ~30%)
    out["asp"] = simulate(clouds, SyncConfig("asp", 1), n_iters=n_iters,
                          model_mb=model_mb, wan=wan)
    return out
