"""Elastic scheduling strategy (paper §III.B).

Implements the load-power model (formula 1)

    LP_i = (Σ_m N_cpu,m · P_m + Σ_n N_gpu,n · P_n) / S_data,i

and the Optimal Matching Algorithm (Table II / Algorithm 1): find the cloud
with the smallest load power (the worst straggler), then trim every other
cloud's resource allocation by brute force so all LPs match the straggler's
as closely as possible — eliminating wait-time over-provisioning.

The device catalog reproduces paper Table I (TFLOPS, measured ResNet18
iteration time, TN/IN normalizations) and is extended with TPU v5e for the
TPU-cluster planning path used by the launcher.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Device catalog — paper Table I (+ TPU extension)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DeviceType:
    name: str
    kind: str                 # "cpu" | "gpu" | "tpu"
    cores: int                # cores used in the Table I measurement
    tflops: float             # peak TFLOPS at that allocation
    iter_time_s: Optional[float] = None   # measured ResNet18 iter time (Table I)

    @property
    def tn(self) -> float:
        """TFLOPS normalization vs the Intel IceLake baseline (Table I)."""
        return self.tflops / CATALOG["icelake"].tflops

    @property
    def in_(self) -> Optional[float]:
        """Iteration-time normalization (baseline_time / time)."""
        if self.iter_time_s is None:
            return None
        return CATALOG["icelake"].iter_time_s / self.iter_time_s

    @property
    def in_tn_ratio(self) -> Optional[float]:
        return None if self.in_ is None else self.in_ / self.tn

    def power(self, prefer_measured: bool = True) -> float:
        """Per-allocation computing power P (paper: TN, or IN when measured)."""
        if prefer_measured and self.in_ is not None:
            return self.in_
        return self.tn


CATALOG: Dict[str, DeviceType] = {}
for _d in [
    DeviceType("icelake", "cpu", 2, 0.096, 3.697),      # baseline (Table I)
    DeviceType("cascade", "cpu", 2, 0.090, 5.549),      # TN .938, IN .666
    DeviceType("skylake", "cpu", 2, 0.112, 3.800),      # TN 1.167, IN .973
    DeviceType("t4", "gpu", 2560, 5.554, 0.062),
    DeviceType("v100", "gpu", 5120, 13.345, 0.024),
    DeviceType("v5e", "tpu", 1, 197.0, None),           # bf16 peak, per chip
]:
    CATALOG[_d.name] = _d
CATALOG["sky"] = CATALOG["skylake"]


# ---------------------------------------------------------------------------
# cloud resource description
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CloudResources:
    """Resources available (reserved or real-time) in one cloud region."""

    region: str
    devices: Tuple[Tuple[str, int], ...]   # ((device_type, max_units), ...)
    data_size: float                        # S_data,i — local dataset size
    cost_per_unit_hour: float = 1.0         # monetary cost per device-unit-hour

    def max_allocation(self) -> Tuple[int, ...]:
        return tuple(n for _, n in self.devices)


@dataclass(frozen=True)
class ResourcePlan:
    region: str
    allocation: Tuple[Tuple[str, int], ...]  # ((device_type, units), ...)
    load_power: float

    @property
    def units(self) -> int:
        return sum(n for _, n in self.allocation)


def load_power(devices: Sequence[Tuple[str, int]], data_size: float,
               prefer_measured: bool = True) -> float:
    """Formula (1): LP = Σ N_d · P_d / S_data."""
    if data_size <= 0:
        return math.inf
    total = sum(n * CATALOG[d].power(prefer_measured) for d, n in devices)
    return total / data_size


# ---------------------------------------------------------------------------
# Algorithm 1 — Optimal Matching
# ---------------------------------------------------------------------------


def _allocations(res: CloudResources) -> List[Tuple[Tuple[str, int], ...]]:
    """All feasible (non-zero) allocations of each device type (brute force,
    per the paper's search_optimal_plan)."""
    ranges = [range(0, n + 1) for _, n in res.devices]
    out = []
    for combo in itertools.product(*ranges):
        if sum(combo) == 0:
            continue
        out.append(tuple((d, c) for (d, _), c in zip(res.devices, combo) if c > 0))
    return out


def optimal_matching(clouds: Sequence[CloudResources],
                     prefer_measured: bool = True) -> List[ResourcePlan]:
    """Algorithm 1: compute LP of each cloud at full allocation, take the
    minimum as the straggler reference, then for every cloud pick the
    cheapest allocation whose LP >= reference with minimal LP excess."""
    if not clouds:
        return []
    full_lp = [load_power(c.devices, c.data_size, prefer_measured) for c in clouds]
    min_lp = min(full_lp)

    return [_match_one(cloud, min_lp, prefer_measured) for cloud in clouds]


# ---------------------------------------------------------------------------
# plan diffing + incremental re-matching (elasticity engine)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanDiff:
    """Difference between two resource-plan sets, keyed by region.

    ``resized`` carries (region, old_allocation, new_allocation) for regions
    present in both plans whose allocation changed.  An all-empty diff means
    a reconfiguration would be a no-op and the trainer skips the barrier
    re-stacking entirely.
    """

    added: Tuple[str, ...] = ()
    removed: Tuple[str, ...] = ()
    resized: Tuple[Tuple[str, Tuple[Tuple[str, int], ...],
                         Tuple[Tuple[str, int], ...]], ...] = ()

    @property
    def is_empty(self) -> bool:
        return not (self.added or self.removed or self.resized)

    def summary(self) -> str:
        if self.is_empty:
            return "no-op"
        parts = []
        if self.added:
            parts.append("+" + ",".join(self.added))
        if self.removed:
            parts.append("-" + ",".join(self.removed))
        for region, old, new in self.resized:
            parts.append(f"{region}:{dict(old)}->{dict(new)}")
        return " ".join(parts)


def diff_plans(old: Sequence[ResourcePlan],
               new: Sequence[ResourcePlan]) -> PlanDiff:
    """Region-keyed structural diff of two Algorithm-1 outputs."""
    old_by = {p.region: p for p in old}
    new_by = {p.region: p for p in new}
    added = tuple(r for r in new_by if r not in old_by)
    removed = tuple(r for r in old_by if r not in new_by)
    resized = tuple(
        (r, old_by[r].allocation, new_by[r].allocation)
        for r in old_by
        if r in new_by and old_by[r].allocation != new_by[r].allocation)
    return PlanDiff(added=added, removed=removed, resized=resized)


def incremental_matching(
    clouds: Sequence[CloudResources],
    prev: Optional[Sequence[ResourcePlan]] = None,
    prefer_measured: bool = True,
) -> List[ResourcePlan]:
    """Incremental Algorithm 1 for the elasticity engine.

    Re-computes the straggler reference for the *new* resource picture, then
    reuses the previous allocation for every cloud whose resources are
    unchanged and whose previous allocation is still optimal against the new
    reference (exact same LP-excess bound), searching only the clouds the
    event actually perturbed.  Output is identical to a fresh
    ``optimal_matching`` call; only the work is incremental.
    """
    if not clouds:
        return []
    prev_by = {p.region: p for p in (prev or [])}
    full_lp = [load_power(c.devices, c.data_size, prefer_measured)
               for c in clouds]
    min_lp = min(full_lp)

    plans: List[ResourcePlan] = []
    for cloud, flp in zip(clouds, full_lp):
        old = prev_by.get(cloud.region)
        if old is not None and _reusable(cloud, old, min_lp, prefer_measured):
            lp = load_power(old.allocation, cloud.data_size, prefer_measured)
            plans.append(old if abs(lp - old.load_power) <= 1e-12 else
                         ResourcePlan(region=cloud.region,
                                      allocation=old.allocation,
                                      load_power=lp))
            continue
        if flp <= min_lp + 1e-12:
            # this cloud *is* the straggler: full allocation by construction
            plans.append(ResourcePlan(region=cloud.region,
                                      allocation=cloud.devices,
                                      load_power=flp))
            continue
        plans.append(_match_one(cloud, min_lp, prefer_measured))
    return plans


def _match_one(cloud: CloudResources, min_lp: float,
               prefer_measured: bool) -> ResourcePlan:
    """Single-cloud Algorithm-1 inner search against a fixed reference."""
    best: Optional[Tuple[float, int, Tuple[Tuple[str, int], ...], float]] = None
    for alloc in _allocations(cloud):
        lp = load_power(alloc, cloud.data_size, prefer_measured)
        if lp < min_lp - 1e-12:
            continue
        units = sum(n for _, n in alloc)
        key = (lp - min_lp, units)
        if best is None or key < (best[0], best[1]):
            best = (lp - min_lp, units, alloc, lp)
    assert best is not None
    return ResourcePlan(region=cloud.region, allocation=best[2],
                        load_power=best[3])


def _reusable(cloud: CloudResources, old: ResourcePlan, min_lp: float,
              prefer_measured: bool) -> bool:
    """Previous allocation still optimal: feasible, not below the new
    reference, and no strictly better (smaller-excess or cheaper) allocation
    exists — checked cheaply by re-running the inner search only when the old
    excess is non-zero."""
    avail = dict(cloud.devices)
    for dev, n in old.allocation:
        if dev not in avail or n > avail[dev]:
            return False
    lp = load_power(old.allocation, cloud.data_size, prefer_measured)
    if lp < min_lp - 1e-12:
        return False
    if abs(lp - min_lp) <= 1e-12:
        return True     # zero excess cannot be beaten
    fresh = _match_one(cloud, min_lp, prefer_measured)
    return fresh.allocation == old.allocation


# ---------------------------------------------------------------------------
# predicted effect (used by the WAN simulator & Fig 8 reproduction)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LoadPrediction:
    region: str
    t_train_rel: float     # relative local-training time for its shard


def predict_times(clouds: Sequence[CloudResources],
                  plans: Optional[Sequence[ResourcePlan]] = None,
                  prefer_measured: bool = True) -> List[LoadPrediction]:
    """T_train ∝ S_data / C_devices (paper §III.B): relative per-period local
    training times, before or after applying a resource plan."""
    out = []
    for i, c in enumerate(clouds):
        devices = plans[i].allocation if plans is not None else c.devices
        power = sum(n * CATALOG[d].power(prefer_measured) for d, n in devices)
        out.append(LoadPrediction(region=c.region, t_train_rel=c.data_size / power))
    return out


def waiting_fraction(preds: Sequence[LoadPrediction]) -> Dict[str, float]:
    """Fraction of each cloud's period spent waiting for the straggler."""
    tmax = max(p.t_train_rel for p in preds)
    return {p.region: 1.0 - p.t_train_rel / tmax for p in preds}


# ---------------------------------------------------------------------------
# TPU-cluster planning (hardware adaptation)
# ---------------------------------------------------------------------------


def plan_batch_split(global_batch: int, pod_powers: Sequence[float]) -> List[int]:
    """Split a global batch across pods proportional to compute power —
    the plan-time expression of the paper's elastic scaling on TPU, where
    allocation granularity is the per-pod microbatch rather than serverless
    worker count.  Largest-remainder rounding; every pod gets >= 1."""
    total = sum(pod_powers)
    raw = [global_batch * p / total for p in pod_powers]
    base = [max(1, int(x)) for x in raw]
    while sum(base) > global_batch:
        base[base.index(max(base))] -= 1
    rema = sorted(range(len(raw)), key=lambda i: raw[i] - base[i], reverse=True)
    i = 0
    while sum(base) < global_batch:
        base[rema[i % len(rema)]] += 1
        i += 1
    return base
