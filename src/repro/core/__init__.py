"""Core: the paper's contributions — sync strategies, elastic scheduler,
control plane, WAN simulator, cost model."""
from repro.core.sync import SyncConfig, SyncState, CODEC_TIERS, \
    BUCKET_CLASSES, BucketOverride, BucketSpec, BucketLayout, \
    bucket_layout, bucket_weights_of, \
    init_sync_state, on_step_gradients, apply_sync, is_sync_step, \
    traffic_per_step_mb, grow_pods, shrink_pods, resize_sync_state, \
    retune_sync_state  # noqa: F401
from repro.core.scheduler import CloudResources, ResourcePlan, DeviceType, \
    CATALOG, load_power, optimal_matching, predict_times, waiting_fraction, \
    plan_batch_split, PlanDiff, diff_plans, incremental_matching  # noqa: F401
from repro.core.wan import SimCloud, SimEvent, WANConfig, SimResult, \
    BandwidthTrace, simulate, compare_strategies  # noqa: F401
from repro.core.cost import CostReport, cost_report, tier_payload_table, \
    bucket_payload_table, adaptive_traffic_mb  # noqa: F401
from repro.core.autotune import AdaptiveSyncController, BucketStats, \
    BucketedSyncController, BucketPlanUpdate, SyncPlanUpdate, WanProbe, \
    WanProbeEstimator, bucket_stats_from_sync_state, build_ladder  # noqa: F401
from repro.core.control_plane import FunctionRegistry, AddressTable, Workflow, \
    WorkflowEngine, TrainingRequest, TrainingPlan, SchedulerFunction, \
    CommunicatorFunction, build_training_plan, training_workflow, reschedule, \
    CloudEvent, EventBus, ElasticityController, ReconfigPlan, \
    adapt_interval  # noqa: F401
